"""Sharded metadata plane (core/mdshard): routing, single-shard fast path,
cross-shard 2PC atomicity under fault injection, subscribe fan-in."""
import threading

import pytest

from repro.core import (Cluster, KVConflict, ShardedKV, TransactionAborted,
                        WarpKV)
from repro.core.testing import LockOrderWatchdog, make_flaky_kv

N_SHARDS = 4


@pytest.fixture
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"),
                n_meta_shards=N_SHARDS)
    yield c
    c.close()


def _paths_on_distinct_shards(kv, n=2, prefix="/x"):
    """Deterministically find n paths whose shards all differ."""
    out, seen = [], set()
    i = 0
    while len(out) < n:
        p = f"{prefix}{i}"
        s = kv.shard_index("paths", p)
        if s not in seen:
            seen.add(s)
            out.append(p)
        i += 1
    return out


# ---------------------------------------------------------------- routing
def test_default_cluster_uses_plain_warpkv(tmp_path):
    c = Cluster(n_servers=1, data_dir=str(tmp_path / "d"))
    try:
        # n_meta_shards=1 must be the EXACT single-store fast path — the
        # plain WarpKV object, not a 1-shard router in front of it.
        assert isinstance(c.kv, WarpKV)
        assert "kv_shards" not in c.total_stats()
    finally:
        c.close()


def test_knob_validation(tmp_path):
    with pytest.raises(ValueError):
        Cluster(n_servers=1, data_dir=str(tmp_path / "a"), n_meta_shards=0)
    with pytest.raises(ValueError):
        Cluster(n_servers=1, data_dir=str(tmp_path / "b"), lease_ttl=0)
    with pytest.raises(ValueError):
        Cluster(n_servers=1, data_dir=str(tmp_path / "c"),
                kv_service_time=-1)


def test_inode_colocated_with_path(cluster):
    """Created files land their inode (and regions) on the path's shard,
    so per-file transactions are single-shard by construction."""
    cl = cluster.client()
    for i in range(12):
        p = f"/colo{i}"
        fd = cl.open(p, "w")
        cl.write(fd, b"data")
        cl.close(fd)
        ino = cluster.kv.get("paths", p)
        assert cluster.kv.shard_index("inodes", ino) \
            == cluster.kv.shard_index("paths", p)
        assert cluster.kv.shard_index("regions", (ino, 0)) \
            == cluster.kv.shard_index("paths", p)


def test_single_file_ops_stay_single_shard(cluster):
    """The hot per-file loop takes the group-commit path: 2PC counters
    must not move at all."""
    cl = cluster.client()
    fd = cl.open("/hot", "w")
    cl.write(fd, b"x" * 1000)
    cl.close(fd)
    before = cluster.kv.stats_2pc.snapshot()
    fd = cl.open("/hot", "rw")
    for i in range(10):
        cl.pwrite(fd, b"y" * 100, i * 100)
        assert cl.pread(fd, 100, i * 100) == b"y" * 100
        cl.stat("/hot")
    cl.close(fd)
    after = cluster.kv.stats_2pc.snapshot()
    assert after["cross_shard_commits"] == before["cross_shard_commits"]
    assert after["prepare_aborts"] == before["prepare_aborts"]
    assert after["single_shard_commits"] > before["single_shard_commits"]


def test_sharded_end_to_end_correctness(cluster):
    cl = cluster.client()
    blobs = {}
    for i in range(10):
        p = f"/e2e{i}"
        blobs[p] = (f"payload-{i}".encode()) * 50
        fd = cl.open(p, "w")
        cl.write(fd, blobs[p])
        cl.close(fd)
    cl2 = cluster.client()
    for p, want in blobs.items():
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == want
        cl2.close(fd)
    # files spread over more than one shard (balanced hash routing)
    used = {cluster.kv.shard_index("paths", p) for p in blobs}
    assert len(used) > 1


# ------------------------------------------------------------- 2PC commits
def test_cross_shard_txn_commits_atomically(cluster):
    cl = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl.open(p, "w")
        cl.write(fd, b"old")
        cl.close(fd)
    before = cluster.kv.stats_2pc.snapshot()
    with cl.transaction():
        for p in (pa, pb):
            fd = cl.open(p, "rw")
            cl.pwrite(fd, b"NEW", 0)
            cl.close(fd)
    after = cluster.kv.stats_2pc.snapshot()
    assert after["cross_shard_commits"] > before["cross_shard_commits"]
    cl2 = cluster.client()
    for p in (pa, pb):
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == b"NEW"
        cl2.close(fd)


def _write_both(client, pa, pb, payload):
    with client.transaction():
        for p in (pa, pb):
            fd = client.open(p, "rw")
            client.pwrite(fd, payload, 0)
            client.close(fd)


def test_prepare_failure_retries_and_leaves_consistent_state(cluster):
    """A prepare failure on either shard position aborts cleanly (nothing
    applied anywhere), surfaces as a retryable KVConflict, and the §2.6
    replay commits the transaction on a later attempt."""
    cl0 = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl0.open(p, "w")
        cl0.write(fd, b"old")
        cl0.close(fd)
    # fail prepare #1 (first shard of attempt 1) and prepare #3 (second
    # shard of attempt 2) — a mid-sequence abort with locks already held
    flaky = make_flaky_kv(cluster, fail_prepares={1, 3})
    cl = cluster.client()
    _write_both(cl, pa, pb, b"NEW")
    assert flaky.injected == 2
    assert cluster.kv.stats_2pc.prepare_aborts >= 2
    cl2 = cluster.client()
    for p in (pa, pb):
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == b"NEW"
        cl2.close(fd)


def test_prepare_failure_exhausts_retries_nothing_visible(cluster):
    """When every attempt's prepare fails, the transaction aborts to the
    application and NO shard shows any effect — all-or-nothing."""
    cl0 = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl0.open(p, "w")
        cl0.write(fd, b"old")
        cl0.close(fd)
    make_flaky_kv(cluster, fail_prepares=set(range(1, 200)))
    cl = cluster.client()
    with pytest.raises(TransactionAborted):
        _write_both(cl, pa, pb, b"NEW")
    cl2 = cluster.client()
    for p in (pa, pb):
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == b"old", \
            "aborted 2PC transaction leaked state onto a shard"
        cl2.close(fd)


def test_crash_between_prepare_and_apply_resolved_abort(cluster):
    """Coordinator crash at the commit point with an 'abort' decision:
    fully rolled back, then the replay commits cleanly."""
    cl0 = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl0.open(p, "w")
        cl0.write(fd, b"old")
        cl0.close(fd)
    flaky = make_flaky_kv(cluster, fail_applies={1},
                          apply_resolution="abort")
    cl = cluster.client()
    _write_both(cl, pa, pb, b"NEW")
    assert flaky.injected == 1
    cl2 = cluster.client()
    for p in (pa, pb):
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == b"NEW"
        cl2.close(fd)


def test_crash_between_prepare_and_apply_resolved_commit(cluster):
    """Coordinator crash at the commit point whose decision record says
    COMMIT: recovery rolls forward and the transaction applies exactly
    once on every shard — never partially."""
    cl0 = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl0.open(p, "w")
        cl0.write(fd, b"old")
        cl0.close(fd)
    flaky = make_flaky_kv(cluster, fail_applies={1},
                          apply_resolution="commit")
    cl = cluster.client()
    _write_both(cl, pa, pb, b"NEW")
    assert flaky.injected == 1
    assert cluster.kv.stats_2pc.recovered_commits == 1
    cl2 = cluster.client()
    for p in (pa, pb):
        fd = cl2.open(p, "r")
        assert cl2.read(fd) == b"NEW"
        cl2.close(fd)


def test_concurrent_cross_shard_commits_no_deadlock(cluster):
    """Cross-shard committers + single-shard group commits running
    concurrently: global (shard, stripe) lock order means no deadlock and
    every write lands."""
    # Witnessed stripes mean an out-of-(shard,stripe)-order grab raises at
    # acquisition time rather than tripping the 60s deadlock timeout below.
    assert LockOrderWatchdog.enabled()
    assert all(LockOrderWatchdog.is_witnessed(s._stripes[0])
               for s in cluster.kv.shards)
    cl0 = cluster.client()
    pa, pb = _paths_on_distinct_shards(cluster.kv)
    for p in (pa, pb):
        fd = cl0.open(p, "w")
        cl0.write(fd, b"0" * 8)
        cl0.close(fd)
    errs = []

    def cross(i):
        try:
            c = cluster.client()
            for _ in range(5):
                _write_both(c, pa, pb, f"c{i:02d}data".encode())
        except Exception as e:          # noqa: BLE001
            errs.append(e)

    def single(i):
        try:
            c = cluster.client()
            fd = c.open(f"/solo{i}", "w")
            for _ in range(10):
                c.write(fd, b"z" * 64)
            c.close(fd)
        except Exception as e:          # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=cross, args=(i,)) for i in range(3)] \
        + [threading.Thread(target=single, args=(i,)) for i in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
        assert not t.is_alive(), "deadlocked cross-shard commit"
    assert not errs
    # both files always end at the same value (atomicity under races)
    cl2 = cluster.client()
    fd = cl2.open(pa, "r")
    va = cl2.read(fd)
    cl2.close(fd)
    fd = cl2.open(pb, "r")
    vb = cl2.read(fd)
    cl2.close(fd)
    assert va == vb
    LockOrderWatchdog.assert_clean()


# ------------------------------------------------------- subscribe fan-in
def test_sharded_wal_bounded_and_subscribe_converges():
    """The PR 5 bounded-WAL replay contract survives sharding: bounded
    per-shard WAL memory, late subscriber converges on the latest value
    per key, listener stays live — through the fan-in."""
    kv = ShardedKV(3)
    for sh in kv.shards:
        sh.WAL_TAIL_MAX = 32
    keys = [f"k{i}" for i in range(5)]
    for round_ in range(200):
        for k in keys:
            kv.put("s", k, (k, round_))
    for sh in kv.shards:
        assert len(sh._wal_tail) <= 32
    assert kv.wal_entries() <= 3 * 32 + len(keys), \
        "WAL memory must be O(keyspace + tail) per shard, not O(history)"

    seen = {}
    kv.subscribe(lambda sp, k, v, ver: seen.__setitem__((sp, k), v))
    for k in keys:
        assert seen[("s", k)] == (k, 199), \
            "a late subscriber must converge on the latest value per key"
    kv.put("s", "k0", "fresh")
    assert seen[("s", "k0")] == "fresh"


def test_fanin_per_shard_sequence_numbers_ordered():
    """with_meta delivery: per-shard seqs are 1-based and gap-free, and
    each shard's events arrive in its commit order."""
    kv = ShardedKV(4)
    events = []
    kv.subscribe(
        lambda sp, k, v, ver, shard, seq: events.append((shard, seq, k, v)),
        with_meta=True)
    for i in range(50):
        kv.put("s", f"k{i}", i)
    per_shard = {}
    for shard, seq, _k, _v in events:
        per_shard.setdefault(shard, []).append(seq)
    assert sum(len(v) for v in per_shard.values()) == len(events) >= 50
    for shard, seqs in per_shard.items():
        assert seqs == list(range(1, len(seqs) + 1)), \
            f"shard {shard} fan-in seqs not contiguous: {seqs[:10]}"


def test_fanin_replay_is_deterministic():
    """Two identically-populated sharded KVs replay the same event order
    to a late subscriber (shard-by-shard, snapshot then tail)."""
    def build():
        kv = ShardedKV(3)
        for i in range(30):
            kv.put("s", f"k{i}", i * 7)
        got = []
        kv.subscribe(lambda sp, k, v, ver: got.append((sp, k, v, ver)))
        return got

    assert build() == build()


# ------------------------------------------------------------------ stats
def test_total_stats_sections(cluster):
    cl = cluster.client()
    fd = cl.open("/st", "w")
    cl.write(fd, b"abc")
    cl.close(fd)
    ts = cluster.total_stats()
    assert len(ts["kv_shards"]) == N_SHARDS
    for snap in ts["kv_shards"]:
        assert "commits" in snap and "gets" in snap
    md = ts["mdshard"]
    for key in ("single_shard_commits", "cross_shard_commits",
                "prepare_aborts", "recovered_commits"):
        assert key in md
    # the aggregate "kv" section equals the per-shard sum
    assert ts["kv"]["commits"] == sum(s["commits"] for s in ts["kv_shards"])


def test_gc_walks_all_shards(cluster):
    from repro.core import GarbageCollector

    cl = cluster.client()
    for i in range(8):
        fd = cl.open(f"/gcf{i}", "w")
        for _ in range(6):
            cl.write(fd, b"frag" * 64)
        cl.close(fd)
    gc = GarbageCollector(cluster)
    stats = gc.compact_all()
    # regions from every shard were visited (the walk isn't single-shard)
    region_shards = {cluster.kv.shard_index("regions", k)
                     for k in cluster.kv.keys("regions")}
    assert len(region_shards) > 1
    assert stats["regions"] + stats["noop"] > 0
    live = gc.scan_filesystem()
    assert sum(len(v) for v in live.values()) > 0


def test_inject_aborts_on_sharded_kv(cluster):
    cl = cluster.client()
    fd = cl.open("/inj", "w")
    cl.write(fd, b"first")
    cl.close(fd)
    cluster.kv.inject_aborts(1)
    retries0 = cl.stats.txn_retries
    fd = cl.open("/inj", "rw")
    cl.pwrite(fd, b"SECOND", 0)
    cl.close(fd)
    assert cl.stats.txn_retries > retries0
    fd = cl.open("/inj", "r")
    assert cl.read(fd) == b"SECOND"
    cl.close(fd)


def test_plain_kvconflict_retry_still_works_sharded(cluster):
    """FlakyKV's classic whole-commit injection composes with ShardedKV."""
    flaky = make_flaky_kv(cluster, fail_commits={2})
    cl = cluster.client()
    fd = cl.open("/fc", "w")
    cl.write(fd, b"payload")
    cl.close(fd)
    assert flaky.injected == 1
    fd = cl.open("/fc", "r")
    assert cl.read(fd) == b"payload"
    cl.close(fd)
