"""Paged serving engine: outputs must match the dense ring-cache decode
path exactly; prefix forking must share pages (zero-copy) and still
produce independent continuations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serving.engine import Engine, EngineConfig
from repro.train import make_serve_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-360m").replace(compute_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_generate(model, params, prompt, max_new):
    """Greedy decode via the model's ring-buffer cache path."""
    cfg = model.cfg
    cache = model.init_cache(1, max_len=len(prompt) + max_new)
    serve = make_serve_step(model)
    out = []
    tok = None
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        tok, cache = serve(params, cache,
                           {"tokens": jnp.asarray([[cur]], jnp.int32),
                            "pos": jnp.asarray([t], jnp.int32)})
        if t >= len(prompt) - 1:
            out.append(int(tok[0]))
    return out[:max_new]


def test_engine_matches_reference(setup):
    cfg, model, params = setup
    eng = Engine(model, params, EngineConfig(page_tokens=4, num_pages=128))
    prompt = np.array([5, 9, 2, 7, 11, 3], np.int32)
    sid = eng.add(prompt, max_new=6)
    while not eng._requests[sid].done:
        eng.step()
    ref = _reference_generate(model, params, list(prompt), 6)
    assert eng.result(sid) == ref


def test_engine_batched_requests(setup):
    cfg, model, params = setup
    eng = Engine(model, params, EngineConfig(page_tokens=4, num_pages=256))
    prompts = [np.array(p, np.int32) for p in
               ([1, 2, 3], [10, 20, 30, 40, 50], [7, 7, 7, 7])]
    sids = [eng.add(p, max_new=4) for p in prompts]
    for _ in range(8):
        eng.step()
    for sid, p in zip(sids, prompts):
        ref = _reference_generate(model, params, list(p), 4)
        assert eng.result(sid) == ref, sid


def test_prefix_fork_shares_pages_and_diverges(setup):
    cfg, model, params = setup
    eng = Engine(model, params, EngineConfig(page_tokens=4, num_pages=256))
    base = np.array([3, 1, 4, 1, 5, 9, 2, 6], np.int32)   # 2 full pages
    a = eng.add(base, max_new=4)
    allocated_before = eng.cache.stats["pages_allocated"]
    # fork with the same 8-token (page-aligned) prefix + 2 extra tokens
    b = eng.add(np.concatenate([base, [8, 8]]).astype(np.int32),
                max_new=4, fork_from=a)
    assert eng.cache.stats["pages_shared"] >= 2        # prefix pages shared
    while not (eng._requests[a].done and eng._requests[b].done):
        eng.step()
    ref_a = _reference_generate(model, params, list(base), 4)
    ref_b = _reference_generate(model, params, list(base) + [8, 8], 4)
    assert eng.result(a) == ref_a
    assert eng.result(b) == ref_b


def test_fork_mid_page_cow(setup):
    cfg, model, params = setup
    eng = Engine(model, params, EngineConfig(page_tokens=4, num_pages=256))
    base = np.array([3, 1, 4, 1, 5, 9], np.int32)      # 1.5 pages
    a = eng.add(base, max_new=3)
    b = eng.add(np.concatenate([base, [2, 2]]).astype(np.int32),
                max_new=3, fork_from=a)
    assert eng.cache.stats["pages_copied"] >= 1        # open page COW'd
    while not (eng._requests[a].done and eng._requests[b].done):
        eng.step()
    assert eng.result(a) == _reference_generate(model, params,
                                                list(base), 3)
    assert eng.result(b) == _reference_generate(
        model, params, list(base) + [2, 2], 3)
