"""The unified async I/O runtime (``core/iort.py``).

Covers:
  * both schedulers are strategy layers over ONE runtime — no private
    pools, no duplicated failover loops;
  * the async surface (``readv_async``/``preadv_async``/``writev_async``/
    ``pwritev_async``): equivalence with the sync twins, submission-time
    EBADF/EINVAL, eager offset semantics, write-behind short-circuit,
    auto-commit-only scoping;
  * failure paths: a future resolving to ``StorageError`` after replica
    exhaustion, a pending async read crossing a commit that invalidates
    its plan (must re-plan, never serve stale extents), and shutdown with
    in-flight futures (clean drain, no leaked pool threads);
  * the version-validated read-plan cache: hot re-read hits, invalidation
    by commits, bypass under write-behind and open transactions;
  * adaptive gap/pack thresholds from the EWMA cost model, and knob
    pinning/validation at ``Cluster`` construction;
  * stats counters staying exact when pool threads and the application
    thread mutate them concurrently (the lost-update race ``add`` fixes).
"""
import threading
import time

import pytest

from repro.core import Cluster, StorageError, WtfError
from repro.core.iort import (ADAPTIVE_CEILING, ADAPTIVE_FLOOR,
                             ADAPTIVE_SEED, IoRuntime)

REGION = 1 << 20


def make_cluster(tmp_path, tag="c", **kw):
    kw.setdefault("n_servers", 3)
    kw.setdefault("replication", 1)
    kw.setdefault("region_size", REGION)
    return Cluster(data_dir=str(tmp_path / tag), **kw)


@pytest.fixture()
def cluster(tmp_path):
    c = make_cluster(tmp_path)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def write_file(fs, path, data):
    with fs.open_file(path, "w") as f:
        f.write(data)


# ------------------------------------------------------------- unification
def test_schedulers_share_one_runtime(cluster):
    """Acceptance: iosched/wsched retain no private pool and delegate to
    the one runtime the cluster owns."""
    assert cluster.scheduler.runtime is cluster.runtime
    assert cluster.wsched.runtime is cluster.runtime
    assert not hasattr(cluster.scheduler, "_pool")
    assert not hasattr(cluster.wsched, "_pool")


def test_read_failover_through_unified_walk(tmp_path):
    """A replicated read survives the chosen server dying — via the one
    ``run_with_failover`` loop."""
    c = make_cluster(tmp_path, "fo", n_servers=3, replication=2)
    fs = c.client()
    write_file(fs, "/f", b"payload" * 100)
    ptrs = {p.server_id
            for ext in fs.yank(fs.open("/f"), 700) for p in ext.ptrs}
    c.fail_server(next(iter(ptrs)))
    with fs.open_file("/f") as f:
        assert f.read() == b"payload" * 100
    c.close()


# ------------------------------------------------------------ async surface
def test_async_read_matches_sync(fs):
    data = bytes(range(256)) * 64
    write_file(fs, "/f", data)
    with fs.open_file("/f") as f:
        ranges = [(0, 100), (5000, 300), (16000, 100), (100, 0)]
        fut = f.readv_async(ranges)
        assert fut.result() == f.readv(ranges)
    assert fs.stats.async_ops == 1


def test_preadv_async_and_eof_clamp(fs):
    write_file(fs, "/f", b"x" * 100)
    with fs.open_file("/f") as f:
        out = f.preadv_async([60, 60, 60], 0).result()
    assert out == [b"x" * 60, b"x" * 40, b""]


def test_async_write_roundtrip_and_eager_offset(fs):
    with fs.open_file("/w", "w") as f:
        fut = f.writev_async([b"hello", b" ", b"world"])
        # POSIX-AIO style: the fd offset advances at submission.
        assert f.tell() == 11
        assert fut.result() == 11
        assert f.pwritev_async([b"HE"], 0).result() == 2
        assert f.tell() == 11              # positional: untouched
    with fs.open_file("/w") as f:
        assert f.read() == b"HEllo world"


def test_async_ordered_writes_interleave_with_planning(fs):
    """Issue many async gather-writes back to back; the eager offsets make
    them land consecutively regardless of completion order."""
    chunks = [bytes([i]) * 97 for i in range(32)]
    with fs.open_file("/seq", "w") as f:
        futs = [f.writev_async([c]) for c in chunks]
        assert [x.result() for x in futs] == [97] * 32
    with fs.open_file("/seq") as f:
        assert f.read() == b"".join(chunks)


def test_async_rejects_bad_fd_and_negative_ranges_at_submission(fs):
    from repro.core import BadFileDescriptor, InvalidOffset
    with pytest.raises(BadFileDescriptor):
        fs.readv_async(999, [(0, 1)])
    write_file(fs, "/f", b"abc")
    fd = fs.open("/f")
    with pytest.raises(InvalidOffset):
        fs.readv_async(fd, [(-1, 5)])
    wfd = fs.open("/f2", "w")
    with pytest.raises(InvalidOffset):
        fs.pwritev_async(wfd, [b"x"], -3)
    from repro.core import NotOpenForWriting
    with pytest.raises(NotOpenForWriting):
        fs.writev_async(fd, [b"x"])        # "r" fd


def test_async_is_auto_commit_only(fs):
    write_file(fs, "/f", b"abc")
    fd = fs.open("/f")
    with pytest.raises(WtfError):
        with fs.transaction():
            fs.readv_async(fd, [(0, 1)])


def test_rejected_writev_async_leaves_offset_untouched(fs):
    """The auto-commit-only gate must fire BEFORE the eager offset
    advance: a rejected submission inside a transaction may not move the
    fd (a later write would land past a hole of stale bytes)."""
    wfd = fs.open("/w", "w")
    fs.write(wfd, b"base")
    with fs.transaction():
        with pytest.raises(WtfError):
            fs.writev_async(wfd, [b"xxxx"])
        assert fs.tell(wfd) == 4           # unmoved
        fs.write(wfd, b"MORE")
    with fs.open_file("/w") as f:
        assert f.read() == b"baseMORE"


def test_async_checkpoint_save_does_not_block_client_async_ops(tmp_path):
    """AsyncCheckpointer saves run on a PRIVATE client: the save's
    worker-side transaction must not make the shared client reject its
    own concurrent async ops as 'inside a transaction'."""
    import numpy as np
    from repro.checkpoint import AsyncCheckpointer, CheckpointManager
    c = make_cluster(tmp_path, "ckc")
    fs = c.client()
    write_file(fs, "/r", b"r" * 8192)
    mgr = CheckpointManager(fs, "/ck")
    ck = AsyncCheckpointer(mgr)
    tree = {"w": np.arange(200000, dtype=np.float32)}
    with fs.open_file("/r") as f:
        ck.save(5, tree)                   # in flight on a worker
        futs = [f.readv_async([(0, 512)]) for _ in range(8)]
        assert all(fu.result() == [b"r" * 512] for fu in futs)
        ck.wait()
    got = mgr.restore({"w": None}, step=5)
    assert np.array_equal(got["w"], tree["w"])
    c.close()


def test_pipeline_close_interrupts_empty_epoch_spin(tmp_path):
    """A shard smaller than one global batch yields zero steps per epoch;
    iterator shutdown must still stop the producer (it re-checks stop on
    every epoch bump) instead of materializing epoch files forever."""
    import time as _time
    import warnings
    import numpy as np
    from repro.data.pipeline import DataPipeline, PipelineConfig
    from repro.data.records import write_token_shard
    c = make_cluster(tmp_path, "spin")
    fs = c.client()
    fs.mkdir("/d")
    rng = np.random.RandomState(0)
    write_token_shard(fs, "/d/s", iter(rng.randint(0, 9, 4 * 8)), 8)
    cfg = PipelineConfig(src_paths=("/d/s",), work_dir="/d/ep",
                         block_tokens=8, global_batch=64, prefetch=2)
    it = iter(DataPipeline(fs, cfg))
    _time.sleep(0.05)                      # let the producer spin epochs
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # a stuck producer would warn
        it.close()
    c.close()


def test_checkpoint_restore_inside_transaction(tmp_path):
    """restore() joins an open transaction by reading synchronously (the
    async fan-out is auto-commit only)."""
    import numpy as np
    from repro.checkpoint import CheckpointManager
    c = make_cluster(tmp_path, "ckr")
    fs = c.client()
    mgr = CheckpointManager(fs, "/ck")
    tree = {"w": np.arange(64, dtype=np.float32)}
    mgr.save(1, tree)
    with fs.transaction():
        got = mgr.restore({"w": None}, step=1)
    assert np.array_equal(got["w"], tree["w"])
    c.close()


def test_async_write_with_write_behind_completes_synchronously(tmp_path):
    c = make_cluster(tmp_path, "wb", write_behind=True)
    fs = c.client()
    with fs.open_file("/f", "w") as f:
        fut = f.writev_async([b"deferred"])
        assert fut.done()                  # nothing to overlap: buffered
        assert fut.result() == 8
    with fs.open_file("/f") as f:
        assert f.read() == b"deferred"
    c.close()


# ------------------------------------------------------------ failure paths
def test_async_future_resolves_to_storage_error_on_replica_exhaustion(
        tmp_path):
    c = make_cluster(tmp_path, "ex", n_servers=2)
    fs = c.client()
    write_file(fs, "/f", b"doomed" * 50)
    with fs.open_file("/f") as f:
        for sid in list(c.servers):
            c.fail_server(sid)
        fut = f.readv_async([(0, 300)])
        assert isinstance(fut.exception(), StorageError)
        with pytest.raises(StorageError):
            fut.result()
    c.close()


def test_pending_async_read_replans_after_invalidating_commit(tmp_path):
    """An async read still queued when a commit rewrites its range must
    re-plan against the committed state — never serve the extents its
    (cached) plan would have fetched."""
    c = make_cluster(tmp_path, "inv", fetch_workers=1)
    fs = c.client()
    write_file(fs, "/f", b"old!" * 256)
    with fs.open_file("/f", "a") as f:
        f.readv([(0, 1024)])               # populate the plan cache
        assert fs.stats.plan_cache_misses == 1
        gate = threading.Event()
        blocker = c.runtime.submit_op(gate.wait)
        fut = f.readv_async([(0, 1024)])   # queued behind the blocker
        fs.pwrite(f.fd, b"new!" * 256, 0)  # invalidates the cached plan
        gate.set()
        assert fut.result() == [b"new!" * 256]
        blocker.result()
    c.close()


def test_shutdown_drains_in_flight_futures_without_leaking_threads(
        tmp_path):
    c = make_cluster(tmp_path, "dr", fetch_workers=2)
    fs = c.client()
    write_file(fs, "/f", b"z" * 4096)
    with fs.open_file("/f") as f:
        futs = [f.readv_async([(i * 64, 64)]) for i in range(16)]
        c.close()                          # drain: everything completes
    assert all(fut.done() for fut in futs)
    assert [fut.result() for fut in futs] == [[b"z" * 64]] * 16
    for _ in range(50):                    # pool threads must exit
        if not any(t.name.startswith("wtf-iort")
                   for t in threading.enumerate()):
            break
        time.sleep(0.02)
    assert not any(t.name.startswith("wtf-iort")
                   for t in threading.enumerate())


# ---------------------------------------------------------------- plan cache
def test_plan_cache_hot_reread_hits_and_serves_fresh_bytes(fs):
    write_file(fs, "/f", b"abcd" * 1000)
    with fs.open_file("/f", "a") as f:
        ranges = [(0, 64), (512, 64), (2048, 128)]
        first = f.readv(ranges)
        assert fs.stats.plan_cache_misses == 1
        for _ in range(5):
            assert f.readv(ranges) == first
        assert fs.stats.plan_cache_hits == 5


def test_plan_cache_invalidated_by_commit(fs):
    write_file(fs, "/f", b"A" * 8192)
    with fs.open_file("/f", "a") as f:
        assert f.readv([(0, 8192)]) == [b"A" * 8192]
        hits = fs.stats.plan_cache_hits
        fs.pwrite(f.fd, b"B" * 4096, 0)    # commutes bump region versions
        assert f.readv([(0, 8192)]) == [b"B" * 4096 + b"A" * 4096]
        assert fs.stats.plan_cache_hits == hits   # stale entry: a miss
        assert f.readv([(0, 8192)])[0][:4] == b"BBBB"
        assert fs.stats.plan_cache_hits == hits + 1


def test_plan_cache_is_per_range_set_and_respects_eof_growth(fs):
    write_file(fs, "/f", b"x" * 100)
    with fs.open_file("/f", "a") as f:
        assert fs.readv(f.fd, [(0, 1000)]) == [b"x" * 100]
        f.append(b"y" * 50)
        # EOF moved: the clamped ranges differ → different cache key; the
        # read must see the appended bytes.
        assert fs.readv(f.fd, [(0, 1000)]) == [b"x" * 100 + b"y" * 50]


def test_plan_cache_bypassed_inside_writing_transaction(fs):
    write_file(fs, "/f", b"1234" * 64)
    fd = fs.open("/f", "a")                         # writable fd
    with fs.transaction():
        fs.pwrite(fd, b"ZZ", 0)
        # queued commutes: the cache must not serve (or record) plans that
        # include this transaction's in-flight view
        assert fs.readv(fd, [(0, 4)]) == [b"ZZ34"]
    assert fs.readv(fd, [(0, 4)]) == [b"ZZ34"]


def test_plan_cache_bypassed_for_pending_write_behind_extents(tmp_path):
    c = make_cluster(tmp_path, "pcwb", write_behind=True)
    fs = c.client()
    write_file(fs, "/f", b"base" * 64)
    fd = fs.open("/f", "a")
    with fs.transaction():
        fs.pwrite(fd, b"WXYZ", 0)
        # read-your-buffered-writes, straight from buffer memory
        assert fs.readv(fd, [(0, 8)]) == [b"WXYZbase"[:8]]
    assert fs.readv(fd, [(0, 8)]) == [b"WXYZbase"[:8]]
    c.close()


def test_yankv_plans_share_the_cache(fs):
    write_file(fs, "/f", b"q" * 4096)
    fd = fs.open("/f")
    plans1 = fs.yankv(fd, [(0, 1024), (2048, 512)])
    misses = fs.stats.plan_cache_misses
    plans2 = fs.yankv(fd, [(0, 1024), (2048, 512)])
    assert plans1 == plans2
    assert fs.stats.plan_cache_misses == misses
    assert fs.stats.plan_cache_hits >= 1


# ------------------------------------------------------- adaptive thresholds
def test_adaptive_thresholds_move_with_observed_cost():
    rt = IoRuntime(max_workers=1)
    assert rt.gap_bytes() == ADAPTIVE_SEED        # no observations yet
    for _ in range(50):                            # 5 ms rounds, 100 MB/s
        rt.observe_round(0, 0.005, 100)
        rt.observe_round(0, 0.01, 1 << 20)
    est = rt.gap_bytes()
    assert est == rt.coalesce_bytes()
    assert ADAPTIVE_FLOOR <= est <= ADAPTIVE_CEILING
    assert est != ADAPTIVE_SEED                    # the model moved
    # A much cheaper round trip shrinks the worthwhile gap.
    rt2 = IoRuntime(max_workers=1)
    for _ in range(50):
        rt2.observe_round(0, 1e-6, 100)
        rt2.observe_round(0, 0.01, 1 << 20)
    assert rt2.gap_bytes() < est
    rt.close()
    rt2.close()


def test_pinned_knobs_disable_adaptation(tmp_path):
    c = make_cluster(tmp_path, "pin", fetch_gap_bytes=12345,
                     store_coalesce_bytes=54321)
    assert c.scheduler.max_gap == 12345
    assert c.wsched.max_coalesce == 54321
    snap = c.runtime.snapshot()
    assert snap["gap_pinned"] and snap["coalesce_pinned"]
    fs = c.client()
    write_file(fs, "/f", b"d" * (64 << 10))
    with fs.open_file("/f") as f:
        f.readv([(0, 1024), (32 << 10, 1024)])
    assert c.scheduler.max_gap == 12345            # observations ignored
    c.close()


def test_cluster_knob_validation(tmp_path):
    cases = [
        dict(replication=0),
        dict(replication=4, n_servers=3),
        dict(fetch_gap_bytes=0),
        dict(fetch_gap_bytes=-5),
        dict(store_coalesce_bytes=0),
        dict(store_coalesce_bytes=-1),
        dict(fetch_workers=0),
        dict(region_size=0),
        dict(n_servers=0),
    ]
    for i, kw in enumerate(cases):
        with pytest.raises(ValueError):
            Cluster(data_dir=str(tmp_path / f"bad{i}"), **kw)
    # replication == n_servers is legal (distinct servers still exist)
    c = Cluster(n_servers=2, replication=2, data_dir=str(tmp_path / "ok"))
    c.close()


# --------------------------------------------------------------- stats races
N_THREADS = 6
OPS_PER_THREAD = 25
CHUNK = 512


def test_storage_stats_exact_under_concurrent_clients(tmp_path):
    """N clients hammer the same servers from N threads; the per-server
    counters must come out exact (the bare-+= lost-update race)."""
    c = make_cluster(tmp_path, "race", n_servers=2)
    clients = [c.client() for _ in range(N_THREADS)]
    handles = [fs.open_file(f"/f{i}", "w")
               for i, fs in enumerate(clients)]
    c.reset_io_stats()                     # creation dirents not counted
    chunk = b"xyz" * (CHUNK // 3)
    errors = []

    def work(i):
        try:
            for _ in range(OPS_PER_THREAD):
                handles[i].writev([chunk])
        except Exception as e:             # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for h in handles:
        h.close()
    expected = N_THREADS * OPS_PER_THREAD * len(chunk)
    written = sum(s.stats.snapshot()["bytes_written"]
                  for s in c.servers.values())
    slices = sum(s.stats.snapshot()["slices_written"]
                 for s in c.servers.values())
    assert written == expected
    assert slices == N_THREADS * OPS_PER_THREAD
    total_logical = sum(cl.stats.logical_bytes_written for cl in clients)
    assert total_logical == expected
    c.close()


def test_client_stats_exact_under_concurrent_async_ops(tmp_path):
    """One client's counters mutated from pool workers and the app thread
    concurrently must total exactly (satellite: the += race)."""
    c = make_cluster(tmp_path, "as", n_servers=3)
    fs = c.client()
    fs.time_fn = lambda: 0          # stable mtimes → conflict-free commutes
    n = 32
    write_file(fs, "/r", b"R" * (n * CHUNK))
    with fs.open_file("/w", "w") as fw, fs.open_file("/r") as fr:
        # Pre-grow /w so its inode (max_region) is stable: every async op
        # then commits conflict-free commutes only — zero KV retries, so
        # the counter totals below are exact, not lower bounds.
        fw.pwrite(b"\0", 0)
        base = fs.stats.snapshot()
        wfuts = [fw.pwritev_async([bytes([i % 251]) * CHUNK], i * CHUNK)
                 for i in range(n)]
        rfuts = [fr.readv_async([(i * CHUNK, CHUNK)]) for i in range(n)]
        # app thread keeps mutating the same stats while workers run
        for i in range(n):
            assert fr.readv([(i * CHUNK, CHUNK)])[0] == b"R" * CHUNK
        assert all(f.result() == CHUNK for f in wfuts)
        assert all(f.result() == [b"R" * CHUNK] for f in rfuts)
    s = fs.stats.snapshot()
    assert fs.stats.txn_retries == base["txn_retries"]
    assert s["async_ops"] - base["async_ops"] == 2 * n
    assert s["logical_bytes_read"] - base["logical_bytes_read"] \
        == 2 * n * CHUNK
    assert s["logical_bytes_written"] - base["logical_bytes_written"] \
        == n * CHUNK
    assert s["data_bytes_written"] - base["data_bytes_written"] \
        == n * CHUNK
    assert s["vectored_ops"] - base["vectored_ops"] == 3 * n
    with fs.open_file("/w") as f:
        got = f.read()
    assert got == b"".join(bytes([i % 251]) * CHUNK for i in range(n))
    c.close()


# ----------------------------------------------------------- blocked waits
def test_blocked_wait_accounting(fs):
    write_file(fs, "/f", b"k" * 4096)
    with fs.open_file("/f") as f:
        before = fs.stats.blocked_waits
        f.readv([(0, 128)])                # sync fetch = one blocked wait
        assert fs.stats.blocked_waits == before + 1
        fut = f.readv_async([(0, 128)])
        while not fut.done():
            time.sleep(0.001)
        fut.result()                       # already done: no blocked wait
        assert fs.stats.blocked_waits == before + 1
