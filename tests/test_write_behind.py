"""Transaction-scoped write-behind buffer (``wbuf``).

Covers the tentpole guarantees:

  * batching: many small write ops under one transaction flush through the
    write scheduler as ONE planning pass — strictly fewer store rounds than
    the same ops with the buffer off, with cross-op coalescing measured in
    ``ClientStats.slices_cross_op_coalesced`` / ``writeback_flushes``;
  * read-your-buffered-writes: reads (and yanks, directory listings, EOF
    arithmetic) inside the transaction observe buffered writes via the
    pending-extent overlay, before any store was dispatched;
  * abort: discarding the buffer leaves ZERO storage-server garbage — no
    store round was ever issued;
  * durability order: a storage failure mid-flush fails the commit and
    nothing becomes visible (slices-before-metadata, §2.1);
  * replay: a KV-level abort after the flush replays the op log against the
    recorded (resolved) batch pointers — data is never stored twice (§2.6);
  * opt-in surfaces: ``Cluster(write_behind=True)`` and
    ``WtfFile(buffered=True)``.
"""
import pytest

from repro.core import (Cluster, NotOpenForWriting, StorageError,
                        TransactionAborted, WtfError)
from repro.core.testing import make_flaky_kv, make_flaky_server

REGION = 64 * 1024


def make_cluster(tmp_path, tag, write_behind, n_servers=3, replication=1):
    return Cluster(n_servers=n_servers, data_dir=str(tmp_path / tag),
                   replication=replication, region_size=REGION,
                   write_behind=write_behind)


def read_file(fs, path):
    with fs.open_file(path) as f:
        return f.read()


def server_slices_written(cluster):
    return sum(s.stats.slices_written for s in cluster.servers.values())


def small_ops_txn(fs, path, n_ops=24, size=128):
    """N small pwrites under one transaction; returns the expected bytes."""
    fd = fs.open(path, "w")
    with fs.transaction():
        off = 0
        for i in range(n_ops):
            fs.pwrite(fd, bytes([i % 251]) * size, off)
            off += size
    fs.close(fd)
    return b"".join(bytes([i % 251]) * size for i in range(n_ops))


# ------------------------------------------------------------------ batching
def test_txn_of_small_writes_flushes_once_with_fewer_rounds(tmp_path):
    runs = {}
    for wb in (True, False):
        cluster = make_cluster(tmp_path, f"wb{wb}", wb)
        fs = cluster.client()
        expect = small_ops_txn(fs, "/log")
        assert read_file(fs, "/log") == expect
        runs[wb] = fs.stats
        cluster.close()
    on, off = runs[True], runs[False]
    assert on.store_batches < off.store_batches, \
        "write-behind must issue strictly fewer store rounds"
    assert on.writeback_flushes >= 1
    assert on.slices_cross_op_coalesced > 0, \
        "small cross-op chunks in one region must coalesce"
    assert off.writeback_flushes == 0
    assert on.logical_bytes_written == off.logical_bytes_written


def test_cross_region_buffered_writes_fan_out_but_batch(tmp_path):
    """Buffered ops spanning several regions: one flush, one round per
    region placement group, contents exact."""
    cluster = make_cluster(tmp_path, "span", True)
    fs = cluster.client()
    fd = fs.open("/wide", "w")
    payload = {}
    flushes0 = fs.stats.writeback_flushes
    with fs.transaction():
        for r in range(3):                    # one small write per region
            data = bytes([r + 1]) * 512
            fs.pwrite(fd, data, r * REGION)
            payload[r] = data
    for r, data in payload.items():
        assert fs.pread(fd, 512, r * REGION) == data
    assert fs.stats.writeback_flushes == flushes0 + 1
    fs.close(fd)
    cluster.close()


def test_buffered_handle_opt_in_without_cluster_knob(tmp_path):
    """``open_file(..., buffered=True)`` defers stores even when the
    cluster-level knob is off; an unbuffered sibling on the same client
    still stores eagerly."""
    cluster = make_cluster(tmp_path, "handle", False)
    fs = cluster.client()
    with fs.open_file("/buf", "w", buffered=True) as f:
        assert "buffered" in repr(f)
        with fs.transaction():
            for i in range(8):
                f.pwrite(b"%d" % i * 64, i * 64)
        flushes = fs.stats.writeback_flushes
        assert flushes == 1
    assert read_file(fs, "/buf")[:64] == b"0" * 64
    # unbuffered handle on the same client: no new flushes
    with fs.open_file("/plain", "w") as f:
        f.write(b"eager")
    assert fs.stats.writeback_flushes == flushes
    assert read_file(fs, "/plain") == b"eager"
    cluster.close()


# ------------------------------------------- read-your-buffered-writes (RYW)
def test_reads_inside_txn_observe_buffered_writes(tmp_path):
    cluster = make_cluster(tmp_path, "ryw", True)
    fs = cluster.client()
    fd = fs.open("/f", "w")
    with fs.transaction():
        fs.pwrite(fd, b"A" * 100, 0)
        fs.pwrite(fd, b"B" * 100, 100)
        # scalar + vectored reads see the buffer before any store happened
        assert fs.pread(fd, 200, 0) == b"A" * 100 + b"B" * 100
        assert fs.readv(fd, [(50, 100)]) == [b"A" * 50 + b"B" * 50]
        # EOF arithmetic sees buffered length
        assert fs.stat("/f")["size"] == 200
        # overwrite inside the txn: later buffered layer wins
        fs.pwrite(fd, b"C" * 50, 75)
        assert fs.pread(fd, 200, 0) == b"A" * 75 + b"C" * 50 + b"B" * 75
    assert read_file(fs, "/f") == b"A" * 75 + b"C" * 50 + b"B" * 75
    fs.close(fd)
    cluster.close()


def test_dir_entries_and_appends_observe_buffer(tmp_path):
    """Directory machinery runs on the same buffered append path: files
    created inside the transaction are listable inside it."""
    cluster = make_cluster(tmp_path, "dir", True)
    fs = cluster.client()
    with fs.transaction():
        fs.mkdir("/d")
        fd = fs.open("/d/x", "w")
        fs.write(fd, b"payload")
        fs.close(fd)
        assert fs.listdir("/d") == ["x"]
        a = fs.open("/d/x", "a")          # append lands at buffered EOF
        fs.append(a, b"-tail")
        fs.close(a)
    assert read_file(fs, "/d/x") == b"payload-tail"
    cluster.close()


def test_yank_paste_of_buffered_data_within_txn(tmp_path):
    cluster = make_cluster(tmp_path, "yank", True)
    fs = cluster.client()
    fd = fs.open("/y", "w")
    with fs.transaction():
        fs.pwrite(fd, b"0123456789" * 10, 0)
        fs.seek(fd, 20)
        exts = fs.yank(fd, 30)            # pending pointers
        fs.seek(fd, 100)
        fs.paste(fd, exts)                # pasted back while still pending
        assert fs.pread(fd, 30, 100) == (b"0123456789" * 10)[20:50]
    assert read_file(fs, "/y")[100:130] == (b"0123456789" * 10)[20:50]
    fs.close(fd)
    cluster.close()


def test_yanked_pending_extents_resolve_after_commit(tmp_path):
    """Extents yanked inside a buffered txn resolve to real pointers at the
    flush; pasting them in a LATER transaction is pure metadata."""
    cluster = make_cluster(tmp_path, "resolve", True)
    fs = cluster.client()
    fd = fs.open("/src", "w")
    with fs.transaction():
        fs.write(fd, b"precious" * 8)
        fs.seek(fd, 0)
        exts = fs.yank(fd, 64)
    dst = fs.open("/dst", "w")
    writes_before = sum(s.stats.bytes_written
                        for s in cluster.servers.values())
    fs.paste(dst, exts)                   # resolved now: metadata only
    assert sum(s.stats.bytes_written
               for s in cluster.servers.values()) == writes_before
    assert read_file(fs, "/dst") == b"precious" * 8
    fs.close(fd); fs.close(dst)
    cluster.close()


def test_pasting_discarded_pending_extents_rejected(tmp_path):
    """Pending extents from an ABORTED buffer are dead: pasting them later
    must raise instead of committing dangling pointers."""
    cluster = make_cluster(tmp_path, "dead", True)
    fs = cluster.client()
    fd = fs.open("/src", "w")
    with fs.transaction() as t:
        fs.write(fd, b"doomed data!")
        fs.seek(fd, 0)
        exts = fs.yank(fd, 12)
        t.abort()
    dst = fs.open("/dst2", "w")
    with pytest.raises(WtfError):
        fs.paste(dst, exts)
    # ...and a LIVE buffer must not launder them either: the paste fails
    # immediately, and the surrounding transaction's own writes survive.
    with fs.transaction():
        fs.pwrite(dst, b"legit", 0)
        with pytest.raises(WtfError):
            fs.paste(dst, exts)
    assert read_file(fs, "/dst2") == b"legit"
    cluster.close()


# ------------------------------------------------------------- abort / crash
def test_abort_discards_buffer_and_leaves_no_garbage(tmp_path):
    cluster = make_cluster(tmp_path, "abort", True)
    fs = cluster.client()
    fd = fs.open("/keep", "w")
    fs.write(fd, b"committed")
    written_before = server_slices_written(cluster)
    with fs.transaction() as t:
        fs.pwrite(fd, b"X" * 1000, 0)
        fs.pwrite(fd, b"Y" * 1000, 1000)
        assert fs.pread(fd, 4, 0) == b"XXXX"
        t.abort()
    assert not fs._wb.pending
    assert server_slices_written(cluster) == written_before, \
        "an aborted write-behind txn must never have stored a slice"
    assert read_file(fs, "/keep") == b"committed"
    fs.close(fd)
    cluster.close()


def test_mid_flush_storage_failure_leaves_nothing_visible(tmp_path):
    """Every replica candidate refuses the flush round: the commit fails
    with ``StorageError`` and neither file contents nor namespace changes
    are observable (slices-before-metadata, §2.1)."""
    cluster = make_cluster(tmp_path, "crash", True, n_servers=2)
    fs = cluster.client()
    fd = fs.open("/victim", "w")
    fs.write(fd, b"old-contents")
    for sid in list(cluster.servers):
        make_flaky_server(cluster, sid, fail_on={"create_slices": {1},
                                                 "create_slice": {1}})
    with pytest.raises(StorageError):
        with fs.transaction():
            fs.pwrite(fd, b"NEW" * 100, 0)
            fs.open(fd2 := "/brand-new", "w")
    reader = cluster.client()
    assert read_file(reader, "/victim") == b"old-contents"
    assert not reader.exists(fd2)
    assert not fs._wb.pending
    cluster.close()


def test_partial_flush_then_failure_still_invisible(tmp_path):
    """Some placement groups store before another group exhausts its
    candidates: the commit still fails wholesale and no partial state is
    visible — stored slices are unreferenced garbage for the GC."""
    cluster = make_cluster(tmp_path, "partial", True, n_servers=2)
    fs = cluster.client()
    fd = fs.open("/span", "w")
    fs.write(fd, b"base")
    # Server 0 accepts exactly ONE store round, server 1 none: with three
    # placement groups (three regions) at most one group lands and at
    # least two exhaust every candidate — the flush must raise after a
    # partial store.
    everything = set(range(1, 32))
    make_flaky_server(cluster, 0, fail_on={"create_slices": everything - {1},
                                           "create_slice": everything - {1}})
    make_flaky_server(cluster, 1, fail_on={"create_slices": everything,
                                           "create_slice": everything})
    with pytest.raises(StorageError):
        with fs.transaction():
            fs.pwrite(fd, b"R0" * 64, 0)
            fs.pwrite(fd, b"R1" * 64, REGION)
            fs.pwrite(fd, b"R2" * 64, 2 * REGION)
    reader = cluster.client()
    assert read_file(reader, "/span") == b"base"
    assert reader.stat("/span")["size"] == 4
    cluster.close()


# ------------------------------------------------------------------- replay
def test_replay_reuses_recorded_batch_pointers(tmp_path):
    """KV abort after the flush: the §2.6 replay reuses the resolved batch
    pointers — identical contents, no second store of any byte."""
    results = {}
    for inject in (False, True):
        cluster = make_cluster(tmp_path, f"replay{inject}", True)
        if inject:
            flaky = make_flaky_kv(cluster, fail_commits={2})
        fs = cluster.client()
        fd = fs.open("/r", "w")           # auto-commit: KV commit #1
        with fs.transaction():            # txn commit: KV commit #2
            off = 0
            for i in range(12):
                fs.pwrite(fd, bytes([i + 1]) * 200, off)
                off += 200
        results[inject] = {
            "data": read_file(fs, "/r"),
            "slices": server_slices_written(cluster),
            "bytes": sum(s.stats.bytes_written
                         for s in cluster.servers.values()),
            "flushes": fs.stats.writeback_flushes,
            "retries": fs.stats.txn_retries,
        }
        if inject:
            assert flaky.injected == 1
        fs.close(fd)
        cluster.close()
    clean, replayed = results[False], results[True]
    assert replayed["data"] == clean["data"]
    assert replayed["retries"] == clean["retries"] + 1
    # one flush for the auto-commit open, one for the txn — and the replay
    # added NO extra flush (artifacts were already resolved)
    assert replayed["flushes"] == clean["flushes"] == 2
    assert replayed["slices"] == clean["slices"], \
        "replay must reuse the recorded pointers, not re-store"
    assert replayed["bytes"] == clean["bytes"]


def test_auto_commit_write_behind_roundtrip(tmp_path):
    """With the cluster knob on, plain auto-commit ops buffer and flush at
    their own commit — semantics identical to eager stores."""
    cluster = make_cluster(tmp_path, "auto", True)
    fs = cluster.client()
    fd = fs.open("/a", "w")
    fs.write(fd, b"hello ")
    fs.write(fd, b"world")
    assert read_file(fs, "/a") == b"hello world"
    assert fs.stats.writeback_flushes >= 2
    fs.close(fd)
    # enforcement still applies under buffering
    rd = fs.open("/a", "r")
    with pytest.raises(NotOpenForWriting):
        fs.write(rd, b"nope")
    cluster.close()
