"""WTF transactions + the §2.6 retry layer.

Key behaviours under test:
  * multi-file atomicity (all-or-nothing visibility),
  * KV-level aborts are replayed transparently (the paper's seek-END+write
    example commits even when a concurrent write moved the end of file),
  * replays that change an application-visible outcome abort to the app,
  * concurrent appends never conflict (§2.5),
  * the op log holds slice pointers: a replayed 100 MB write re-uses its
    slices instead of rewriting them.
"""
import threading

import pytest

from repro.core import (Cluster, SEEK_END, SEEK_SET, TransactionAborted)


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=64 * 1024)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def make_file(fs, path, payload=b""):
    fd = fs.open(path, "w")
    if payload:
        fs.write(fd, payload)
    fs.close(fd)


def read_file(fs, path):
    fd = fs.open(path, "r")
    data = fs.read(fd)
    fs.close(fd)
    return data


def test_multi_file_atomic_visibility(cluster, fs):
    make_file(fs, "/acct_a", b"100")
    make_file(fs, "/acct_b", b"000")
    other = cluster.client()

    with fs.transaction():
        fa = fs.open("/acct_a", "rw")
        fb = fs.open("/acct_b", "rw")
        fs.pwrite(fa, b"050", 0)
        # mid-transaction: another client must still see the old values
        assert read_file(other, "/acct_a") == b"100"
        fs.pwrite(fb, b"050", 0)
    assert read_file(other, "/acct_a") == b"050"
    assert read_file(other, "/acct_b") == b"050"


def test_abort_on_exception_rolls_back(cluster, fs):
    make_file(fs, "/keep", b"before")
    with pytest.raises(RuntimeError):
        with fs.transaction():
            fd = fs.open("/keep", "rw")
            fs.pwrite(fd, b"after!", 0)
            raise RuntimeError("boom")
    assert read_file(fs, "/keep") == b"before"


def test_seek_end_write_retries_transparently(cluster, fs):
    """The paper's flagship example: seek(END)+write('Hello World') must
    commit even though a concurrent writer changed the file length between
    our seek and our commit (§2.6)."""
    make_file(fs, "/f", b"0123456789")
    other = cluster.client()

    with fs.transaction():
        fd = fs.open("/f", "rw")
        fs.seek(fd, 0, SEEK_END)
        # concurrent append changes the end of file before we commit
        ofd = other.open("/f", "rw")
        other.seek(ofd, 0, SEEK_END)
        other.write(ofd, b"_intruder_")
        other.close(ofd)
        fs.write(fd, b"Hello World")
    data = read_file(fs, "/f")
    assert data == b"0123456789_intruder_Hello World"
    assert fs.stats.txn_retries >= 1


def test_replay_reuses_slices_not_data(cluster, fs):
    """§2.6: the log maintains slice pointers, not data — a retried write
    must NOT rewrite its payload to the storage servers."""
    make_file(fs, "/f", b"base")
    other = cluster.client()
    payload = b"P" * 10_000

    def srv_writes():
        return sum(s.stats.bytes_written for s in cluster.servers.values())

    with fs.transaction():
        fd = fs.open("/f", "rw")
        fs.seek(fd, 0, SEEK_END)
        written_after_op = None
        fs.write(fd, payload)
        written_after_op = srv_writes()
        # force a conflict → commit will replay
        ofd = other.open("/f", "rw")
        other.seek(ofd, 0, SEEK_END)
        other.write(ofd, b"x")
        other.close(ofd)
    assert fs.stats.txn_retries >= 1
    # replay re-pointed the same slice: at most the intruder's 1 byte extra
    assert srv_writes() - written_after_op <= 1
    assert read_file(fs, "/f") == b"base" + b"x" + payload


def test_app_visible_conflict_aborts(cluster, fs):
    """If a replayed READ returns different bytes, the conflict is
    application-visible and the transaction must abort (§2.6)."""
    make_file(fs, "/f", b"AAAA")
    other = cluster.client()

    with pytest.raises(TransactionAborted):
        with fs.transaction():
            fd = fs.open("/f", "rw")
            data = fs.read(fd, 4)          # app sees 'AAAA'
            # concurrent writer changes what that read returns
            ofd = other.open("/f", "rw")
            other.pwrite(ofd, b"BBBB", 0)
            other.close(ofd)
            fs.pwrite(fd, data[::-1], 0)   # decision based on the read
    assert read_file(fs, "/f") == b"BBBB"  # our txn left no trace


def test_injected_kv_abort_is_invisible(cluster, fs):
    """Spurious KV-level aborts (not app-visible) replay and commit."""
    make_file(fs, "/f", b"stable")
    cluster.kv.inject_aborts(2)
    with fs.transaction():
        fd = fs.open("/f", "rw")
        fs.pwrite(fd, b"STABLE", 0)
    assert read_file(fs, "/f") == b"STABLE"
    assert fs.stats.txn_retries >= 2


def test_transactional_concat_with_writes(cluster, fs):
    make_file(fs, "/p1", b"part-one;")
    make_file(fs, "/p2", b"part-two;")
    other = cluster.client()
    with fs.transaction():
        fs.concat(["/p1", "/p2"], "/joined")
        fd = fs.open("/joined", "rw")
        fs.seek(fd, 0, SEEK_END)
        fs.write(fd, b"tail")
        assert not other.exists("/joined")
    assert read_file(fs, "/joined") == b"part-one;part-two;tail"


def test_concurrent_appends_all_commit(cluster):
    """§2.5: relative appends commute — N threads append M records each and
    every record lands exactly once.  No appends may be lost or duplicated."""
    setup = cluster.client()
    make_file(setup, "/log", b"")
    N, M = 8, 30

    def worker(i):
        c = cluster.client()
        fd = c.open("/log", "rw")
        for j in range(M):
            rec = f"{i:02d}:{j:03d};".encode()
            c.append(fd, rec)
        c.close(fd)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()

    data = read_file(setup, "/log")
    records = [r for r in data.decode().split(";") if r]
    assert len(records) == N * M
    assert len(set(records)) == N * M


def test_concurrent_append_fast_path_mostly_conflict_free(cluster):
    """Within one region, concurrent appends proceed in parallel in the
    common case (§2.5): the region list itself carries no read dependency.
    The only permissible internal retries come from the *inode* read racing
    the very first append (max_region -1 → 0) — a one-time event, so aborts
    must stay far below the number of appends (and are never app-visible)."""
    setup = cluster.client()
    fd0 = setup.open("/fastlog", "w")
    setup.write(fd0, b"!")            # force max_region to 0 up front
    setup.close(fd0)
    aborts_before = cluster.kv.stats.aborts
    N, M = 4, 20

    def worker(i):
        c = cluster.client()
        fd = c.open("/fastlog", "rw")
        for j in range(M):
            c.append(fd, b"r" * 16)
        c.close(fd)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()
    # mtime-second rollover can cause at most a handful of inode races
    assert cluster.kv.stats.aborts - aborts_before <= N
    assert setup.stat("/fastlog")["size"] == 1 + N * M * 16


@pytest.mark.parametrize("batching", [True, False],
                         ids=["wsched-on", "wsched-off"])
def test_overlapping_pwritev_batches_serialize_all_or_nothing(tmp_path,
                                                              batching):
    """Two clients hammer the SAME multi-region range with opposing
    ``pwritev`` batches while a third reads it: every observation must be
    uniformly one writer's batch (or the initial zeros), never a mix — a
    vectored batch commits all-or-nothing whether or not the write
    scheduler is on."""
    c = Cluster(n_servers=4, data_dir=str(tmp_path / f"b{batching}"),
                replication=1, region_size=4096, store_batching=batching)
    setup = c.client()
    span = 3 * 4096                       # forces cross-region store fan-out
    make_file(setup, "/race", b"\x00" * span)
    rounds, errors = 12, []

    def writer(tag: bytes):
        try:
            cl = c.client()
            fd = cl.open("/race", "rw")
            chunks = [tag * 4096] * 3
            for _ in range(rounds):
                cl.pwritev(fd, chunks, 0)
            cl.close(fd)
        except Exception as e:            # noqa: BLE001 - surfaced below
            errors.append(e)

    stop = threading.Event()

    def reader():
        try:
            cl = c.client()
            fd = cl.open("/race", "r")
            while not stop.is_set():
                try:
                    [data] = cl.readv(fd, [(0, span)])
                except TransactionAborted:
                    continue     # starved by writer churn: observed nothing
                seen = set(data)
                assert len(seen) <= 1, \
                    f"torn batch visible: byte values {sorted(seen)}"
            cl.close(fd)
        except Exception as e:            # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(b"A",)),
               threading.Thread(target=writer, args=(b"B",)),
               threading.Thread(target=reader)]
    for t in threads:
        t.start()
    threads[0].join(); threads[1].join()
    stop.set()
    threads[2].join()
    assert not errors, errors
    final = read_file(setup, "/race")
    assert final in (b"A" * span, b"B" * span), \
        "the last committed batch must win wholesale"
    c.close()


def test_fd_state_restored_after_failed_txn(cluster, fs):
    make_file(fs, "/f", b"0123456789")
    fd0 = fs.open("/f", "rw")
    fs.seek(fd0, 4)
    other = cluster.client()
    with pytest.raises(TransactionAborted):
        with fs.transaction():
            data = fs.read(fd0, 2)     # offset moves to 6 inside the txn
            ofd = other.open("/f", "rw")
            other.pwrite(ofd, b"XX", 4)
            other.close(ofd)
            fs.pwrite(fd0, data, 8)
    assert fs.tell(fd0) == 4, "fd offset must roll back with the txn"


def test_truncate_after_write_in_same_txn(cluster, fs):
    """Truncate composes with the txn's own queued writes in queue order:
    writes BEFORE the truncate are wiped, writes AFTER survive — a raw
    region delete used to resurrect the earlier writes at commit."""
    make_file(fs, "/t1", b"persisted")
    fd = fs.open("/t1", "rw")
    with fs.transaction():
        fs.pwrite(fd, b"X" * 100, 0)
        fs.truncate(fd, 0)
        assert fs.stat("/t1")["size"] == 0
    assert fs.stat("/t1")["size"] == 0
    assert read_file(fs, "/t1") == b""

    make_file(fs, "/t2", b"persisted")
    fd2 = fs.open("/t2", "rw")
    with fs.transaction():
        fs.pwrite(fd2, b"wiped out!", 0)
        fs.truncate(fd2, 0)
        fs.pwrite(fd2, b"kept", 0)
        assert fs.stat("/t2")["size"] == 4
    assert read_file(fs, "/t2") == b"kept"


def test_open_w_truncates_same_txn_writes(cluster, fs):
    """open(path, 'w') truncate semantics inside a transaction must also
    wipe regions grown by the SAME transaction's earlier writes."""
    make_file(fs, "/t3", b"persisted")
    with fs.transaction():
        fd = fs.open("/t3", "rw")
        fs.pwrite(fd, b"A" * 70_000, 0)    # grows past region 0 (64 KiB)
        fs.close(fd)
        fd = fs.open("/t3", "w")           # truncate semantics
        fs.write(fd, b"fresh")
        fs.close(fd)
    assert fs.stat("/t3")["size"] == 5
    assert read_file(fs, "/t3") == b"fresh"


# ------------------------------------------------- O_APPEND write routing
def test_write_on_append_fds_across_clients_loses_nothing(cluster):
    """Regression: plain ``write`` on an ``"a"``-mode fd used to be a
    positional write at the EOF the fd cached at open — concurrent clients
    opened at the same EOF and silently overwrote each other (bytes lost,
    zero conflicts).  O_APPEND writes must land at the CURRENT end of file
    atomically: every record survives exactly once."""
    setup = cluster.client()
    make_file(setup, "/alog", b"")
    N, M = 6, 25

    def worker(i):
        c = cluster.client()
        fd = c.open("/alog", "a")
        for j in range(M):
            c.write(fd, f"{i:02d}:{j:03d};".encode())
        c.close(fd)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()

    data = read_file(setup, "/alog")
    assert len(data) == N * M * 7, "appended bytes were lost"
    records = [r for r in data.decode().split(";") if r]
    assert len(set(records)) == N * M, "records overwrote each other"


def test_append_fd_write_ignores_seek(cluster, fs):
    """POSIX O_APPEND: the fd offset is advisory — a seek must not turn
    the next write into an overwrite at that offset."""
    make_file(fs, "/seeklog", b"0123456789")
    fd = fs.open("/seeklog", "a")
    fs.seek(fd, 0, SEEK_SET)
    fs.write(fd, b"TAIL")
    fs.close(fd)
    assert read_file(fs, "/seeklog") == b"0123456789TAIL"


def test_writev_on_append_fd_lands_at_eof(cluster, fs):
    """Gather-writes on an O_APPEND fd append the whole batch contiguously
    at the current EOF, concurrent-writer-safe like scalar ``write``."""
    make_file(fs, "/vlog", b"head;")
    fd = fs.open("/vlog", "a")
    fs.seek(fd, 0, SEEK_SET)              # advisory; must not matter
    n = fs.writev(fd, [b"one;", b"two;", b"three;"])
    fs.close(fd)
    assert n == 14
    assert read_file(fs, "/vlog") == b"head;one;two;three;"


def test_appends_racing_truncate_never_tear_records(cluster):
    """Truncate is a structural inode change, so it SERIALIZES against
    appends (§2.5's zero-conflict promise is append-vs-append only).  Under
    a truncate storm the file must always be a clean record boundary: every
    surviving byte belongs to a whole record, nothing is ever torn or
    resurrected."""
    setup = cluster.client()
    make_file(setup, "/trunclog", b"")
    stop = threading.Event()
    N, M = 3, 30

    def appender(i):
        c = cluster.client()
        fd = c.open("/trunclog", "a")
        for j in range(M):
            c.write(fd, f"[{i}:{j:04d}]".encode())   # 8-byte records
        c.close(fd)

    def truncator():
        c = cluster.client()
        fd = c.open("/trunclog", "rw")
        while not stop.is_set():
            c.truncate(fd, 0)
        c.close(fd)

    threads = [threading.Thread(target=appender, args=(i,))
               for i in range(N)]
    tt = threading.Thread(target=truncator)
    tt.start()
    for t in threads: t.start()
    for t in threads: t.join()
    stop.set()
    tt.join()

    data = read_file(setup, "/trunclog")
    assert len(data) % 8 == 0, f"torn record: {data[-16:]!r}"
    recs = [data[k:k + 8] for k in range(0, len(data), 8)]
    assert len(set(recs)) == len(recs), "a truncated record was resurrected"
    for r in recs:
        assert r[:1] == b"[" and r[7:] == b"]", f"corrupt record {r!r}"


def test_replayed_append_reuses_recorded_pointers(cluster, fs):
    """§2.6 for the append path: a replayed append must paste the slice
    pointers its first attempt recorded, not re-store the payload."""
    make_file(fs, "/replaylog", b"!")
    payload = b"R" * 20_000

    def srv_writes():
        return sum(s.stats.bytes_written for s in cluster.servers.values())

    fd = fs.open("/replaylog", "a")
    before = srv_writes()
    cluster.kv.inject_aborts(2)
    fs.write(fd, payload)                 # auto-commit; replays internally
    fs.close(fd)
    assert fs.stats.txn_retries >= 2
    assert srv_writes() - before == len(payload), \
        "replay re-stored the payload instead of reusing its pointers"
    assert read_file(fs, "/replaylog") == b"!" + payload
