"""Unit + property tests for the slicing algebra (paper §2.1, Figure 2)."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.slicing import (Extent, SlicePointer, compact,
                                decode_extents, encode_extents,
                                merge_adjacent, overlay, slice_range,
                                split_by_regions, visible_length)


def ptr(server=0, f="b0", off=0, ln=1):
    return SlicePointer(server, f, off, ln)


def ext(offset, length, disk_off=None, server=0, f="b0"):
    if disk_off is None:
        disk_off = offset
    return Extent(offset, length, (ptr(server, f, disk_off, length),))


# ---------------------------------------------------------------- figure 2
def test_figure2_compaction():
    """The exact example from the paper: A@[0,2] B@[2,4] C@[1,3] D@[2,3]
    E@[2,3] compacts to A@[0,1] C@[1,2] E@[2,3] B@[3,4]."""
    MB = 1 << 20
    A = Extent(0 * MB, 2 * MB, (ptr(0, "fa", 0, 2 * MB),))
    B = Extent(2 * MB, 2 * MB, (ptr(1, "fb", 0, 2 * MB),))
    C = Extent(1 * MB, 2 * MB, (ptr(2, "fc", 0, 2 * MB),))
    D = Extent(2 * MB, 1 * MB, (ptr(3, "fd", 0, 1 * MB),))
    E = Extent(2 * MB, 1 * MB, (ptr(4, "fe", 0, 1 * MB),))
    out = compact([A, B, C, D, E])
    spans = [(e.offset // MB, e.end // MB, e.ptrs[0].server_id) for e in out]
    assert spans == [(0, 1, 0), (1, 2, 2), (2, 3, 4), (3, 4, 1)]
    # the C fragment must be sub-ranged: C covers [1,3) but only [1,2) shows
    c_frag = out[1]
    assert c_frag.ptrs[0].offset == 0 and c_frag.ptrs[0].length == MB


def test_subptr_arithmetic():
    p = ptr(0, "f", 100, 50)
    s = p.sub(10, 20)
    assert (s.offset, s.length) == (110, 20)
    with pytest.raises(ValueError):
        p.sub(40, 20)


def test_merge_adjacent_on_disk():
    a = ext(0, 10, disk_off=0)
    b = ext(10, 5, disk_off=10)
    merged = merge_adjacent([a, b])
    assert len(merged) == 1
    assert merged[0].length == 15
    assert merged[0].ptrs[0].length == 15


def test_no_merge_when_disk_discontiguous():
    a = ext(0, 10, disk_off=0)
    b = ext(10, 5, disk_off=100)
    assert len(merge_adjacent([a, b])) == 2


def test_zero_extent_obscures():
    a = ext(0, 10)
    z = Extent(2, 5, ())           # punch
    out = compact([a, z])
    assert [(e.offset, e.length, e.is_zero) for e in out] == [
        (0, 2, False), (2, 5, True), (7, 3, False)]


def test_slice_range_with_holes():
    a = ext(10, 10)
    tiles = slice_range([a], 5, 20)
    assert [(t.offset, t.length, t.is_zero) for t in tiles] == [
        (5, 5, True), (10, 10, False), (20, 5, True)]


def test_split_by_regions():
    pieces = list(split_by_regions(100, 250, 128))
    assert pieces == [(0, 100, 0, 28), (1, 0, 28, 128), (2, 0, 156, 94)]
    assert sum(p[3] for p in pieces) == 250


def test_encode_decode_roundtrip():
    exts = [ext(0, 10), Extent(10, 5, ()), ext(15, 3, disk_off=99)]
    assert decode_extents(encode_extents(exts)) == exts


# ------------------------------------------------------------ property tests
# Oracle: materialize the overlay into a byte array where each extent writes
# its (unique) id; compaction/overlay must reproduce the same coverage map.

@st.composite
def extent_lists(draw, max_len=200):
    n = draw(st.integers(1, 12))
    out = []
    for i in range(n):
        off = draw(st.integers(0, max_len - 1))
        ln = draw(st.integers(1, max_len - off))
        zero = draw(st.booleans())
        out.append(Extent(off, ln, ()) if zero
                   else Extent(off, ln, (ptr(0, f"f{i}", 0, ln),)))
    return out


def coverage_map(entries, max_len=200):
    """id of the visible extent at each byte (-1 hole, -2 zero extent)."""
    cover = [-1] * max_len
    for idx, e in enumerate(entries):
        for b in range(e.offset, min(e.end, max_len)):
            cover[b] = -2 if e.is_zero else idx
    return cover


@settings(max_examples=200, deadline=None)
@given(extent_lists())
def test_overlay_matches_byte_oracle(entries):
    cover = coverage_map(entries)
    resolved = overlay(entries)
    got = [-1] * 200
    for e in resolved:
        src = None
        if not e.is_zero:
            # identify the source extent by backing-file name
            src = int(e.ptrs[0].backing_file[1:])
        for b in range(e.offset, min(e.end, 200)):
            assert got[b] == -1, "overlay produced overlapping extents"
            got[b] = -2 if e.is_zero else src
    assert got == cover


@settings(max_examples=200, deadline=None)
@given(extent_lists())
def test_compact_idempotent_and_equivalent(entries):
    c1 = compact(entries)
    c2 = compact(c1)
    assert c1 == c2, "compact must be idempotent"
    assert coverage_visible(c1) == coverage_visible(overlay(entries))


def coverage_visible(extents):
    out = {}
    for e in extents:
        for b in range(e.offset, e.end):
            # (is_zero, disk position) identifies the visible byte source
            out[b] = ((True, None) if e.is_zero else
                      (False, (e.ptrs[0].backing_file,
                               e.ptrs[0].offset + (b - e.offset))))
    return out


@settings(max_examples=200, deadline=None)
@given(extent_lists(), st.integers(0, 199), st.integers(1, 200))
def test_slice_range_tiles_exactly(entries, start, length):
    tiles = slice_range(entries, start, length)
    assert sum(t.length for t in tiles) == length
    cursor = start
    for t in tiles:
        assert t.offset == cursor
        cursor += t.length
    ref = coverage_map(entries)
    for t in tiles:
        for b in range(t.offset, t.end):
            if b < 200:
                if t.is_zero:
                    assert ref[b] in (-1, -2)
                else:
                    assert ref[b] >= 0


@settings(max_examples=100, deadline=None)
@given(extent_lists())
def test_visible_length_is_max_end(entries):
    assert visible_length(entries) == max(e.end for e in entries)
