"""Multi-device behavior that needs >1 device: run in subprocesses with
--xla_force_host_platform_device_count (never set in THIS process — the
rest of the suite must see one device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_small_mesh_train_and_serve_compile():
    """The launcher machinery (rules, shardings, batch fitting) on a
    (2,4) mesh with a reduced config: lower + compile both steps and
    confirm collectives exist (i.e. the program is genuinely sharded)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.models import get_model
        from repro.parallel.sharding import make_rules, tree_shardings
        from repro.train import TrainHyper, abstract_state, \\
            make_train_step, make_serve_step
        from repro.launch.mesh import _make_mesh
        from repro.roofline.hlo_analysis import analyze

        mesh = _make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("olmoe-1b-7b").replace(max_seq=32)
        model = get_model(cfg)
        rules = make_rules(mesh, **dict(cfg.rules_overrides))
        psh = tree_shardings(model.schema(), mesh, rules)
        state = abstract_state(model)
        ssh = {"params": psh,
               "opt": type(state["opt"])(m=psh, v=psh,
                   count=NamedSharding(mesh, PartitionSpec())),
               "step": NamedSharding(mesh, PartitionSpec())}
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        bsh = {k: NamedSharding(mesh, PartitionSpec("data", None))
               for k in batch}
        step = make_train_step(model, TrainHyper(), rules)
        compiled = jax.jit(step, in_shardings=(ssh, bsh),
                           out_shardings=(ssh, None)).lower(
                               state, batch).compile()
        ana = analyze(compiled.as_text(), total_devices=8)
        assert ana.collective_ops, "expected a sharded program"
        print("train collectives:", len(ana.collective_ops))

        cache = model.abstract_cache(4, max_len=32)
        csh = tree_shardings(model.cache_schema(4, 32), mesh, rules)
        serve = make_serve_step(model, rules)
        dec = {"tokens": jax.ShapeDtypeStruct((4, 1), jnp.int32),
               "pos": jax.ShapeDtypeStruct((4,), jnp.int32)}
        dsh = {"tokens": NamedSharding(mesh, PartitionSpec("data", None)),
               "pos": NamedSharding(mesh, PartitionSpec("data"))}
        compiled2 = jax.jit(serve, in_shardings=(psh, csh, dsh),
                            out_shardings=(None, csh)).lower(
                                model.abstract_params(), cache,
                                dec).compile()
        print("serve ok", compiled2.cost_analysis() is not None)
    """)
    assert "train collectives:" in out
    assert "serve ok True" in out


def test_int8_pod_sync_preserves_mean():
    """make_pod_sync on a real (pod, data, model) mesh: averaged params
    match the fp32 cross-pod mean within int8 quantization error."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.launch.mesh import _make_mesh
        from repro.train.compression import make_pod_sync

        mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
        sync = make_pod_sync(mesh, compress=True)
        rng = np.random.RandomState(0)
        base = rng.randn(64, 32).astype(np.float32)
        # per-pod divergent replicas: x on pod 0, x+delta on pod 1
        delta = rng.randn(64, 32).astype(np.float32) * 0.1
        per_dev = []
        for d in mesh.devices.flat:
            pod = int(np.argwhere(mesh.devices == d)[0][0])
            per_dev.append(base + pod * delta)
        x = jax.make_array_from_single_device_arrays(
            (64, 32), NamedSharding(mesh, PartitionSpec()),
            [jax.device_put(v, d)
             for v, d in zip(per_dev, mesh.devices.flat)])
        y = sync({"w": x})["w"]
        want = base + 0.5 * delta
        err = float(jnp.max(jnp.abs(y - want)))
        scale = float(np.abs(per_dev[-1]).max()) / 127
        assert err <= scale + 1e-6, (err, scale)
        print("pod sync err:", err, "<= step", scale)
    """)
    assert "pod sync err:" in out
