"""WarpKV: optimistic multi-key transactions + commutative ops."""
import threading

import pytest

from repro.core.errors import KVConflict, PreconditionFailed
from repro.core.metadata import ListAppend, Transaction, WarpKV
from repro.core.testing import LockOrderWatchdog


def test_basic_put_get():
    kv = WarpKV()
    kv.put("s", "k", 1)
    assert kv.get("s", "k") == 1
    assert kv.get("s", "missing", 42) == 42


def test_multi_key_commit_is_atomic():
    kv = WarpKV()
    txn = kv.begin()
    txn.put("a", 1, "x")
    txn.put("b", 2, "y")
    assert kv.get("a", 1) is None, "writes must not leak before commit"
    txn.commit()
    assert kv.get("a", 1) == "x" and kv.get("b", 2) == "y"


def test_read_version_conflict_aborts():
    kv = WarpKV()
    kv.put("s", "k", 1)
    t1 = kv.begin()
    assert t1.get("s", "k") == 1
    kv.put("s", "k", 2)            # concurrent commit
    t1.put("s", "other", 99)
    with pytest.raises(KVConflict):
        t1.commit()
    assert kv.get("s", "other") is None


def test_blind_writes_do_not_conflict():
    kv = WarpKV()
    kv.put("s", "k", 1)
    t1 = kv.begin()
    t1.put("s", "k", 10)           # no read → no dependency
    kv.put("s", "k", 2)
    t1.commit()                    # must succeed
    assert kv.get("s", "k") == 10


def test_delete_then_recreate_is_not_aba():
    kv = WarpKV()
    kv.put("s", "k", "v1")
    t1 = kv.begin()
    t1.get("s", "k")
    # delete and recreate behind t1's back
    t2 = kv.begin(); t2.delete("s", "k"); t2.commit()
    t3 = kv.begin(); t3.put("s", "k", "v2"); t3.commit()
    t1.put("s", "x", 1)
    with pytest.raises(KVConflict):
        t1.commit()


def test_commutative_appends_never_conflict():
    kv = WarpKV()
    t1 = kv.begin()
    t2 = kv.begin()
    t1.commute("s", "lst", ListAppend(["a"]))
    t2.commute("s", "lst", ListAppend(["b"]))
    t1.commit()
    t2.commit()                    # both commit: appends commute
    assert sorted(kv.get("s", "lst")) == ["a", "b"]


def test_commute_result_deferred():
    kv = WarpKV()
    txn = kv.begin()
    d = txn.commute("s", "lst", ListAppend(["a", "b"]))
    with pytest.raises(RuntimeError):
        _ = d.value
    txn.commit()
    assert d.value == 2


def test_get_view_sees_own_commutes():
    kv = WarpKV()
    txn = kv.begin()
    txn.commute("s", "lst", ListAppend(["a"]))
    txn.commute("s", "lst", ListAppend(["b"]))
    assert txn.get_view("s", "lst") == ["a", "b"]
    assert kv.get("s", "lst") is None      # still uncommitted


def test_noop_commute_does_not_invalidate_readers():
    kv = WarpKV()
    kv.put("s", "k", 5)

    class MaxMerge:
        def __init__(self, v): self.v = v
        def precondition(self, value): return True
        def apply(self, value): return max(value, self.v), None

    reader = kv.begin()
    assert reader.get("s", "k") == 5
    t = kv.begin()
    t.commute("s", "k", MaxMerge(3))       # 5 stays 5 → no version bump
    t.commit()
    reader.put("s", "out", 1)
    reader.commit()                         # must NOT conflict
    assert kv.get("s", "out") == 1


def test_precondition_failure_aborts():
    kv = WarpKV()

    class Bounded(ListAppend):
        def precondition(self, value):
            return len(value or []) + len(self.items) <= 2

    t = kv.begin()
    t.commute("s", "lst", Bounded(["a", "b", "c"]))
    with pytest.raises(PreconditionFailed):
        t.commit()


def test_injected_abort():
    kv = WarpKV()
    kv.inject_aborts(1)
    t = kv.begin()
    t.put("s", "k", 1)
    with pytest.raises(KVConflict):
        t.commit()
    t2 = kv.begin(); t2.put("s", "k", 1); t2.commit()
    assert kv.get("s", "k") == 1


def test_concurrent_counter_with_retries():
    """Classic OCC stress: N threads × M increments via read-modify-write."""
    kv = WarpKV()
    kv.put("s", "n", 0)
    N, M = 8, 25

    def worker():
        for _ in range(M):
            while True:
                txn = kv.begin()
                v = txn.get("s", "n")
                txn.put("s", "n", v + 1)
                try:
                    txn.commit()
                    break
                except KVConflict:
                    continue

    threads = [threading.Thread(target=worker) for _ in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()
    assert kv.get("s", "n") == N * M


def test_concurrent_commutative_appends_threaded():
    kv = WarpKV()
    N, M = 8, 50

    def worker(i):
        for j in range(M):
            txn = kv.begin()
            txn.commute("s", "lst", ListAppend([(i, j)]))
            txn.commit()           # never needs a retry loop

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()
    lst = kv.get("s", "lst")
    assert len(lst) == N * M
    assert len(set(lst)) == N * M
    assert kv.stats.aborts == 0, "commutative appends must never abort"


# ------------------------------------------------ commit-path diagnostics
def test_conflicts_counter_counts_only_version_validation():
    """``conflicts`` is the §2.5 signal — true OCC read-version validation
    failures.  Injected aborts and precondition failures bump ``aborts``
    (or raise) without polluting it."""
    kv = WarpKV()
    kv.put("s", "k", 0)

    t1 = kv.begin()
    t1.get("s", "k")
    kv.put("s", "k", 1)                    # move the version under t1
    t1.put("s", "k", 99)
    with pytest.raises(KVConflict):
        t1.commit()
    assert kv.stats.conflicts == 1
    assert kv.stats.aborts == 1

    kv.inject_aborts(1)
    t2 = kv.begin()
    t2.put("s", "k", 2)
    with pytest.raises(KVConflict):
        t2.commit()
    assert kv.stats.conflicts == 1, "injected aborts are not conflicts"
    assert kv.stats.aborts == 2

    class Never(ListAppend):
        def precondition(self, value):
            return False

    t3 = kv.begin()
    t3.commute("s", "lst", Never(["x"]))
    with pytest.raises(PreconditionFailed):
        t3.commit()
    assert kv.stats.conflicts == 1, "precondition failures are not conflicts"


def test_group_commit_leader_handoff_under_contention():
    """The leader-handoff group commit: a retiring leader hands the batch
    leadership to the queue head instead of letting every follower race a
    mutex.  Under contention some drains must batch more than one commit,
    every commit lands, and the wait/hold clocks tick."""
    kv = WarpKV()
    # The lock-order witness turns any inversion in the handoff path into
    # an immediate LockOrderViolation instead of a silent deadlock risk.
    assert LockOrderWatchdog.enabled()
    assert LockOrderWatchdog.is_witnessed(kv._wal_lock)
    assert LockOrderWatchdog.is_witnessed(kv._stripes[0])
    N, M = 8, 50

    def worker(i):
        for j in range(M):
            txn = kv.begin()
            txn.put("s", (i, j), j)
            txn.commit()

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in threads: t.start()
    for t in threads: t.join()

    s = kv.stats.snapshot()
    assert s["commits"] == N * M
    assert 0 < s["leader_drains"] <= N * M
    assert len(kv.keys("s")) == N * M
    assert s["commit_hold_s"] > 0.0
    assert s["commit_wait_s"] >= 0.0
    LockOrderWatchdog.assert_clean()


def test_subscribe_attach_mid_stream_no_gap():
    """Regression for the snapshot-then-tail handoff: a subscriber that
    attaches WHILE commits are in flight must see a gap-free per-shard
    sequence and converge on the exact latest value of every key — no
    event may fall between the replay and the live tail."""
    kv = WarpKV()
    M = 300
    seen = {}
    seqs = []
    started = threading.Event()

    def committer():
        for j in range(M):
            kv.put("s", j % 7, j)
            if j == M // 4:
                started.set()

    th = threading.Thread(target=committer)
    th.start()
    started.wait()
    cancel = kv.subscribe(
        lambda sp, k, v, ver, shard, seq: (
            seen.__setitem__((sp, k), v), seqs.append(seq)),
        with_meta=True)
    th.join()

    assert seqs == list(range(1, len(seqs) + 1)), \
        "per-subscriber sequence must be gap-free from 1"
    for k in range(7):
        assert seen[("s", k)] == kv.get("s", k), \
            "subscriber diverged from the store"

    before = len(seqs)
    cancel()
    kv.put("s", "post-cancel", 1)
    assert len(seqs) == before, "cancelled subscriber still delivered"
