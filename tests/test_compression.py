"""int8 gradient/parameter compression: quantization error bounds and
mean preservation (the cross-pod sync path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import (dequantize_int8, quantize_int8)


@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
def test_quantize_roundtrip_error_bound(scale):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(128, 64) * scale, jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s)
    # max error ≤ half a quantization step
    step = float(s)
    assert float(jnp.max(jnp.abs(y - x))) <= 0.5 * step + 1e-12
    # RMS error well under 1% of the dynamic range
    rms = float(jnp.sqrt(jnp.mean((y - x) ** 2)))
    assert rms < 0.005 * float(jnp.max(jnp.abs(x)))


def test_quantize_zero_tensor():
    q, s = quantize_int8(jnp.zeros((16,)))
    assert float(jnp.max(jnp.abs(dequantize_int8(q, s)))) == 0.0


def test_compressed_mean_across_pods_simulated():
    """Simulate the pod-axis mean: per-pod quantized tensors, exact int32
    sum, per-pod dequant — matches the fp32 mean within quant error."""
    rng = np.random.RandomState(1)
    pods = [jnp.asarray(rng.randn(256) * (i + 1), jnp.float32)
            for i in range(4)]
    qs = [quantize_int8(p) for p in pods]
    approx = sum(dequantize_int8(q, s) for q, s in qs) / len(pods)
    exact = sum(pods) / len(pods)
    err = float(jnp.max(jnp.abs(approx - exact)))
    worst_step = max(float(s) for _, s in qs)
    assert err <= 0.5 * worst_step * len(pods) / len(pods) + 1e-9
