"""Leased client metadata caching (core/lease): zero-round-trip hot
re-reads, revocation on writes, staleness safety, expiry, the grant-race
protocol, and shared plan-cache eviction."""
import threading
import time

import pytest

from repro.core import (Cluster, LeaseHub, LeaseTable, TransactionAborted,
                        WarpKV)

TTL = 60.0


@pytest.fixture
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"), lease_ttl=TTL)
    yield c
    c.close()


def _mk(cl, path, data=b"hello world"):
    fd = cl.open(path, "w")
    cl.write(fd, data)
    cl.close(fd)


def _kv_reqs(cluster):
    s = cluster.kv.stats.snapshot()
    return s["gets"], s["commits"]


# ----------------------------------------------------------- hot re-reads
def test_hot_stat_zero_kv_round_trips(cluster):
    cl = cluster.client()
    _mk(cl, "/hot")
    cl.stat("/hot")                        # warm: grants the leases
    before = _kv_reqs(cluster)
    hits0 = cluster.lease_hub.stats.lease_hits
    for _ in range(20):
        st = cl.stat("/hot")
        assert st["size"] == len(b"hello world")
    after = _kv_reqs(cluster)
    assert after == before, \
        f"leased hot re-reads hit the KV: gets/commits {before} -> {after}"
    assert cluster.lease_hub.stats.lease_hits > hits0
    assert cluster.lease_hub.stats.lease_commit_skips > 0


def test_leases_off_by_default(tmp_path):
    c = Cluster(n_servers=1, data_dir=str(tmp_path / "d"))
    try:
        assert c.lease_hub is None
        assert c.shared_plan_cache is None
        cl = c.client()
        _mk(cl, "/f")
        cl.stat("/f")
        before = _kv_reqs(c)
        cl.stat("/f")
        assert _kv_reqs(c) != before       # every stat round-trips
        assert "leases" not in c.total_stats()
    finally:
        c.close()


# ------------------------------------------------------------- revocation
def test_write_by_other_client_revokes_and_reader_sees_fresh(cluster):
    ca, cb = cluster.client(), cluster.client()
    _mk(ca, "/x", b"v1")
    fd = ca.open("/x", "r")
    assert ca.read(fd) == b"v1"            # warms ca's leases on /x
    ca.close(fd)
    revs0 = cluster.lease_hub.stats.lease_revocations
    fd = cb.open("/x", "rw")
    cb.pwrite(fd, b"v2", 0)
    cb.close(fd)
    assert cluster.lease_hub.stats.lease_revocations > revs0
    fd = ca.open("/x", "r")
    assert ca.read(fd) == b"v2", "reader served stale leased metadata"
    ca.close(fd)


def test_stale_lease_never_commits_stale(cluster):
    """A transaction that observed leased metadata which a concurrent
    writer then changed must NOT commit on the stale snapshot: the commit
    falls through to the KV, conflicts, and the §2.6 replay — seeing a
    different outcome — aborts to the application."""
    ca, cb = cluster.client(), cluster.client()
    _mk(ca, "/shared", b"AAAA")
    _mk(ca, "/out", b"....")
    fd = ca.open("/shared", "r")
    ca.read(fd)
    ca.close(fd)                           # leases on /shared now warm
    with pytest.raises(TransactionAborted):
        with ca.transaction():
            fd = ca.open("/shared", "r")
            observed = ca.read(fd)         # app observes (leased) AAAA
            ca.close(fd)
            assert observed == b"AAAA"
            # concurrent writer commits BBBB mid-transaction
            wfd = cb.open("/shared", "rw")
            cb.pwrite(wfd, b"BBBB", 0)
            cb.close(wfd)
            ofd = ca.open("/out", "rw")
            ca.pwrite(ofd, b"obs=" + observed, 0)
            ca.close(ofd)
    # the aborted transaction left no trace
    fd = ca.open("/out", "r")
    assert ca.read(fd) == b"...."
    ca.close(fd)


def test_lease_expiry_forces_refetch(cluster):
    cl = cluster.client()
    _mk(cl, "/exp")
    cl.stat("/exp")
    before = _kv_reqs(cluster)
    cl.stat("/exp")
    assert _kv_reqs(cluster) == before     # leased while fresh
    # jump the hub clock past every TTL
    real = cluster.lease_hub.clock
    cluster.lease_hub.clock = lambda: real() + TTL + 1
    exp0 = cluster.lease_hub.stats.lease_expirations
    st = cl.stat("/exp")
    assert st["size"] == len(b"hello world")
    assert cluster.lease_hub.stats.lease_expirations > exp0
    assert _kv_reqs(cluster) != before, \
        "expired leases must fall back to real KV reads"


def test_grant_race_killed_placeholder_never_activates():
    """The two-step grant protocol: a revocation landing between
    ``begin_grant`` and ``commit_grant`` kills the placeholder, so a lease
    can never be born from a read that predates the revoking commit."""
    hub = LeaseHub(WarpKV(), ttl=TTL)
    tab = LeaseTable(hub)
    sk = ("inodes", 7)
    tok = tab.begin_grant(sk)
    tab.revoke([sk])                       # writer's barrier fires here
    assert tab.commit_grant(sk, tok, version=3, value="stale") is False
    assert tab.lookup(sk) is None
    assert hub.stats.lease_grants == 0
    # an untouched grant does activate
    tok2 = tab.begin_grant(sk)
    assert tab.commit_grant(sk, tok2, version=4, value="fresh") is True
    assert tab.lookup(sk) == (4, "fresh")
    assert hub.stats.lease_grants == 1


def test_lease_table_lru_bounded():
    hub = LeaseHub(WarpKV(), ttl=TTL)
    tab = LeaseTable(hub)
    tab.MAX_LEASES = 8
    for i in range(32):
        tok = tab.begin_grant(("inodes", i))
        tab.commit_grant(("inodes", i), tok, version=1, value=i)
    assert len(tab) <= 8
    assert tab.lookup(("inodes", 31)) == (1, 31)     # newest survives
    assert tab.lookup(("inodes", 0)) is None         # oldest evicted


def test_revalidate_rejects_version_skew():
    hub = LeaseHub(WarpKV(), ttl=TTL)
    tab = LeaseTable(hub)
    sk = ("paths", "/p")
    tok = tab.begin_grant(sk)
    tab.commit_grant(sk, tok, version=5, value=1)
    assert tab.revalidate({sk: 5}) is True
    assert tab.revalidate({sk: 4}) is False
    assert tab.revalidate({sk: 5, ("paths", "/q"): 1}) is False


# ---------------------------------------------------- shared plan cache
def test_plan_cache_shared_across_clients(cluster):
    ca, cb = cluster.client(), cluster.client()
    assert ca._plan_cache is cb._plan_cache \
        is cluster.shared_plan_cache
    payload = b"p" * 4096
    _mk(ca, "/plans", payload)
    fd = ca.open("/plans", "r")
    assert ca.preadv(fd, [4096], 0) == [payload]   # installs the plan
    ca.close(fd)
    assert ca.stats.plan_cache_misses > 0
    misses_b0 = cb.stats.plan_cache_misses
    fd = cb.open("/plans", "r")
    assert cb.preadv(fd, [4096], 0) == [payload]   # same (inode, ranges)
    cb.close(fd)
    assert cb.stats.plan_cache_hits > 0, \
        "client B never hit the plan client A installed"
    assert cb.stats.plan_cache_misses == misses_b0


def test_plan_cache_dropped_on_region_write(cluster):
    ca, cb = cluster.client(), cluster.client()
    payload = b"q" * 4096
    _mk(ca, "/pinv", payload)
    fd = ca.open("/pinv", "r")
    assert ca.preadv(fd, [4096], 0) == [payload]   # plan now cached
    ca.close(fd)
    assert cluster.shared_plan_cache.get((ca.stat("/pinv")["inode"],
                                          ((0, 4096),))) is not None
    inv0 = cluster.lease_hub.stats.plan_invalidations
    fd = cb.open("/pinv", "rw")
    cb.pwrite(fd, b"Z" * 128, 0)
    cb.close(fd)
    assert cluster.lease_hub.stats.plan_invalidations > inv0, \
        "region write did not evict the shared plan cache"
    fd = ca.open("/pinv", "r")
    assert ca.read(fd) == b"Z" * 128 + payload[128:]
    ca.close(fd)


# --------------------------------------------------- leases x sharding
def test_leases_on_sharded_plane_zero_round_trips(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"),
                n_meta_shards=2, lease_ttl=TTL)
    try:
        cl = c.client()
        for i in range(6):
            _mk(cl, f"/sh{i}")
            cl.stat(f"/sh{i}")
        before = _kv_reqs(c)
        for _ in range(5):
            for i in range(6):
                cl.stat(f"/sh{i}")
        assert _kv_reqs(c) == before
        ts = c.total_stats()
        assert ts["leases"]["lease_hits"] > 0
        assert len(ts["kv_shards"]) == 2
    finally:
        c.close()


def test_concurrent_readers_and_writer_converge(cluster):
    """Hammer leased stats from reader threads while a writer keeps
    appending: no stale size is ever observed after the writer finishes."""
    cl = cluster.client()
    _mk(cl, "/conv", b"")
    stop = threading.Event()
    errs = []

    def reader():
        c = cluster.client()
        try:
            while not stop.is_set():
                c.stat("/conv")
        except Exception as e:            # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=reader) for _ in range(3)]
    for t in ts:
        t.start()
    w = cluster.client()
    fd = w.open("/conv", "rw")
    total = 0
    for i in range(20):
        w.pwrite(fd, b"x" * 50, total)
        total += 50
    w.close(fd)
    time.sleep(0.01)
    stop.set()
    for t in ts:
        t.join(timeout=30)
        assert not t.is_alive()
    assert not errs
    fresh = cluster.client()
    assert fresh.stat("/conv")["size"] == total
    assert cl.stat("/conv")["size"] == total, \
        "long-lived client stuck on a stale lease"


def test_lease_stats_in_total_stats(cluster):
    cl = cluster.client()
    _mk(cl, "/ts")
    cl.stat("/ts")
    cl.stat("/ts")
    ls = cluster.total_stats()["leases"]
    for key in ("lease_grants", "lease_hits", "lease_revocations",
                "lease_expirations", "lease_commit_skips",
                "plan_invalidations"):
        assert key in ls
    assert ls["lease_grants"] > 0
    assert ls["lease_hits"] > 0
