"""Fault tolerance: coordinator quorum, slice replication, failover (§2.9)."""
import pytest

from repro.core import (Cluster, NoQuorum, ReplicatedCoordinator,
                        StorageError)


# ------------------------------------------------------------- coordinator
def test_coordinator_replicas_agree():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.register_server(1, "b")
    cfg = co.config()
    assert cfg["online"] == [0, 1]
    for rep in co._replicas:
        assert rep.state.config() == cfg


def test_coordinator_survives_minority_failure():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(0)
    co.register_server(1, "b")          # still has 2/3 quorum
    assert co.config()["online"] == [0, 1]


def test_coordinator_loses_quorum():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(0)
    co.crash_replica(1)
    with pytest.raises(NoQuorum):
        co.register_server(1, "b")
    with pytest.raises(NoQuorum):
        co.config()


def test_coordinator_replica_recovery_catches_up():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(2)
    co.register_server(1, "b")
    co.fail_server(0)
    co.recover_replica(2)
    assert co._replicas[2].state.config() == co.config()


def test_epoch_bumps_on_membership_change():
    co = ReplicatedCoordinator(3)
    e1 = co.register_server(0, "a")
    e2 = co.fail_server(0)
    e3 = co.recover_server(0)
    assert e1 < e2 < e3


# ---------------------------------------------------------- data replication
@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    yield c
    c.close()


def make_file(fs, path, payload):
    fd = fs.open(path, "w")
    fs.write(fd, payload)
    fs.close(fd)


def read_file(fs, path):
    fd = fs.open(path, "r")
    data = fs.read(fd)
    fs.close(fd)
    return data


def test_writes_create_two_replicas(cluster):
    fs = cluster.client()
    make_file(fs, "/r", b"replicated" * 100)
    ino = fs.stat("/r")["inode"]
    rd = cluster.kv.get("regions", (ino, 0))
    for e in rd.entries:
        assert len(e.ptrs) == 2
        assert e.ptrs[0].server_id != e.ptrs[1].server_id, \
            "replicas must land on distinct servers"


def test_read_survives_one_server_failure(cluster):
    """Both systems tolerate the failure of any one storage server (§4)."""
    fs = cluster.client()
    payload = b"precious-data" * 500
    make_file(fs, "/critical", payload)
    ino = fs.stat("/critical")["inode"]
    rd = cluster.kv.get("regions", (ino, 0))
    victim = rd.entries[0].ptrs[0].server_id
    cluster.fail_server(victim)
    assert read_file(fs, "/critical") == payload


def test_write_survives_one_server_failure(cluster):
    fs = cluster.client()
    cluster.fail_server(0)
    payload = b"written-during-failure" * 100
    make_file(fs, "/during", payload)
    assert read_file(fs, "/during") == payload


def test_failed_server_recovery_rejoins_ring(cluster):
    fs = cluster.client()
    cluster.fail_server(1)
    make_file(fs, "/a", b"x" * 1000)
    cluster.recover_server(1)
    assert 1 in cluster._ring.servers
    make_file(fs, "/b", b"y" * 1000)
    assert read_file(fs, "/b") == b"y" * 1000


def test_unreplicated_cluster_loses_availability(tmp_path):
    """Sanity check on the failure model: with replication=1, losing the
    server holding a slice makes reads fail (no silent wrong answers)."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "u"), replication=1,
                region_size=64 * 1024)
    fs = c.client()
    make_file(fs, "/single", b"fragile")
    ino = fs.stat("/single")["inode"]
    rd = c.kv.get("regions", (ino, 0))
    victim = rd.entries[0].ptrs[0].server_id
    c.fail_server(victim)
    with pytest.raises(StorageError):
        read_file(fs, "/single")
    c.close()
