"""Fault tolerance: coordinator quorum, slice replication, failover (§2.9),
plus deterministic fault injection (``repro.core.testing``) driving the
batched read scheduler's per-extent failover and the §2.6 replay layer."""
import pytest

from repro.core import (Cluster, NoQuorum, ReplicatedCoordinator,
                        StorageError, TransactionAborted)
from repro.core.testing import make_flaky_kv, make_flaky_server


# ------------------------------------------------------------- coordinator
def test_coordinator_replicas_agree():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.register_server(1, "b")
    cfg = co.config()
    assert cfg["online"] == [0, 1]
    for rep in co._replicas:
        assert rep.state.config() == cfg


def test_coordinator_survives_minority_failure():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(0)
    co.register_server(1, "b")          # still has 2/3 quorum
    assert co.config()["online"] == [0, 1]


def test_coordinator_loses_quorum():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(0)
    co.crash_replica(1)
    with pytest.raises(NoQuorum):
        co.register_server(1, "b")
    with pytest.raises(NoQuorum):
        co.config()


def test_coordinator_replica_recovery_catches_up():
    co = ReplicatedCoordinator(3)
    co.register_server(0, "a")
    co.crash_replica(2)
    co.register_server(1, "b")
    co.fail_server(0)
    co.recover_replica(2)
    assert co._replicas[2].state.config() == co.config()


def test_epoch_bumps_on_membership_change():
    co = ReplicatedCoordinator(3)
    e1 = co.register_server(0, "a")
    e2 = co.fail_server(0)
    e3 = co.recover_server(0)
    assert e1 < e2 < e3


# ---------------------------------------------------------- data replication
@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    yield c
    c.close()


def make_file(fs, path, payload):
    fd = fs.open(path, "w")
    fs.write(fd, payload)
    fs.close(fd)


def read_file(fs, path):
    fd = fs.open(path, "r")
    data = fs.read(fd)
    fs.close(fd)
    return data


def test_writes_create_two_replicas(cluster):
    fs = cluster.client()
    make_file(fs, "/r", b"replicated" * 100)
    ino = fs.stat("/r")["inode"]
    rd = cluster.kv.get("regions", (ino, 0))
    for e in rd.entries:
        assert len(e.ptrs) == 2
        assert e.ptrs[0].server_id != e.ptrs[1].server_id, \
            "replicas must land on distinct servers"


def test_read_survives_one_server_failure(cluster):
    """Both systems tolerate the failure of any one storage server (§4)."""
    fs = cluster.client()
    payload = b"precious-data" * 500
    make_file(fs, "/critical", payload)
    ino = fs.stat("/critical")["inode"]
    rd = cluster.kv.get("regions", (ino, 0))
    victim = rd.entries[0].ptrs[0].server_id
    cluster.fail_server(victim)
    assert read_file(fs, "/critical") == payload


def test_write_survives_one_server_failure(cluster):
    fs = cluster.client()
    cluster.fail_server(0)
    payload = b"written-during-failure" * 100
    make_file(fs, "/during", payload)
    assert read_file(fs, "/during") == payload


def test_failed_server_recovery_rejoins_ring(cluster):
    fs = cluster.client()
    cluster.fail_server(1)
    make_file(fs, "/a", b"x" * 1000)
    cluster.recover_server(1)
    assert 1 in cluster._ring.servers
    make_file(fs, "/b", b"y" * 1000)
    assert read_file(fs, "/b") == b"y" * 1000


# ----------------------------------------------------- injected faults (read)
def test_read_scheduler_degrades_to_per_extent_on_covering_failure(tmp_path):
    """A covering retrieval that fails mid-batch must fall back to
    per-extent fetches with full replica failover — batching never reduces
    availability (iosched docstring contract)."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "pe"), replication=1,
                region_size=1 << 20)
    fs = c.client()
    payload = bytes(i & 0xFF for i in range(128 * 1024))
    with fs.open_file("/pe", "w") as f:
        f.write(payload)
    # every slice of the file lives on one server; fail exactly the FIRST
    # retrieve (the covering fetch), so only the degraded path can answer
    sid = c.kv.get("regions", (fs.stat("/pe")["inode"], 0)) \
        .entries[0].ptrs[0].server_id
    flaky = make_flaky_server(c, sid, {"retrieve_slice": {1}})
    ranges = [(i * 16 * 1024, 4096) for i in range(8)]
    with fs.open_file("/pe") as f:
        got = f.readv(ranges)
    assert got == [payload[o:o + n] for o, n in ranges]
    assert flaky.injected == 1
    assert flaky.calls["retrieve_slice"] > 1, \
        "degraded path must have re-fetched per extent"
    c.close()


def test_read_failover_to_replica_on_injected_error(tmp_path):
    """Transient retrieve failures on one replica fail over to the other
    (§2.9) without surfacing to the application."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "ro"), replication=2,
                region_size=1 << 20)
    fs = c.client()
    with fs.open_file("/ro", "w") as f:
        f.write(b"replicated-read" * 100)
    first = c.kv.get("regions", (fs.stat("/ro")["inode"], 0)) \
        .entries[0].ptrs[0].server_id
    flaky = make_flaky_server(c, first, {"retrieve_slice": {1, 2, 3}})
    with fs.open_file("/ro") as f:
        assert f.read() == b"replicated-read" * 100
    assert flaky.injected >= 1
    c.close()


# ------------------------------------------------ injected faults (KV commit)
def test_injected_commit_failure_replays_invisibly(tmp_path):
    """FlakyKV fails the Nth commit deterministically; with no concurrent
    interference the §2.6 replay must commit with identical outcomes."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "kv"), replication=1,
                region_size=64 * 1024)
    flaky = make_flaky_kv(c, fail_commits={3})
    fs = c.client()
    with fs.open_file("/f", "w") as f:      # commits #1 (open) and #2 (write)
        f.write(b"one")
    with fs.transaction():                  # commit #3 fails → replay
        fd = fs.open("/f", "rw")
        fs.seek(fd, 0, 2)
        fs.write(fd, b"-two")
    assert flaky.injected == 1
    assert fs.stats.txn_retries >= 1
    with fs.open_file("/f") as f:
        assert f.read() == b"one-two"
    c.close()


def test_replay_divergence_aborts_to_application(tmp_path):
    """If the replay of an injected-abort commit observes different bytes
    than the application already saw, the transaction must abort — the
    divergence is application-visible (§2.6)."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "dv"), replication=1,
                region_size=64 * 1024)
    flaky = make_flaky_kv(c, fail_commits={5})
    fs = c.client()
    other = c.client()
    with fs.open_file("/d", "w") as f:      # commits #1, #2
        f.write(b"AAAA")
    with pytest.raises(TransactionAborted):
        with fs.transaction():
            fd = fs.open("/d", "rw")
            seen = fs.read(fd, 4)           # app observes 'AAAA'
            ofd = other.open("/d", "rw")    # commit #3 (open)
            other.pwrite(ofd, b"BBBB", 0)   # commit #4 changes those bytes
            other.close(ofd)
            fs.pwrite(fd, seen[::-1], 0)    # commit #5 injected-fails
    assert flaky.injected == 1
    with other.open_file("/d") as f:
        assert f.read() == b"BBBB", "aborted txn must leave no trace"
    c.close()


def test_unreplicated_cluster_loses_availability(tmp_path):
    """Sanity check on the failure model: with replication=1, losing the
    server holding a slice makes reads fail (no silent wrong answers)."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "u"), replication=1,
                region_size=64 * 1024)
    fs = c.client()
    make_file(fs, "/single", b"fragile")
    ino = fs.stat("/single")["inode"]
    rd = c.kv.get("regions", (ino, 0))
    victim = rd.entries[0].ptrs[0].server_id
    c.fail_server(victim)
    with pytest.raises(StorageError):
        read_file(fs, "/single")
    c.close()
