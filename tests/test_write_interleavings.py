"""Property test: random interleavings of scalar writes, vectored writes and
yank/paste against an in-memory reference file, with the write scheduler ON
and OFF — and with the write-behind buffer ON (the whole sequence under one
transaction, reads served from the pending overlay until the commit flush).

For every generated op sequence the WTF file's contents must equal the
reference bytearray's, *regardless of batching*, and the client's stats must
satisfy the scheduler's invariants:

  * ``logical_bytes_written`` is identical in all modes (batching is
    invisible to the application);
  * the batched run never issues MORE store rounds than the scalar run,
    and the write-behind run never more than the batched run's scalar
    baseline;
  * the scalar pipeline never reports coalescing (it has none);
  * no ``degraded_stores`` without injected failures;
  * in the write-behind run, the contents observed INSIDE the transaction
    (pre-flush, straight from the buffer) already equal the model.

Runs with seeded ``random`` always; when hypothesis is installed (CI) the
same driver is additionally fuzzed with generated op lists.
"""
import random

import pytest

from repro.core import Cluster

REGION = 2048
MAXLEN = 3 * REGION                  # exercise region-boundary splits


# ------------------------------------------------------------------- driver
def gen_ops(rng: random.Random, n_ops: int) -> list:
    ops = []
    for _ in range(n_ops):
        kind = rng.randrange(4)
        if kind == 0:                # scalar positional write
            off = rng.randrange(0, MAXLEN)
            ops.append(("pwrite", off, rng.randbytes(rng.randrange(1, 600))))
        elif kind == 1:              # vectored positional gather-write
            off = rng.randrange(0, MAXLEN)
            chunks = [rng.randbytes(rng.randrange(1, 300))
                      for _ in range(rng.randrange(1, 6))]
            ops.append(("pwritev", off, chunks))
        elif kind == 2:              # scalar append
            ops.append(("append", rng.randbytes(rng.randrange(1, 400))))
        else:                        # yank a range, paste it elsewhere
            ops.append(("yankpaste", rng.randrange(0, MAXLEN),
                        rng.randrange(1, 500), rng.randrange(0, MAXLEN)))
    return ops


def splice(buf: bytearray, off: int, data: bytes) -> None:
    if not data:
        return                  # a zero-byte write never extends the file
    if off > len(buf):
        buf.extend(b"\x00" * (off - len(buf)))
    buf[off:off + len(data)] = data


def apply_ops(cluster: Cluster, ops: list, in_txn: bool = False) -> tuple:
    """Apply ``ops`` to a WTF file and the reference model; return
    (final file contents, reference contents, client stats, pre-commit
    contents — None unless ``in_txn``)."""
    fs = cluster.client()
    ref = bytearray()
    fd = fs.open("/prop", "w")
    buffered = None

    def drive():
        nonlocal buffered
        for op in ops:
            if op[0] == "pwrite":
                _, off, data = op
                fs.pwrite(fd, data, off)
                splice(ref, off, data)
            elif op[0] == "pwritev":
                _, off, chunks = op
                fs.pwritev(fd, chunks, off)
                splice(ref, off, b"".join(chunks))
            elif op[0] == "append":
                fs.append(fd, op[1])
                ref.extend(op[1])
            else:
                _, src, n, dst = op
                extents = fs.yankv(fd, [(src, n)])[0]
                fs.seek(fd, dst)
                fs.paste(fd, extents)
                splice(ref, dst, bytes(ref[src:src + n]))  # EOF-clamped copy
        if in_txn:
            # read-your-buffered-writes: the model must already hold
            buffered = fs.pread(fd, len(ref) + 1024, 0)

    if in_txn:
        with fs.transaction():    # aborts (not commits) if drive() raises
            drive()
    else:
        drive()
    got = fs.pread(fd, len(ref) + 1024, 0)
    fs.close(fd)
    return got, bytes(ref), fs.stats, buffered


def check_interleaving(tmp_path, ops) -> None:
    runs = {}
    # (key, store_batching, write_behind)
    for key, batching, wb in (("batched", True, False),
                              ("scalar", False, False),
                              ("writeback", True, True)):
        d = str(tmp_path / f"run_{key}")
        cluster = Cluster(n_servers=3, data_dir=d, replication=1,
                          region_size=REGION, num_backing_files=2,
                          store_batching=batching, write_behind=wb)
        try:
            runs[key] = apply_ops(cluster, ops, in_txn=wb)
        finally:
            cluster.close()
    for key, (got, ref, stats, buffered) in runs.items():
        assert got == ref, f"contents diverged from model ({key})"
        assert stats.degraded_stores == 0
        if buffered is not None:
            assert buffered == ref, \
                "buffered reads inside the txn diverged from model"
    batched, scalar = runs["batched"][2], runs["scalar"][2]
    writeback = runs["writeback"][2]
    assert batched.logical_bytes_written == scalar.logical_bytes_written
    assert writeback.logical_bytes_written == scalar.logical_bytes_written
    assert batched.store_batches <= scalar.store_batches
    assert writeback.store_batches <= scalar.store_batches
    assert writeback.writeback_flushes >= 1
    assert scalar.slices_store_coalesced == 0


# ------------------------------------------------------------- seeded runs
@pytest.mark.parametrize("seed", range(8))
def test_random_interleavings_match_model(tmp_path, seed):
    rng = random.Random(1000 + seed)
    check_interleaving(tmp_path, gen_ops(rng, 18))


def test_vectored_heavy_interleaving(tmp_path):
    """All-vectored sequence crossing region boundaries on every op."""
    rng = random.Random(7)
    ops = [("pwritev", i * (REGION // 2),
            [rng.randbytes(REGION // 3) for _ in range(3)])
           for i in range(8)]
    check_interleaving(tmp_path, ops)


# --------------------------------------------------------------- hypothesis
try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings

    op_strategy = st.one_of(
        st.tuples(st.just("pwrite"), st.integers(0, MAXLEN - 1),
                  st.binary(min_size=1, max_size=600)),
        st.tuples(st.just("pwritev"), st.integers(0, MAXLEN - 1),
                  st.lists(st.binary(min_size=1, max_size=300),
                           min_size=1, max_size=5)),
        st.tuples(st.just("append"), st.binary(min_size=1, max_size=400)),
        st.tuples(st.just("yankpaste"), st.integers(0, MAXLEN - 1),
                  st.integers(1, 500), st.integers(0, MAXLEN - 1)),
    )

    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.function_scoped_fixture])
    @given(st.lists(op_strategy, min_size=1, max_size=20))
    def test_hypothesis_interleavings_match_model(tmp_path_factory, ops):
        check_interleaving(tmp_path_factory.mktemp("wtf_ws"), ops)
except ImportError:                                    # pragma: no cover
    pass                       # seeded tests above still cover the property
