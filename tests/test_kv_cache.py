"""Paged KV cache: page algebra, prefix sharing, copy-on-write, GC."""
import numpy as np
import pytest

from repro.serving import CacheConfig, OutOfPages, PagedKVCache


def cfg(**kw):
    base = dict(num_layers=2, num_kv_heads=2, head_dim=4, page_tokens=4,
                num_pages=32, max_seqs=8)
    base.update(kw)
    return CacheConfig(**base)


def rand_kv(t, c, seed=0):
    rng = np.random.default_rng(seed)
    shape = (c.num_layers, t, c.num_kv_heads, c.head_dim)
    return (rng.standard_normal(shape).astype(np.float32),
            rng.standard_normal(shape).astype(np.float32))


def test_append_gather_roundtrip():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(10, c)
    cache.append(0, k, v)
    for layer in range(c.num_layers):
        gk, gv = cache.gather(0, layer)
        np.testing.assert_allclose(gk, k[layer])
        np.testing.assert_allclose(gv, v[layer])


def test_incremental_decode_appends():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    ks, vs = [], []
    for t in range(9):                    # token-by-token decode
        k, v = rand_kv(1, c, seed=t)
        cache.append(0, k, v)
        ks.append(k); vs.append(v)
    gk, _ = cache.gather(0, 0)
    np.testing.assert_allclose(gk, np.concatenate(ks, axis=1)[0])


def test_page_accounting():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(9, c)                  # 9 tokens → 3 pages of 4
    cache.append(0, k, v)
    assert len(cache.page_table[0]) == 3
    assert cache.free_pages() == c.num_pages - 3
    cache.release(0)
    assert cache.free_pages() == c.num_pages


def test_fork_shares_pages_zero_copy():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(8, c)                  # exactly 2 full pages
    cache.append(0, k, v)
    allocated_before = cache.stats["pages_allocated"]
    cache.fork(0, 1)
    assert cache.stats["pages_allocated"] == allocated_before, \
        "fork must not allocate pages"
    assert cache.page_table[0] == cache.page_table[1]
    gk0, _ = cache.gather(0, 0)
    gk1, _ = cache.gather(1, 0)
    np.testing.assert_allclose(gk0, gk1)


def test_fork_copy_on_write_open_page():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(6, c)                  # page 0 full, page 1 half-open
    cache.append(0, k, v)
    cache.fork(0, 1)
    # both sequences now append different tokens
    k0, v0 = rand_kv(1, c, seed=100)
    k1, v1 = rand_kv(1, c, seed=200)
    cache.append(0, k0, v0)
    cache.append(1, k1, v1)
    assert cache.page_table[0][0] == cache.page_table[1][0], \
        "full page stays shared"
    assert cache.page_table[0][1] != cache.page_table[1][1], \
        "open page must diverge (copy-on-write)"
    gk0, _ = cache.gather(0, 0)
    gk1, _ = cache.gather(1, 0)
    np.testing.assert_allclose(gk0[:6], gk1[:6])
    assert not np.allclose(gk0[6], gk1[6])


def test_release_with_sharing_refcounts():
    c = cfg()
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(8, c)
    cache.append(0, k, v)
    cache.fork(0, 1)
    cache.release(0)
    gk, _ = cache.gather(1, 0)            # child still intact
    np.testing.assert_allclose(gk, k[0])
    cache.release(1)
    assert cache.free_pages() == c.num_pages


def test_pool_exhaustion():
    c = cfg(num_pages=2)
    cache = PagedKVCache(c)
    cache.create(0)
    k, v = rand_kv(8, c)
    cache.append(0, k, v)                 # uses both pages
    cache.create(1)
    k1, v1 = rand_kv(1, c)
    with pytest.raises(OutOfPages):
        cache.append(1, k1, v1)


def test_table_array_format():
    c = cfg()
    cache = PagedKVCache(c)
    for s, t in ((0, 3), (1, 9)):
        cache.create(s)
        k, v = rand_kv(t, c, seed=s)
        cache.append(s, k, v)
    tbl, lens = cache.table_array([0, 1])
    assert tbl.shape == (2, 3)
    assert lens.tolist() == [3, 9]
    assert (tbl[0, 1:] == -1).all()
    assert (tbl[1] >= 0).all()
