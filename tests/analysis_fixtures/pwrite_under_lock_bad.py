"""WTF002 fixture (bug form): the PR 7 append-lock bug — device I/O issued
while holding the offset-reservation lock serializes every appender behind
the disk."""
import os
import threading


class BackingFile:
    def __init__(self, fd):
        self.lock = threading.Lock()
        self._fd = fd
        self.size = 0

    def append(self, data):
        with self.lock:
            off = self.size
            self.size += len(data)
            os.pwrite(self._fd, data, off)   # blocking I/O under the lock
        return off
