"""WTF004 fixture (bug form): a CommutingOp whose apply reads live KV
state, can raise, mutates its input and its own fields — everything
"apply cannot fail" (paper §2.5) forbids — plus a version_preserving op
that rebuilds the region end."""


class CommutingOp:
    def apply(self, value):
        raise NotImplementedError


class RegionData:
    def __init__(self, entries, end, indirect=None):
        self.entries = entries
        self.end = end
        self.indirect = indirect


class CounterAdd(CommutingOp):
    def __init__(self, kv, delta):
        self.kv = kv
        self.delta = delta

    def apply(self, value):
        base = self.kv.get("counters", "x")     # reads live KV state
        if value is None:
            raise ValueError("missing operand")  # apply cannot fail
        value.append(self.delta + base)          # mutates its input
        self.delta += 1                          # mutates op state
        return value


class StampRegion(CommutingOp):
    version_preserving = True

    def apply(self, rd):
        return RegionData(list(rd.entries), rd.end + 1, rd.indirect)
