"""WTF001 fixture (fixed form): sorted stripe acquisition, WAL taken inside
the stripes — matches the declared order, so the rule stays quiet."""
import threading


class MiniKV:
    N_STRIPES = 8

    def __init__(self):
        self._stripes = [threading.RLock() for _ in range(self.N_STRIPES)]
        self._wal_lock = threading.RLock()

    def commit_batch(self, stripe_ids):
        ordered = sorted(set(stripe_ids))
        for sid in ordered:
            self._stripes[sid].acquire()
        try:
            return len(ordered)
        finally:
            for sid in reversed(ordered):
                self._stripes[sid].release()

    def lock_then_log(self, sid):
        with self._stripes[sid]:
            with self._wal_lock:
                return sid
