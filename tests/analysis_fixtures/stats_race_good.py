"""WTF003 fixture (fixed form): the cursor moves under the lock and the
stats dataclass is mutated through add()."""
import threading
from dataclasses import dataclass, field


class AtomicStatsMixin:
    def add(self, **deltas):
        raise NotImplementedError


@dataclass
class ServerStats(AtomicStatsMixin):
    requests: int = 0
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = ServerStats()
        self._rr = 0

    def handle(self):
        with self._lock:
            self._rr += 1
        self.stats.add(requests=1)
        return self._rr
