"""WTF002 fixture (fixed form): reserve the offset under the lock, write
outside it — concurrent pwrites to disjoint ranges are safe."""
import os
import threading


class BackingFile:
    def __init__(self, fd):
        self.lock = threading.Lock()
        self._fd = fd
        self.size = 0

    def append(self, data):
        with self.lock:
            off = self.size
            self.size += len(data)
        os.pwrite(self._fd, data, off)
        return off
