"""WTF004 fixture (fixed form): pure ops — copy the operand, mutate the
copy, carry ``end`` through verbatim."""


class CommutingOp:
    def apply(self, value):
        raise NotImplementedError


class RegionData:
    def __init__(self, entries, end, indirect=None):
        self.entries = entries
        self.end = end
        self.indirect = indirect


class ListAppend(CommutingOp):
    def __init__(self, delta):
        self.delta = delta

    def apply(self, value):
        cur = list(value) if value is not None else []
        cur.append(self.delta)
        return cur


class CompactRegion(CommutingOp):
    version_preserving = True

    def apply(self, rd):
        entries = tuple(dict.fromkeys(rd.entries))
        return RegionData(entries, rd.end, rd.indirect)
