"""WTF001 fixture (bug form): stripe locks grabbed in arrival order and a
WAL-before-stripe inversion — the deadlock shapes group commit must avoid.

Never imported; parsed by tests/test_analysis.py through the analyzer.
"""
import threading


class MiniKV:
    N_STRIPES = 8

    def __init__(self):
        self._stripes = [threading.RLock() for _ in range(self.N_STRIPES)]
        self._wal_lock = threading.RLock()

    def commit_batch(self, stripe_ids):
        for sid in stripe_ids:             # arrival order, not sorted
            self._stripes[sid].acquire()
        try:
            return len(stripe_ids)
        finally:
            for sid in reversed(stripe_ids):
                self._stripes[sid].release()

    def log_then_lock(self, sid):
        with self._wal_lock:               # kv.wal is inner to kv.stripe
            with self._stripes[sid]:
                return sid
