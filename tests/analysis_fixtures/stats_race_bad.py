"""WTF003 fixture (bug form): the PR 4 race — bare '+=' on shared counters
from pool threads, both on a plain attribute and through a stats dataclass
that should only move via AtomicStatsMixin.add()."""
import threading
from dataclasses import dataclass, field


class AtomicStatsMixin:
    def add(self, **deltas):
        raise NotImplementedError


@dataclass
class ServerStats(AtomicStatsMixin):
    requests: int = 0
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = ServerStats()
        self._rr = 0

    def handle(self):
        self._rr += 1                  # unlocked read-modify-write
        self.stats.requests += 1       # bypasses AtomicStatsMixin.add()
        return self._rr
