"""Data-plane read caching: the client block cache and server readahead.

The block cache (``core/blockcache``) is validated by the same
touched-region KV versions as the plan cache — any commit that bumps a
touched region's version invalidates plans AND cached blocks together —
so these tests drive every invalidation edge: a concurrent writer, a
lease revocation (shared-cache clusters), write-behind pending extents
(structural bypass), and GC's sparse rewrite (server readahead pool).
A seeded differential run pins the strongest claim: every cache/readahead
configuration returns byte-identical data.
"""
import numpy as np
import pytest

from repro.core import Cluster, GarbageCollector
from repro.core.blockcache import BlockCache


@pytest.fixture
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"))
    yield c
    c.close()


def _mk(cl, path, data):
    fd = cl.open(path, "w")
    cl.write(fd, data)
    cl.close(fd)


def _read(cl, path):
    fd = cl.open(path, "r")
    try:
        return cl.read(fd)
    finally:
        cl.close(fd)


def _srv(cluster, key):
    return sum(s[key] for s in cluster.total_stats()["servers"].values())


# --------------------------------------------------------- hot re-reads
def test_hot_reread_costs_zero_storage_rounds(cluster):
    fs = cluster.client()
    payload = np.random.RandomState(0).bytes(256 << 10)
    _mk(fs, "/hot", payload)
    fd = fs.open("/hot", "r")
    assert fs.pread(fd, len(payload), 0) == payload   # fills the cache
    rounds0 = _srv(cluster, "read_rounds")
    assert fs.pread(fd, len(payload), 0) == payload
    assert _srv(cluster, "read_rounds") == rounds0, \
        "block-cached re-read issued storage rounds"
    assert fs.stats.block_cache_hits > 0
    fs.close(fd)


def test_cache_disabled_rereads_hit_storage(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"),
                block_cache_bytes=0)
    try:
        fs = c.client()
        payload = b"z" * (64 << 10)
        _mk(fs, "/nocache", payload)
        fd = fs.open("/nocache", "r")
        assert fs.pread(fd, len(payload), 0) == payload
        rounds0 = _srv(c, "read_rounds")
        assert fs.pread(fd, len(payload), 0) == payload
        assert _srv(c, "read_rounds") > rounds0
        assert fs.stats.block_cache_hits == 0
        fs.close(fd)
    finally:
        c.close()


# ------------------------------------------------------- invalidation
def test_concurrent_writer_invalidates_cached_blocks(cluster):
    ca, cb = cluster.client(), cluster.client()
    payload = b"a" * (128 << 10)
    _mk(ca, "/inv", payload)
    fd = ca.open("/inv", "r")
    assert ca.pread(fd, len(payload), 0) == payload   # A caches the block
    wfd = cb.open("/inv", "rw")
    cb.pwrite(wfd, b"B" * 4096, 0)                    # B overwrites
    cb.close(wfd)
    got = ca.pread(fd, len(payload), 0)
    assert got == b"B" * 4096 + payload[4096:], \
        "client A read stale cached bytes after a concurrent write"
    ca.close(fd)


def test_lease_revocation_evicts_shared_blocks(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"), lease_ttl=60.0)
    try:
        ca, cb = c.client(), c.client()
        payload = b"l" * (64 << 10)
        _mk(ca, "/lease", payload)
        fd = ca.open("/lease", "r")
        assert ca.pread(fd, len(payload), 0) == payload
        assert c.shared_block_cache is not None
        assert len(c.shared_block_cache) > 0
        inv0 = c.lease_hub.stats.block_invalidations
        wfd = cb.open("/lease", "rw")
        cb.pwrite(wfd, b"W" * 1024, 0)
        cb.close(wfd)
        assert c.lease_hub.stats.block_invalidations > inv0, \
            "invalidating commit did not evict shared cached blocks"
        assert ca.pread(fd, len(payload), 0) == b"W" * 1024 + payload[1024:]
        ca.close(fd)
    finally:
        c.close()


def test_write_behind_pending_extents_bypass_cache(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"),
                write_behind=True)
    try:
        fs = c.client()
        payload = b"p" * (64 << 10)
        _mk(fs, "/wb", payload)
        fd = fs.open("/wb", "rw")
        assert fs.pread(fd, len(payload), 0) == payload   # cached
        with fs.transaction():
            fs.pwrite(fd, b"N" * 8192, 0)
            # still buffered (no store dispatched): the read must see the
            # pending extent, not the cached pre-write block
            assert fs.pread(fd, 8192, 0) == b"N" * 8192
        assert fs.pread(fd, len(payload), 0) == \
            b"N" * 8192 + payload[8192:]
        fs.close(fd)
    finally:
        c.close()


def test_gc_sparse_rewrite_drops_readahead_pool(cluster):
    fs = cluster.client()
    rng = np.random.RandomState(1)
    alive, dead = rng.bytes(512 << 10), rng.bytes(512 << 10)
    _mk(fs, "/alive", alive)
    _mk(fs, "/dead", dead)
    # warm the server readahead pool with a sequential scan of /alive
    reader = cluster.client()
    fd = reader.open("/alive", "r")
    for off in range(0, len(alive), 64 << 10):
        reader.pread(fd, 64 << 10, off)
    reader.close(fd)
    fs.unlink("/dead")
    gc = GarbageCollector(cluster)
    gc.storage_gc_pass()                  # two-scan rule: records garbage
    gc.storage_gc_pass()                  # second pass punches holes
    # the sparse rewrite swaps the backing fd and drops the readahead
    # pool; live bytes must still read back exactly afterwards
    fresh = cluster.client()
    assert _read(fresh, "/alive") == alive, \
        "readahead pool served stale bytes after GC sparse rewrite"


# ---------------------------------------------------------- readahead
def test_sequential_scan_hits_readahead(cluster):
    fs = cluster.client()
    payload = np.random.RandomState(2).bytes(1 << 20)
    _mk(fs, "/seqscan", payload)
    reader = cluster.client()
    fd = reader.open("/seqscan", "r")
    got = b"".join(reader.pread(fd, 64 << 10, off)
                   for off in range(0, len(payload), 64 << 10))
    reader.close(fd)
    assert got == payload
    assert _srv(cluster, "readahead_hits") > 0, \
        "sequential scan never hit the readahead pool"


def test_readahead_off_never_speculates(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"),
                readahead=False)
    try:
        fs = c.client()
        payload = np.random.RandomState(3).bytes(1 << 20)
        _mk(fs, "/noraseq", payload)
        reader = c.client()
        fd = reader.open("/noraseq", "r")
        got = b"".join(reader.pread(fd, 64 << 10, off)
                       for off in range(0, len(payload), 64 << 10))
        reader.close(fd)
        assert got == payload
        assert _srv(c, "readahead_hits") == 0
        assert _srv(c, "readahead_bytes") == 0
    finally:
        c.close()


# ------------------------------------------------ seeded differential
def test_differential_cached_vs_uncached_byte_identical(tmp_path):
    """Random interleaved writes/overwrites/reads on four configurations
    (readahead x block cache) must return identical bytes throughout."""
    configs = [("on-on", {}),
               ("off-on", {"readahead": False}),
               ("on-off", {"block_cache_bytes": 0}),
               ("off-off", {"readahead": False, "block_cache_bytes": 0})]
    clusters, clients = {}, {}
    try:
        for tag, kw in configs:
            c = Cluster(n_servers=2, data_dir=str(tmp_path / tag), **kw)
            clusters[tag] = c
            clients[tag] = [c.client(), c.client()]
        rng = np.random.RandomState(42)
        size = 256 << 10
        base = rng.bytes(size)
        for tag, _ in configs:
            _mk(clients[tag][0], "/diff", base)
        for step in range(30):
            op = rng.randint(3)
            off = int(rng.randint(0, size - 4096))
            if op == 0:                       # overwrite from writer client
                blob = rng.bytes(4096)
                for tag, _ in configs:
                    w = clients[tag][1]
                    fd = w.open("/diff", "rw")
                    w.pwrite(fd, blob, off)
                    w.close(fd)
            elif op == 1:                     # scalar read from reader
                n = int(rng.randint(1, 64 << 10))
                outs = set()
                for tag, _ in configs:
                    r = clients[tag][0]
                    fd = r.open("/diff", "r")
                    outs.add(bytes(r.pread(fd, n, off)))
                    r.close(fd)
                assert len(outs) == 1, f"divergence at step {step} (pread)"
            else:                             # vectored read from reader
                ranges = [(int(rng.randint(0, size - 4096)), 4096)
                          for _ in range(4)]
                outs = set()
                for tag, _ in configs:
                    r = clients[tag][0]
                    fd = r.open("/diff", "r")
                    outs.add(b"|".join(bytes(p)
                                       for p in r.readv(fd, ranges)))
                    r.close(fd)
                assert len(outs) == 1, f"divergence at step {step} (readv)"
        finals = {tag: _read(clients[tag][0], "/diff")
                  for tag, _ in configs}
        assert len(set(finals.values())) == 1
    finally:
        for c in clusters.values():
            c.close()


# ------------------------------------------------------- knobs & unit
def test_block_cache_bytes_validation(tmp_path):
    with pytest.raises(ValueError):
        Cluster(n_servers=1, data_dir=str(tmp_path / "a"),
                block_cache_bytes=-1)
    with pytest.raises(ValueError):
        Cluster(n_servers=1, data_dir=str(tmp_path / "b"),
                block_cache_bytes=1.5)


def test_blockcache_lru_unit():
    bc = BlockCache(1024)                 # max_entry = 256
    k = lambda i: (0, "f", i * 256, 256)
    for i in range(4):
        bc.put(k(i), bytes([i]) * 256, inode_id=7)
    assert bc.nbytes() == 1024 and len(bc) == 4
    assert bc.get(k(0)) == b"\x00" * 256  # touch: 0 becomes most-recent
    bc.put(k(4), b"\x04" * 256, inode_id=7)
    assert bc.get(k(1)) is None, "LRU victim should be the untouched key"
    assert bc.get(k(0)) is not None
    bc.put((0, "f", 9999, 512), b"x" * 512, inode_id=7)
    assert bc.get((0, "f", 9999, 512)) is None, \
        "oversized entries must not enter the cache"
    dropped = bc.drop_inode(7)
    assert dropped == len([x for x in (0, 2, 3, 4)])
    assert len(bc) == 0 and bc.nbytes() == 0
