"""Tests for the concurrency invariant analyzer (WTF001-WTF004) and the
runtime lock-order witness it shares ``analysis/lockspec.py`` with.

The fixture pairs under ``tests/analysis_fixtures/`` reproduce each
historical bug class this repo actually shipped (unsorted stripe grabs,
pwrite under the append lock, the bare-'+=' stats race, impure commuting
ops); each rule must fire on the bug form and stay quiet on the fixed
form.  The shipped tree itself must scan clean — that is the CI gate.
"""
import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.analysis import lockspec
from repro.analysis.report import active, apply_suppressions
from repro.analysis.rules import run_rules
from repro.analysis.scanner import scan_paths
from repro.core.metadata import WarpKV
from repro.core.testing import (LockOrderViolation, LockOrderWatchdog,
                                witness_lock)

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
SRC_REPRO = REPO / "src" / "repro"


def findings_for(path, only=None):
    mods = scan_paths([Path(path)])
    findings = run_rules(mods, only=only)
    sources = {str(m.path): m.source for m in mods}
    return active(apply_suppressions(findings, sources))


# ------------------------------------------------------------ static pass

@pytest.mark.parametrize("rule,stem", [
    ("WTF001", "stripe_order"),
    ("WTF002", "pwrite_under_lock"),
    ("WTF003", "stats_race"),
    ("WTF004", "impure_commute"),
])
def test_rule_fires_on_bug_form_and_not_on_fix(rule, stem):
    bad = findings_for(FIXTURES / f"{stem}_bad.py")
    assert any(f.rule == rule for f in bad), \
        f"{rule} did not fire on {stem}_bad.py: {bad}"
    good = findings_for(FIXTURES / f"{stem}_good.py")
    assert good == [], f"{stem}_good.py should scan clean: {good}"


def test_stripe_order_bad_flags_both_shapes():
    msgs = [f.message for f in
            findings_for(FIXTURES / "stripe_order_bad.py", only={"WTF001"})]
    assert any("unsorted" in m for m in msgs), msgs          # arrival-order loop
    assert any("while holding 'kv.wal'" in m for m in msgs), msgs


def test_impure_commute_bad_flags_every_sin():
    msgs = " | ".join(f.message for f in
                      findings_for(FIXTURES / "impure_commute_bad.py"))
    for needle in ("raise inside", "reads KV", "mutates its input",
                   "mutates op state", "carry 'end'"):
        assert needle in msgs, (needle, msgs)


def test_shipped_tree_scans_clean_without_baseline():
    assert findings_for(SRC_REPRO) == []


def test_only_selector_restricts_rules():
    out = findings_for(FIXTURES / "stats_race_bad.py", only={"WTF001"})
    assert out == []
    out = findings_for(FIXTURES / "stats_race_bad.py", only={"WTF003"})
    assert out and all(f.rule == "WTF003" for f in out)


def test_suppression_requires_reason(tmp_path):
    src = (FIXTURES / "stats_race_bad.py").read_text()
    justified = src.replace(
        "self._rr += 1",
        "self._rr += 1  # wtf-lint: ignore[WTF003] -- single-threaded here")
    p = tmp_path / "justified.py"
    p.write_text(justified)
    rules = {f.rule for f in findings_for(p)}
    assert "WTF000" not in rules
    assert len([r for r in rules]) >= 1     # the stats-bypass one remains

    bare = src.replace("self._rr += 1",
                       "self._rr += 1  # wtf-lint: ignore[WTF003]")
    p2 = tmp_path / "bare.py"
    p2.write_text(bare)
    rules2 = {f.rule for f in findings_for(p2)}
    assert "WTF000" in rules2               # ignore without a reason


def test_cli_exits_nonzero_on_each_bug_class():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    for stem in ("stripe_order", "pwrite_under_lock", "stats_race",
                 "impure_commute"):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis",
             str(FIXTURES / f"{stem}_bad.py"),
             "--no-baseline", "--format", "json"],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert proc.returncode == 1, (stem, proc.stdout, proc.stderr)
        doc = json.loads(proc.stdout)
        assert doc["counts"]["active"] >= 1


def test_cli_exits_zero_on_shipped_tree():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src/repro",
         "--format", "json"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_lockspec_ranks_are_strictly_increasing():
    ranks = [lv.rank for lv in lockspec.LOCK_LEVELS]
    assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks)
    # every statically-mapped attr resolves to a declared level
    for (_, _, attr), level in lockspec.STATIC_LOCK_MAP.items():
        assert level in lockspec.LEVEL_BY_NAME, (attr, level)


# -------------------------------------------------------- runtime witness

def test_witness_enabled_under_tier1():
    # conftest.py sets WTF_LOCK_WITNESS=1 for the whole suite
    assert LockOrderWatchdog.enabled()


def test_order_inversion_caught_at_acquisition_time():
    outer = witness_lock(threading.Lock(), "kv.commit_queue", enabled=True)
    inner = witness_lock(threading.Lock(), "kv.wal", enabled=True)
    # declared order works
    with outer:
        with inner:
            pass
    # the inversion raises immediately — no second thread, no timeout:
    # this is acquisition-time detection, not deadlock detection
    with inner:
        with pytest.raises(LockOrderViolation):
            outer.acquire()
    LockOrderWatchdog.assert_clean()


def test_stripe_family_requires_ascending_keys():
    lo = witness_lock(threading.RLock(), "kv.stripe", key=(0, 3),
                      enabled=True)
    hi = witness_lock(threading.RLock(), "kv.stripe", key=(1, 0),
                      enabled=True)
    with lo:
        with hi:                      # (0,3) < (1,0): global shard order
            pass
    with hi:
        with pytest.raises(LockOrderViolation):
            lo.acquire()
    LockOrderWatchdog.assert_clean()


def test_reentrant_acquire_is_allowed():
    lk = witness_lock(threading.RLock(), "kv.stripe", key=(0, 1),
                      enabled=True)
    with lk:
        with lk:                      # identity re-entry: RLock semantics
            pass
    LockOrderWatchdog.assert_clean()


def test_witness_wraps_real_warpkv_and_catches_inversion():
    kv = WarpKV()
    assert LockOrderWatchdog.is_witnessed(kv._wal_lock)
    assert LockOrderWatchdog.is_witnessed(kv._stripes[0])
    with kv._wal_lock:
        with pytest.raises(LockOrderViolation):
            kv._stripes[0].acquire()
    LockOrderWatchdog.assert_clean()


def test_condition_over_witnessed_lock():
    lk = witness_lock(threading.Lock(), "wlog.consumer", enabled=True)
    cond = threading.Condition(lk)
    seen = []

    def waiter():
        with cond:
            cond.wait(timeout=5)
            seen.append([h.name for h in LockOrderWatchdog.held()])

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cond:
        cond.notify()
    t.join(5)
    assert not t.is_alive()
    # wait() released and re-acquired through the wrapper: the stack is
    # honest on the far side of the wakeup
    assert seen == [["wlog.consumer"]]
    LockOrderWatchdog.assert_clean()
