"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.mamba2_scan import ssd_recurrent_ref, ssd_scan
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

RNG = jax.random.PRNGKey(7)


def _tol(dtype):
    return {"float32": 2e-5, "bfloat16": 2e-2}[jnp.dtype(dtype).name]


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,sq,h,hkv,d", [
    (2, 256, 4, 2, 64),
    (1, 128, 8, 8, 128),
    (2, 512, 4, 1, 64),      # MQA
    (1, 384, 6, 2, 128),     # non-power-of-two seq (3 blocks)
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_sweep(b, sq, h, hkv, d, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sq, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sq, hkv, d)).astype(dtype)
    out = flash_attention(q, k, v, True, None, 128, 128, True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [64, 200])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    out = flash_attention(q, k, v, True, window, 128, 64, True)
    ref = attention_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, 64, 64, True))

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# -------------------------------------------------------- paged attention
@pytest.mark.parametrize("b,h,hkv,d,pages,t,pp", [
    (3, 8, 2, 64, 32, 16, 8),
    (2, 4, 4, 128, 16, 32, 4),
    (1, 16, 1, 64, 64, 16, 16),   # MQA, long table
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_paged_attention_sweep(b, h, hkv, d, pages, t, pp, dtype):
    ks = jax.random.split(RNG, 4)
    q = jax.random.normal(ks[0], (b, h, d)).astype(dtype)
    kp = jax.random.normal(ks[1], (pages, t, hkv, d)).astype(dtype)
    vp = jax.random.normal(ks[2], (pages, t, hkv, d)).astype(dtype)
    rng = np.random.RandomState(0)
    lengths = rng.randint(1, pp * t + 1, size=b).astype(np.int32)
    tbl = np.full((b, pp), -1, np.int32)
    free = list(rng.permutation(pages))
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // t)):
            tbl[i, j] = free.pop()
    out = paged_attention(q, kp, vp, jnp.asarray(tbl),
                          jnp.asarray(lengths), interpret=True)
    ref = paged_attention_ref(q, jnp.moveaxis(kp, 2, 0),
                              jnp.moveaxis(vp, 2, 0),
                              jnp.asarray(tbl), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 64, 32, 64),
    (1, 128, 2, 64, 64, 128),
    (2, 512, 8, 32, 16, 64),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    d_skip = jnp.ones((h,))
    y, sf = ssd_scan(xh, dt, a_log, bb, cc, d_skip, chunk=chunk,
                     interpret=True)
    yr, sr = ssd_recurrent_ref(xh * dt[..., None],
                               dt * -jnp.exp(a_log), bb, cc)
    yr = yr + d_skip[None, None, :, None] * xh
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               atol=2e-3, rtol=2e-3)


# -------------------------------------------- model-internal chunked paths
def test_model_ssd_chunked_matches_recurrent():
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(RNG, 5)
    b, s, h, p, n = 2, 192, 2, 32, 16
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y, sfin = ssd_chunked(xh, dt, a_log, bb, cc, jnp.zeros((h,)), 64)
    yr, sr = ssd_recurrent_ref(xh * dt[..., None],
                               dt * -jnp.exp(a_log), bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sfin), np.asarray(sr),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_chunked_matches_recurrent():
    """The xLSTM chunkwise form vs explicit token-by-token recurrence."""
    from repro.models.xlstm import mlstm_chunked, mlstm_step
    ks = jax.random.split(RNG, 5)
    b, s, h, dk, dv = 2, 128, 2, 16, 32
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0

    out_c, _ = mlstm_chunked(q, k, v, ig, fg, chunk=32)

    state = (jnp.zeros((b, h, dv, dk)), jnp.zeros((b, h, dk)),
             jnp.full((b, h), -jnp.inf))
    outs = []
    for t in range(s):
        o, state = mlstm_step(state, q[:, t], k[:, t], v[:, t],
                              ig[:, t], fg[:, t])
        outs.append(o)
    out_r = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               atol=2e-4, rtol=2e-3)
