"""Failure domain: health-tracked failover, deadlines/hedging, typed
degraded errors, and the background repair plane (§2.9 + ``core.repair``).

Three layers under test:

  * ``iort.HealthTracker`` — the circuit breaker the candidate walk
    consults (unit-level, with a fake clock: no real sleeping);
  * the degrade paths — typed ``DegradedRead``/``ReplicaExhausted``
    errors, repair tickets filed at degrade time, ``strict_replication``;
  * ``repair.RepairDaemon`` — re-replication after a silent server kill,
    including byte-identity of hot re-reads through the shared block/plan
    caches once the canonical pointer has moved.
"""
import pytest

from repro.core import (Cluster, DeadlineExceeded, DegradedRead,
                        HealthTracker, RepairDaemon, ReplicaExhausted,
                        StorageError)
from repro.core.iort import (HEALTH_FAILURE_THRESHOLD, HEALTH_JITTER_FRAC,
                             HEDGE_EWMA_MULTIPLIER)
from repro.core.repair import RepairTicket, ticket_from_placement
from repro.core.testing import kill_server, make_flaky_server, restart_server


# ------------------------------------------------------------ health tracker
class FakeClock:
    def __init__(self):
        self.t = 1000.0

    def __call__(self):
        return self.t


def test_health_closed_until_threshold():
    clk = FakeClock()
    h = HealthTracker(clock=clk)
    for _ in range(HEALTH_FAILURE_THRESHOLD - 1):
        h.record_failure(7)
        assert h.allow(7)
    h.record_failure(7)
    assert not h.allow(7)                    # circuit open
    assert h.snapshot()["servers"][7]["circuit_open"]


def test_health_success_closes_circuit_and_resets_backoff():
    clk = FakeClock()
    h = HealthTracker(clock=clk, backoff_base_s=1.0)
    for _ in range(HEALTH_FAILURE_THRESHOLD):
        h.record_failure(1)
    assert not h.allow(1)
    clk.t += 10.0                            # backoff elapsed: probe token
    assert h.allow(1)                        # the single half-open probe
    h.record_success(1, 0.001)
    assert h.allow(1) and h.allow(1)         # fully closed again
    snap = h.snapshot()["servers"][1]
    assert snap["consecutive_failures"] == 0
    assert not snap["circuit_open"]


def test_health_half_open_admits_exactly_one_probe():
    clk = FakeClock()
    h = HealthTracker(clock=clk, backoff_base_s=1.0)
    for _ in range(HEALTH_FAILURE_THRESHOLD):
        h.record_failure(2)
    clk.t += 100.0
    assert h.allow(2)                        # probe token granted
    assert not h.allow(2)                    # second caller still refused
    h.record_failure(2)                      # probe failed: re-open
    assert not h.allow(2)
    assert h.snapshot()["half_open_probes"] == 1


def test_health_backoff_grows_exponentially_with_jitter():
    clk = FakeClock()
    h = HealthTracker(seed=42, clock=clk, backoff_base_s=1.0,
                      backoff_cap_s=1000.0)
    opens = []
    for _ in range(HEALTH_FAILURE_THRESHOLD):
        h.record_failure(3)                  # trip the breaker
    for _ in range(3):
        st = h._servers[3]
        opens.append(st.open_until - clk.t)
        clk.t = st.open_until + 0.001        # serve out the backoff
        assert h.allow(3)                    # probe...
        h.record_failure(3)                  # ...which fails: re-open
    # base 1, 2, 4 seconds, each inflated by at most the jitter fraction.
    for i, base in enumerate((1.0, 2.0, 4.0)):
        assert base <= opens[i] <= base * (1.0 + HEALTH_JITTER_FRAC)
    assert opens[0] < opens[1] < opens[2]


def test_health_jitter_is_deterministic_per_seed():
    a, b = HealthTracker(seed=7), HealthTracker(seed=7)
    c = HealthTracker(seed=8)
    pairs = [(sid, n) for sid in range(4) for n in range(4)]
    assert [a._jitter(s, n) for s, n in pairs] == \
           [b._jitter(s, n) for s, n in pairs]
    assert [a._jitter(s, n) for s, n in pairs] != \
           [c._jitter(s, n) for s, n in pairs]
    assert all(0.0 <= a._jitter(s, n) < 1.0 for s, n in pairs)


def test_health_reset_forgets_server():
    h = HealthTracker()
    for _ in range(HEALTH_FAILURE_THRESHOLD):
        h.record_failure(5)
    assert not h.allow(5)
    h.reset(5)
    assert h.allow(5)
    assert 5 not in h.snapshot()["servers"]


def test_hedge_threshold_tracks_ewma():
    h = HealthTracker()
    assert h.hedge_threshold_s(0, 1.0) == 0.5      # no EWMA: deadline / 2
    h.record_success(0, 0.010)
    assert h.hedge_threshold_s(0, 1.0) == pytest.approx(
        0.010 * HEDGE_EWMA_MULTIPLIER)
    h.record_success(0, 10.0)                      # slow server...
    assert h.hedge_threshold_s(0, 1.0) == 1.0      # ...clamped to deadline


# --------------------------------------------------------------- clusters
@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    yield c
    c.close()


def write_file(c, path, payload):
    cl = c.client()
    with cl.open_file(path, "w") as f:
        f.write(payload)
    return cl


def read_file(cl, path):
    with cl.open_file(path, "r") as f:
        return f.read()


def test_failover_skips_circuit_open_servers(cluster):
    payload = b"q" * 40_000
    cl = write_file(cluster, "/a", payload)
    victim = None
    cl2 = cluster.client()
    # Trip some server's breaker via real failed rounds: kill one silently
    # and read until its failures cross the threshold.
    kill_server(cluster, 0)
    for _ in range(HEALTH_FAILURE_THRESHOLD + 1):
        assert read_file(cl2, "/a") == payload
    snap = cluster.health.snapshot()
    # Reads route around the corpse via live-replica picking, so server 0
    # may or may not have accrued failures — but every server that did is
    # now skipped up front by the walk.
    for sid, st in snap["servers"].items():
        if st["circuit_open"]:
            victim = sid
            assert not cluster.health.allow(sid)
    # Either way the walk keeps serving.
    assert read_file(cl2, "/a") == payload
    if victim is not None:
        cluster.health.reset(victim)


def test_degraded_read_typed_errors(tmp_path):
    c = Cluster(n_servers=3, data_dir=str(tmp_path), replication=2,
                min_read_replicas=2, region_size=64 * 1024)
    try:
        payload = b"z" * 30_000
        cl = write_file(c, "/f", payload)
        assert read_file(cl, "/f") == payload
        kill_server(c, 0)
        kill_server(c, 1)
        kill_server(c, 2)
        # All replicas dead: the strongest signal, and it IS a DegradedRead
        # and a StorageError (handlers written against either still work).
        with pytest.raises(ReplicaExhausted):
            read_file(c.client(), "/f")
        assert issubclass(ReplicaExhausted, DegradedRead)
        assert issubclass(DegradedRead, StorageError)
        assert issubclass(DeadlineExceeded, StorageError)
        restart_server(c, 0)
        restart_server(c, 1)
        restart_server(c, 2)
        # One dead replica out of two, with min_read_replicas=2: a policy
        # refusal even though the bytes are still readable.
        stats = c.total_stats()
        kill = next(sid for sid, st in stats["servers"].items()
                    if st["slices_written"] > 0)
        kill_server(c, kill)
        with pytest.raises(DegradedRead):
            read_file(c.client(), "/f")
    finally:
        c.close()


def test_degraded_store_files_repair_ticket(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    try:
        kill_server(c, 1)
        write_file(c, "/d", b"d" * 20_000)
        assert c.degraded_stores > 0
        snap = c.repair_stats.snapshot()
        assert snap["tickets_enqueued"] > 0
        # The ticket carries the extent identity (inode + region), not just
        # a "something degraded somewhere" counter.
        tickets = c.repair_queue.drain()
        assert tickets and all(t.region_idx is not None for t in tickets)
    finally:
        c.close()


def test_strict_replication_raises_on_shortfall(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                strict_replication=True, region_size=64 * 1024)
    try:
        write_file(c, "/ok", b"k" * 10_000)      # both servers up: fine
        kill_server(c, 1)
        with pytest.raises(StorageError):
            write_file(c, "/bad", b"b" * 10_000)
        assert len(c.repair_queue) > 0           # ticket filed before raise
    finally:
        c.close()


def test_ticket_parsing():
    t = ticket_from_placement(("region", 12, 3), reason="degraded-store")
    assert t == RepairTicket(12, 3, None, "degraded-store")
    t = ticket_from_placement(("gc-spill", 5, 0))
    assert (t.inode_id, t.region_idx) == (5, 0)
    assert ticket_from_placement(("something", "else")) is None


def test_knob_validation(tmp_path):
    with pytest.raises(ValueError):
        Cluster(n_servers=2, data_dir=str(tmp_path), io_deadline_s=0)
    with pytest.raises(ValueError):
        Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                min_read_replicas=3)
    with pytest.raises(ValueError):
        Cluster(n_servers=2, data_dir=str(tmp_path), min_read_replicas=0)


# --------------------------------------------------------- deadline / hedge
def test_deadline_hedged_retry_beats_slow_server(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                io_deadline_s=2.0, region_size=64 * 1024,
                block_cache_bytes=0)
    try:
        payload = b"h" * 8_000
        cl = write_file(c, "/h", payload)
        # Teach the EWMA what fast looks like, then make one server slow:
        # every retrieve on it stalls well past the hedge threshold.
        for _ in range(3):
            assert read_file(cl, "/h") == payload
        slow_sid = next(sid for sid, st in c.total_stats()["servers"].items()
                        if st["slices_read"] > 0)
        make_flaky_server(c, slow_sid, {}, slow_every_n=1, delay_s=0.6)
        cl2 = c.client()
        assert read_file(cl2, "/h") == payload   # hedge to the fast replica
        snap = c.health.snapshot()
        assert snap["hedged_rounds"] >= 1
        assert snap["deadline_timeouts"] == 0    # hedge won, no timeout
    finally:
        c.close()


def test_deadline_timeout_recorded_not_fatal(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                io_deadline_s=0.2, region_size=64 * 1024,
                block_cache_bytes=0)
    try:
        payload = b"t" * 8_000
        cl = write_file(c, "/t", payload)
        for _ in range(3):
            assert read_file(cl, "/t") == payload
        # EVERY replica slow beyond the deadline: the hedge cannot save the
        # round, both attempts are abandoned, and the walk exhausts with a
        # typed error whose cause chain is the deadline.
        for sid in list(c.servers):
            make_flaky_server(c, sid, {}, slow_every_n=1, delay_s=0.5)
        with pytest.raises(ReplicaExhausted):
            read_file(c.client(), "/t")
        snap = c.health.snapshot()
        assert snap["deadline_timeouts"] >= 1
        # Slow is not dead: neither server was reported to the coordinator.
        assert all(c.servers[sid].alive for sid in c.servers)
    finally:
        c.close()


def test_latency_injection_is_deterministic(tmp_path):
    c = Cluster(n_servers=1, data_dir=str(tmp_path), block_cache_bytes=0)
    try:
        flaky = make_flaky_server(c, 0, {}, slow_every_n=3, delay_s=0.0)
        cl = write_file(c, "/s", b"s" * 1000)
        for _ in range(5):
            read_file(cl, "/s")
        # Call numbering is per-op (shared with ``fail_on``): every 3rd
        # call of each intercepted op sleeps, nothing else does.
        assert sum(flaky.calls.values()) > 0
        assert flaky.delayed == sum(n // 3 for n in flaky.calls.values())
    finally:
        c.close()


def test_latency_injection_validates_knob(tmp_path):
    c = Cluster(n_servers=1, data_dir=str(tmp_path))
    try:
        with pytest.raises(ValueError):
            make_flaky_server(c, 0, {}, slow_every_n=0)
    finally:
        c.close()


# ------------------------------------------------------------- repair plane
def test_repair_restores_replication_after_kill(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    try:
        files = {f"/r{i}": bytes([i]) * 25_000 for i in range(6)}
        cl = c.client()
        for path, payload in files.items():
            with cl.open_file(path, "w") as f:
                f.write(payload)
        kill_server(c, 2)
        daemon = RepairDaemon(c)
        before = daemon.verify()
        assert not before["replication_restored"]
        assert before["lost"] == 0               # replication saved the data
        daemon.repair_pass(full_scan=True)
        after = daemon.verify()
        assert after["replication_restored"], after
        assert after["lost"] == 0
        assert c.repair_stats.snapshot()["replicas_created"] > 0
        # Byte-identity after repair, from a fresh client (no stale caches).
        cl2 = c.client()
        for path, payload in files.items():
            with cl2.open_file(path, "r") as f:
                assert f.read() == payload, path
        # And the repaired sets survive the original server staying dead
        # while ANOTHER server (one of the repair targets) restarts.
        restart_server(c, 2)
        assert daemon.verify()["replication_restored"]
    finally:
        c.close()


def test_repair_ticket_path_without_full_scan(tmp_path):
    """Reads that fail over past a dead replica file an inode-wide ticket,
    and the ticket path alone (no periodic scan) restores replication."""
    c = Cluster(n_servers=3, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024, block_cache_bytes=0)
    try:
        cl = c.client()
        payload = b"tk" * 10_000
        with cl.open_file("/tk", "w") as f:
            f.write(payload)
        kill_server(c, 0)
        with cl.open_file("/tk", "r") as f:      # succeeds via failover...
            assert f.read() == payload
        # ...but if a replica was on the corpse, a ticket was filed.
        tickets = [t for t in c.repair_queue.drain()]
        for t in tickets:                        # put them back
            c.repair_queue.put(t)
        daemon = RepairDaemon(c)
        summary = daemon.repair_pass(full_scan=False)
        if tickets:
            assert summary["tickets"] > 0
            # The ticketed inode is fully re-replicated by the ticket path
            # alone — no metadata-wide scan needed for fresh damage.
            for t in tickets:
                for key in daemon._walk_regions():
                    if key[0] != t.inode_id:
                        continue
                    rd = c.kv.get("regions", key)
                    for e in rd.entries:
                        live = [p for p in e.ptrs
                                if c.servers[p.server_id].alive]
                        assert len(live) >= 2, (key, e)
        # Other inodes (e.g. directory data never read) are the periodic
        # scan's job — after one full scan the whole store is healed.
        daemon.repair_pass(full_scan=True)
        assert daemon.verify()["replication_restored"]
    finally:
        c.close()


def test_repair_preserves_hot_cache_reads(tmp_path):
    """After a crash + re-replication, hot re-reads through the SHARED
    block cache and plan cache (lease cluster) stay byte-identical — the
    canonical-pointer rule: stable when replica 0 survived, inode dropped
    from the shared caches when it did not."""
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024, lease_ttl=30.0)
    try:
        files = {f"/hc{i}": bytes([64 + i]) * 30_000 for i in range(6)}
        cl = c.client()
        for path, payload in files.items():
            with cl.open_file(path, "w") as f:
                f.write(payload)
        # Warm the shared caches.
        for path, payload in files.items():
            with cl.open_file(path, "r") as f:
                assert f.read() == payload
        assert len(c.shared_block_cache) > 0
        kill_server(c, 1)
        daemon = RepairDaemon(c)
        daemon.repair_pass(full_scan=True)
        assert daemon.verify()["replication_restored"]
        # Hot re-reads through the same client and caches: byte-identical.
        for path, payload in files.items():
            with cl.open_file(path, "r") as f:
                assert f.read() == payload, path
        # Now lose a repair target too — surviving copies still serve.
        stats = c.repair_stats.snapshot()
        assert stats["extents_repaired"] > 0
    finally:
        c.close()


def test_repair_daemon_background_thread(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=2,
                region_size=64 * 1024)
    try:
        cl = c.client()
        with cl.open_file("/bg", "w") as f:
            f.write(b"bg" * 10_000)
        daemon = RepairDaemon(c, scan_every=1).start(interval_s=0.01)
        kill_server(c, 0)
        deadline_verify = RepairDaemon(c)
        for _ in range(300):
            if deadline_verify.verify()["replication_restored"]:
                break
            import time
            time.sleep(0.01)
        assert deadline_verify.verify()["replication_restored"]
        daemon.stop()
        daemon.stop()                            # idempotent
    finally:
        c.close()


def test_subtract_interval():
    from repro.core.repair import _subtract_interval
    assert _subtract_interval([(0, 10)], 3, 5) == [(0, 3), (5, 10)]
    assert _subtract_interval([(0, 10)], 0, 10) == []
    assert _subtract_interval([(0, 4), (6, 10)], 2, 8) == [(0, 2), (8, 10)]
    assert _subtract_interval([(0, 4)], 8, 9) == [(0, 4)]
    assert _subtract_interval([], 0, 5) == []


def test_unreplicated_loss_is_detected_and_counted(tmp_path):
    """With replication=1 a server kill IS data loss: repair has no source
    copy, ``unrepairable`` counts the visible extents, and verify reports
    them as lost instead of pretending the scan was clean."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=1,
                region_size=64 * 1024)
    try:
        cl = c.client()
        for i in range(8):                       # lands on both servers
            with cl.open_file(f"/u{i}", "w") as f:
                f.write(bytes([i]) * 5_000)
        kill_server(c, 0)
        daemon = RepairDaemon(c)
        daemon.repair_pass(full_scan=True)
        v = daemon.verify()
        assert v["lost"] > 0
        assert not v["replication_restored"]
        assert c.repair_stats.snapshot()["unrepairable"] > 0
    finally:
        c.close()


def test_cluster_close_is_idempotent(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path))
    daemon = RepairDaemon(c).start(interval_s=0.01)
    c.close()
    c.close()                                    # second close: no-op
    assert daemon._thread is None                # daemon was stopped
