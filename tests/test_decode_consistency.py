"""Prefill-vs-decode equivalence: for every architecture with a decode
path, running the full-sequence forward must produce the same logits at
position t as feeding tokens one-by-one through decode_step with the cache.
This is the property that validates every cache implementation (ring-buffer
KV, SSM state, conv state, mLSTM/sLSTM state, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model

B, S = 2, 16

ARCHS = ["smollm-360m", "qwen2-7b", "command-r-35b", "olmoe-1b-7b",
         "granite-moe-3b-a800m", "zamba2-1.2b", "xlstm-1.3b",
         "whisper-medium"]


def _cfg(arch):
    # fp32 compute for tight comparisons
    cfg = get_smoke_config(arch).replace(compute_dtype="float32",
                                         max_seq=S)
    if cfg.moe is not None:
        # capacity-based MoE drops tokens batch-dependently; equivalence
        # holds only in the dropless regime (capacity = n_tokens)
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = _cfg(arch)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(5), (B, cfg.encdec.encoder_seq, cfg.d_model))
    full = model.forward(params, batch)            # [B, S, V]

    cache = model.init_cache(B, max_len=S)
    if cfg.encdec is not None:
        from repro.models import whisper as W
        enc = W.encode(params, batch["frames"], cfg)
        cache["cross"] = W.make_cross_kv(params, enc, cfg)

    step = jax.jit(lambda p, c, bt: model.decode_step(p, c, bt))
    outs = []
    for t in range(S):
        logits, cache = step(params, cache,
                             {"tokens": tokens[:, t:t + 1],
                              "pos": jnp.full((B,), t, jnp.int32)})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_cache():
    """Dense decode with a window smaller than the sequence: the ring
    buffer must overwrite old slots and mask by position."""
    cfg = _cfg("smollm-360m").replace(sliding_window=6)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    full = model.forward(params, {"tokens": tokens})

    cache = model.init_cache(B, max_len=S)      # width = window = 6
    assert cache["k"].shape[2] == 6
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, {"tokens": tokens[:, t:t + 1],
                            "pos": jnp.full((B,), t, jnp.int32)})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_vlm_prefix_then_decode():
    """LLaVA: image patches + prompt prefix via forward, then decode
    continues — logits must stay finite and shaped."""
    cfg = _cfg("llava-next-34b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    patches = jax.random.normal(
        jax.random.PRNGKey(1), (B, cfg.vlm.num_patches, cfg.vlm.vision_dim))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab)
    logits = model.forward(params, {"tokens": tokens,
                                    "patch_embeds": patches})
    assert logits.shape[1] == S                  # image positions stripped
    assert bool(jnp.all(jnp.isfinite(logits)))
