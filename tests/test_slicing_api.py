"""The file slicing API: yank/paste/punch/append/concat/copy (Table 1).

The defining property throughout: slicing ops move ZERO data bytes — we
assert on the storage servers' I/O counters, the paper's Table 2 metric.
"""
import pytest

from repro.core import Cluster, SEEK_SET


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=4096)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def server_write_bytes(cluster):
    return sum(s.stats.bytes_written for s in cluster.servers.values())


def server_read_bytes(cluster):
    return sum(s.stats.bytes_read for s in cluster.servers.values())


def make_file(fs, path, payload):
    fd = fs.open(path, "w")
    fs.write(fd, payload)
    fs.close(fd)
    return payload


def read_file(fs, path):
    fd = fs.open(path, "r")
    data = fs.read(fd)
    fs.close(fd)
    return data


def test_yank_returns_pointers_without_reading(cluster, fs):
    payload = make_file(fs, "/src", b"0123456789" * 100)
    fd = fs.open("/src", "r")
    reads_before = server_read_bytes(cluster)
    extents = fs.yank(fd, 500)
    assert server_read_bytes(cluster) == reads_before, \
        "yank without data must incur no storage reads"
    assert sum(e.length for e in extents) == 500
    assert fs.tell(fd) == 500
    fs.close(fd)


def test_yank_with_data(fs):
    payload = make_file(fs, "/src", b"abcdef" * 100)
    fd = fs.open("/src", "r")
    extents, data = fs.yank(fd, 300, want_data=True)
    assert data == payload[:300]
    fs.close(fd)


def test_paste_moves_no_data(cluster, fs):
    payload = make_file(fs, "/src", bytes(range(256)) * 8)  # 2 KB
    fd = fs.open("/src", "r")
    extents = fs.yank(fd, 2048)
    fs.close(fd)

    fd = fs.open("/dst", "w")      # creation writes a dirent record; the
    writes_before = server_write_bytes(cluster)   # paste itself moves nothing
    fs.paste(fd, extents)
    fs.close(fd)
    assert server_write_bytes(cluster) == writes_before, \
        "paste is metadata-only"
    assert read_file(fs, "/dst") == payload


def test_paste_rearranges_records(cluster, fs):
    """The sort primitive: reorder records via yank+paste with zero writes."""
    rec = 128
    records = [bytes([i]) * rec for i in (3, 1, 0, 2)]
    make_file(fs, "/in", b"".join(records))
    fd = fs.open("/in", "r")
    exts = []
    for i in range(4):
        fs.seek(fd, i * rec)
        exts.append(fs.yank(fd, rec))
    fs.close(fd)
    order = [2, 1, 3, 0]               # sorted by key byte
    fd = fs.open("/out", "w")
    writes_before = server_write_bytes(cluster)
    for i in order:
        fs.paste(fd, exts[i])
    fs.close(fd)
    assert server_write_bytes(cluster) == writes_before
    assert read_file(fs, "/out") == b"".join(records[i] for i in order)


def test_concat_is_metadata_only(cluster, fs):
    a = make_file(fs, "/a", b"A" * 3000)
    b = make_file(fs, "/b", b"B" * 5000)
    c = make_file(fs, "/c", b"C" * 100)
    before_w = server_write_bytes(cluster)
    before_r = server_read_bytes(cluster)
    fs.concat(["/a", "/b", "/c"], "/all")
    # creating /all appends one dirent record (metadata bookkeeping); the
    # 8.1 KB of file content itself moves zero bytes
    assert server_write_bytes(cluster) - before_w < 100
    assert server_read_bytes(cluster) == before_r
    assert read_file(fs, "/all") == a + b + c


def test_copy_then_diverge(fs):
    payload = make_file(fs, "/orig", b"original-content" * 10)
    fs.copy("/orig", "/clone")
    assert read_file(fs, "/clone") == payload
    # copies share slices but have independent metadata: mutate the clone
    fd = fs.open("/clone", "rw")
    fs.pwrite(fd, b"XXXX", 0)
    fs.close(fd)
    assert read_file(fs, "/orig") == payload
    assert read_file(fs, "/clone")[:4] == b"XXXX"


def test_punch_zeroes_and_frees(cluster, fs):
    payload = make_file(fs, "/p", b"Z" * 1000)
    fd = fs.open("/p", "rw")
    fs.seek(fd, 100)
    writes_before = server_write_bytes(cluster)
    fs.punch(fd, 200)
    assert server_write_bytes(cluster) == writes_before
    assert fs.tell(fd) == 300
    fs.close(fd)
    data = read_file(fs, "/p")
    assert data[:100] == b"Z" * 100
    assert data[100:300] == b"\x00" * 200
    assert data[300:] == b"Z" * 700


def test_append_slices(fs):
    make_file(fs, "/x", b"12345")
    make_file(fs, "/y", b"67890")
    fd = fs.open("/y", "r")
    exts = fs.yank(fd, 5)
    fs.close(fd)
    fd = fs.open("/x", "rw")
    fs.append_slices(fd, exts)
    fs.close(fd)
    assert read_file(fs, "/x") == b"1234567890"


def test_yank_paste_across_region_boundaries(cluster, fs):
    """region_size=4096; a 10 KB file spans 3 regions."""
    payload = make_file(fs, "/big", bytes(range(256)) * 40)  # 10240
    fd = fs.open("/big", "r")
    fs.seek(fd, 3000)
    exts = fs.yank(fd, 5000)           # crosses two boundaries
    fs.close(fd)
    fd = fs.open("/piece", "w")
    fs.paste(fd, exts)
    fs.close(fd)
    assert read_file(fs, "/piece") == payload[3000:8000]


def test_concat_empty_and_missing(fs):
    make_file(fs, "/only", b"data")
    from repro.core import NotFound
    with pytest.raises(NotFound):
        fs.concat(["/only", "/missing"], "/out2")
