"""Transactional checkpointing: atomic commit, incremental, reshard."""
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.core import Cluster, NotFound


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=256 * 1024)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def tree_of(step):
    rng = np.random.default_rng(step)
    return {
        "params": {
            "embed": rng.standard_normal((64, 32)).astype(np.float32),
            "layers": {"w1": rng.standard_normal((32, 128)).astype(np.float32),
                       "b1": np.zeros(128, dtype=np.float32)},
        },
        "opt": {"mu": rng.standard_normal((64, 32)).astype(np.float32),
                "count": np.int32(step)},
    }


def trees_equal(a, b):
    np.testing.assert_array_equal(a["params"]["embed"], b["params"]["embed"])
    np.testing.assert_array_equal(a["params"]["layers"]["w1"],
                                  b["params"]["layers"]["w1"])
    np.testing.assert_array_equal(a["opt"]["mu"], b["opt"]["mu"])
    assert int(a["opt"]["count"]) == int(b["opt"]["count"])


def test_save_restore_roundtrip(fs):
    mgr = CheckpointManager(fs)
    t = tree_of(1)
    mgr.save(1, t)
    got = mgr.restore(t)
    trees_equal(t, got)
    assert mgr.latest_step() == 1


def test_latest_flips_atomically(fs):
    mgr = CheckpointManager(fs)
    mgr.save(1, tree_of(1))
    mgr.save(2, tree_of(2))
    assert mgr.latest_step() == 2
    got = mgr.restore(tree_of(0))          # template only provides structure
    trees_equal(tree_of(2), got)
    # older checkpoint remains addressable
    got1 = mgr.restore(tree_of(0), step=1)
    trees_equal(tree_of(1), got1)


def test_reader_never_sees_partial_checkpoint(cluster, fs):
    """Kill the writer mid-save: latest still points at the old manifest."""
    mgr = CheckpointManager(fs)
    mgr.save(1, tree_of(1))

    class Boom(Exception):
        pass

    t2 = tree_of(2)
    # sabotage: fail after some data files are written but before commit
    orig_commit = mgr._commit
    def failing_commit(*a, **k):
        raise Boom()
    mgr._commit = failing_commit
    with pytest.raises(Boom):
        mgr.save(2, t2)
    mgr._commit = orig_commit

    reader = CheckpointManager(cluster.client())
    assert reader.latest_step() == 1
    trees_equal(tree_of(1), reader.restore(tree_of(0)))


def test_incremental_save_shares_unchanged_leaves(cluster, fs):
    mgr = CheckpointManager(fs)
    t1 = tree_of(1)
    mgr.save(1, t1)
    # step 2: only opt.count changes
    t2 = {"params": t1["params"],
          "opt": {"mu": t1["opt"]["mu"], "count": np.int32(2)}}
    writes_before = sum(s.stats.bytes_written
                        for s in cluster.servers.values())
    stats = mgr.save(2, t2, prev_step=1)
    writes_after = sum(s.stats.bytes_written
                       for s in cluster.servers.values())
    assert stats["leaves_shared"] == 4      # embed, w1, b1, mu
    assert stats["bytes_written"] == 4      # just the int32 count
    # physical writes ≈ dirents + manifest, far below the 41 KB of params
    assert writes_after - writes_before < 4000
    trees_equal(t2, mgr.restore(tree_of(0)))


def test_multihost_sharded_save(fs):
    mgr = CheckpointManager(fs)
    big = {"w": np.arange(100_000, dtype=np.float32)}   # 400 KB → sharded
    for host in range(4):
        mgr.save(5, big, host_id=host, num_hosts=4)
    got = mgr.restore({"w": None})
    np.testing.assert_array_equal(got["w"], big["w"])
    man = mgr.read_manifest(5)
    assert man["leaves"]["w"]["shards"] == 4


def test_zero_copy_reshard(cluster, fs):
    mgr = CheckpointManager(fs)
    big = {"w": np.arange(50_000, dtype=np.float32),
           "small": np.float32(3.0)}
    for host in range(2):
        mgr.save(1, big, host_id=host, num_hosts=2)
    writes_before = sum(s.stats.bytes_written
                        for s in cluster.servers.values())
    mgr.reshard(1, new_shards=4, dst_step=2)
    writes_after = sum(s.stats.bytes_written
                       for s in cluster.servers.values())
    # resharding 200 KB of data writes only manifest+dirent metadata
    assert writes_after - writes_before < 8000
    got = mgr.restore({"w": None, "small": None}, step=2)
    np.testing.assert_array_equal(got["w"], big["w"])
    man = mgr.read_manifest(2)
    assert man["leaves"]["w"]["shards"] == 4


def test_retention_unlinks_old_steps(fs):
    mgr = CheckpointManager(fs, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"x": np.full(10, step, np.float32)})
    assert mgr.list_steps() == [3, 4]
    with pytest.raises(NotFound):
        mgr.restore({"x": None}, step=1)


def test_async_checkpointer(fs):
    mgr = CheckpointManager(fs)
    ck = AsyncCheckpointer(mgr)
    t = tree_of(7)
    ck.save(7, t)
    # trainer mutates its arrays immediately — snapshot must protect us
    t["params"]["embed"][:] = -1
    ck.wait()
    got = mgr.restore(tree_of(0))
    assert not np.allclose(got["params"]["embed"], -1)
    assert mgr.latest_step() == 7


def test_restore_missing_raises(fs):
    mgr = CheckpointManager(fs)
    with pytest.raises(NotFound):
        mgr.restore({"x": None})
