"""Three-tier garbage collection (paper §2.8)."""
import os
import tempfile

import pytest

from repro.core import Cluster, GarbageCollector
from repro.core.inode import RegionData, region_key
from repro.core.testing import LockOrderWatchdog


def _fs_supports_sparse_files() -> bool:
    """Tier-3 reclaim is measured via ``st_blocks``, which only shrinks if
    the filesystem turns seek-past-gaps into holes (9p, for one, does not)."""
    with tempfile.NamedTemporaryFile() as tmp:
        tmp.seek(1 << 20)
        tmp.write(b"x")
        tmp.flush()
        st = os.stat(tmp.name)
        return st.st_blocks * 512 < st.st_size


requires_sparse = pytest.mark.skipif(
    not _fs_supports_sparse_files(),
    reason="filesystem does not support sparse files (st_blocks cannot "
           "shrink), so physical reclaim is unmeasurable")


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=3, data_dir=str(tmp_path), replication=1,
                region_size=64 * 1024, num_backing_files=2)
    yield c
    c.close()


def make_file(fs, path, payload):
    fd = fs.open(path, "w")
    fs.write(fd, payload)
    fs.close(fd)


def read_file(fs, path):
    fd = fs.open(path, "r")
    data = fs.read(fd)
    fs.close(fd)
    return data


def region_entry_count(cluster, fs, path):
    ino = fs.stat(path)["inode"]
    rd: RegionData = cluster.kv.get("regions", region_key(ino, 0))
    return len(rd.entries) if rd else 0


def test_tier1_compaction_shrinks_metadata(cluster):
    fs = cluster.client()
    fd = fs.open("/frag", "w")
    for i in range(50):                       # 50 sequential appends
        fs.append(fd, bytes([i]) * 100)
    fs.close(fd)
    before = region_entry_count(cluster, fs, "/frag")
    assert before == 50
    content = read_file(fs, "/frag")

    gc = GarbageCollector(cluster)
    ino = fs.stat("/frag")["inode"]
    r = gc.compact_region(ino, 0)
    assert not r["skipped"]
    after = region_entry_count(cluster, fs, "/frag")
    # locality-aware placement sends sequential appends to one backing file,
    # so compaction merges them into very few pointers (§2.7)
    assert after < before / 5
    assert read_file(fs, "/frag") == content, "compaction preserves content"


def test_tier1_compaction_drops_overwritten(cluster):
    fs = cluster.client()
    fd = fs.open("/ovw", "w")
    fs.write(fd, b"A" * 1000)
    for _ in range(10):
        fs.seek(fd, 0)
        fs.write(fd, b"B" * 1000)            # 10 full overwrites
    fs.close(fd)
    content = read_file(fs, "/ovw")
    gc = GarbageCollector(cluster)
    ino = fs.stat("/ovw")["inode"]
    r = gc.compact_region(ino, 0)
    assert r["after"] <= 2
    assert read_file(fs, "/ovw") == content


def test_tier2_spill_to_slice(cluster):
    """Random writes defeat merging; a fragmented list spills to a slice."""
    fs = cluster.client()
    fd = fs.open("/rand", "w")
    import random
    rng = random.Random(7)
    fs.write(fd, b"\x00" * 8000)
    for i in range(120):
        off = rng.randrange(0, 7900) & ~1    # scattered small writes
        fs.pwrite(fd, bytes([i % 256]) * 7, off)
    fs.close(fd)
    content = read_file(fs, "/rand")
    gc = GarbageCollector(cluster, spill_threshold=16)
    ino = fs.stat("/rand")["inode"]
    r = gc.compact_region(ino, 0)
    assert r["spilled"], "fragmented region should spill (tier 2)"
    rd = cluster.kv.get("regions", region_key(ino, 0))
    assert rd.indirect is not None and rd.entries == ()
    assert read_file(fs, "/rand") == content
    # and the file still accepts appends after the spill
    fd = fs.open("/rand", "rw")
    fs.append(fd, b"tail")
    fs.close(fd)
    assert read_file(fs, "/rand") == content + b"tail"


@requires_sparse
def test_tier3_storage_gc_reclaims_deleted_files(cluster, tmp_path):
    fs = cluster.client()
    payload = b"x" * 200_000
    make_file(fs, "/dead", payload)
    make_file(fs, "/alive", b"y" * 50_000)
    usage_before = sum(s.real_usage() for s in cluster.servers.values())
    fs.unlink("/dead")

    gc = GarbageCollector(cluster)
    # two-scan rule: the first pass must not collect anything
    r1 = gc.storage_gc_pass()
    assert r1["reclaimed"] == 0
    r2 = gc.storage_gc_pass()
    assert r2["reclaimed"] > 0, "second consecutive scan may collect"
    usage_after = sum(s.real_usage() for s in cluster.servers.values())
    assert usage_after < usage_before
    assert read_file(fs, "/alive") == b"y" * 50_000, \
        "live data must survive GC"


def test_tier3_preserves_overwritten_files_content(cluster):
    fs = cluster.client()
    fd = fs.open("/f", "w")
    fs.write(fd, b"old" * 10_000)
    fs.seek(fd, 0)
    fs.write(fd, b"new" * 10_000)           # 30 KB garbage behind
    fs.close(fd)
    gc = GarbageCollector(cluster)
    gc.full_cycle()
    gc.full_cycle()
    assert read_file(fs, "/f") == b"new" * 10_000


def test_gc_lists_live_in_reserved_directory(cluster):
    fs = cluster.client()
    make_file(fs, "/somefile", b"z" * 1000)
    gc = GarbageCollector(cluster)
    gc.storage_gc_pass()
    names = fs.listdir("/.wtf-gc")
    assert names == [f"server-{sid:03d}" for sid in sorted(cluster.servers)]
    # the lists are ordinary WTF files the servers read via the client lib
    ptrs = gc.read_live_list(0)
    assert all(p.server_id == 0 for p in ptrs)


def test_appends_racing_sparse_rewrite_lose_nothing(cluster):
    """The tier-3 sparse rewrite swaps a backing file's descriptor; the
    reservation protocol must park new appends and drain in-flight writes
    around the swap, or bytes land in the replaced inode and vanish.
    Appenders hammer the log while GC rewrites garbage-heavy backing
    files; every appended record must survive, byte for byte."""
    import threading

    fs = cluster.client()
    # Manufacture garbage on every backing file so gc_pass actually
    # sparse-rewrites: write then fully overwrite a large file, twice.
    for _ in range(2):
        fd = fs.open("/churn", "w")
        fs.write(fd, b"old" * 30_000)
        fs.seek(fd, 0)
        fs.write(fd, b"new" * 30_000)
        fs.close(fd)
    make_file(fs, "/safe", b"")

    gc = GarbageCollector(cluster)
    gc.storage_gc_pass()                   # first scan (two-scan rule)
    # The witness covers the storage locks: if the rewrite ever grabbed a
    # backing-file lock above the directory lock (or inverted against the
    # KV plane), the race below would raise instead of losing bytes.
    assert LockOrderWatchdog.enabled()
    srv = next(iter(cluster.servers.values()))
    assert LockOrderWatchdog.is_witnessed(srv._files_lock)
    assert all(LockOrderWatchdog.is_witnessed(bf.lock)
               for bf in srv._files.values())
    stop = threading.Event()
    N, M = 3, 40

    def appender(i):
        c = cluster.client()
        fd = c.open("/safe", "a")
        for j in range(M):
            c.write(fd, f"<{i}:{j:04d}>".encode())
        c.close(fd)

    def collector():
        while not stop.is_set():
            gc.storage_gc_pass()           # second+ scans: rewrites

    gt = threading.Thread(target=collector)
    threads = [threading.Thread(target=appender, args=(i,))
               for i in range(N)]
    gt.start()
    for t in threads: t.start()
    for t in threads: t.join()
    stop.set()
    gt.join()

    data = read_file(fs, "/safe")
    recs = sorted(data.decode().replace("><", ">|<").split("|"))
    assert len(data) == N * M * 8, "appended bytes lost during GC rewrite"
    expect = sorted(f"<{i}:{j:04d}>" for i in range(N) for j in range(M))
    assert recs == expect
    assert read_file(fs, "/churn") == b"new" * 30_000
    LockOrderWatchdog.assert_clean()
