"""Property tests for the O(n log n) overlay rewrite: byte-exact
equivalence with a brute-force byte-map oracle, on adversarial extent
lists (the rewrite replaced the original O(n²) algorithm — §Perf A1).
Plus differential properties for the metadata-plane fast path: the
incremental resolved index (``overlay_extend``) and the commit-time
compacting commute (``inode.CompactRegion``) against full
``overlay()``/``compact()``."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.inode import CompactRegion, RegionData
from repro.core.slicing import (Extent, SlicePointer, compact, overlay,
                                overlay_extend, slice_range)


def _mk_extent(i, offset, length):
    return Extent(offset, length,
                  (SlicePointer(0, f"f{i}", 1000 * i, length),))


@st.composite
def extent_lists(draw):
    n = draw(st.integers(0, 40))
    out = []
    for i in range(n):
        off = draw(st.integers(0, 200))
        ln = draw(st.integers(1, 60))
        out.append(_mk_extent(i, off, ln))
    return out


def _oracle(entries, size=300):
    """Byte map: which (entry index, byte-within-entry) is visible."""
    m = np.full(size, -1, np.int64)
    for i, e in enumerate(entries):
        for b in range(e.length):
            m[e.offset + b] = i * 10_000 + b
    return m


def _materialize(extents, size=300):
    m = np.full(size, -1, np.int64)
    for ext in extents:
        if ext.is_zero:
            continue
        p = ext.ptrs[0]
        i = int(p.backing_file[1:])
        start_in_slice = p.offset - 1000 * i
        for b in range(ext.length):
            m[ext.offset + b] = i * 10_000 + start_in_slice + b
    return m


@given(extent_lists())
@settings(max_examples=200, deadline=None)
def test_overlay_matches_byte_oracle(entries):
    got = overlay(entries)
    # non-overlapping + sorted
    for a, b in zip(got, got[1:]):
        assert a.end <= b.offset
    np.testing.assert_array_equal(_materialize(got), _oracle(entries))


@given(extent_lists())
@settings(max_examples=100, deadline=None)
def test_compact_preserves_bytes(entries):
    np.testing.assert_array_equal(_materialize(compact(entries)),
                                  _oracle(entries))


def _oracle_z(entries, size=300):
    """Byte map like ``_oracle`` but zero (punch) extents mark -2."""
    m = np.full(size, -1, np.int64)
    for i, e in enumerate(entries):
        if e.is_zero:
            m[e.offset:e.end] = -2
        else:
            for b in range(e.length):
                m[e.offset + b] = i * 10_000 + b
    return m


def _materialize_z(extents, size=300):
    m = np.full(size, -1, np.int64)
    for ext in extents:
        if ext.is_zero:
            m[ext.offset:ext.end] = -2
            continue
        p = ext.ptrs[0]
        i = int(p.backing_file[1:])
        start_in_slice = p.offset - 1000 * i
        for b in range(ext.length):
            m[ext.offset + b] = i * 10_000 + start_in_slice + b
    return m


@st.composite
def extent_lists_with_zeros(draw):
    """Like ``extent_lists`` but ~1 in 5 entries is a punch (zero extent)."""
    n = draw(st.integers(0, 40))
    out = []
    for i in range(n):
        off = draw(st.integers(0, 200))
        ln = draw(st.integers(1, 60))
        if draw(st.booleans()) and draw(st.booleans()) \
                and draw(st.booleans()):
            out.append(Extent(off, ln, ()))
        else:
            out.append(_mk_extent(i, off, ln))
    return out


@given(extent_lists_with_zeros(), st.integers(0, 40))
@settings(max_examples=200, deadline=None)
def test_overlay_extend_structurally_equals_overlay(entries, split_at):
    """The resolved index's delta update must land on the STRUCTURALLY
    identical canonical form full ``overlay`` produces — plans and op
    digests must not depend on which path resolved the region."""
    split = min(split_at, len(entries))
    base = overlay(entries[:split])
    assert overlay_extend(base, entries[split:]) == overlay(entries)


@given(extent_lists_with_zeros(), st.integers(1, 20))
@settings(max_examples=150, deadline=None)
def test_compact_region_commute_equals_compact(entries, threshold):
    """The commit-time compacting commute is byte-identical to full
    ``compact()`` (including punch extents), preserves ``end``, and
    no-ops below its threshold."""
    rd = RegionData(tuple(entries), end=300)
    new, _ = CompactRegion(threshold).apply(rd)
    if len(entries) < threshold:
        assert new is rd
    else:
        assert new.end == rd.end
        np.testing.assert_array_equal(_materialize_z(new.entries),
                                      _materialize_z(compact(entries)))
        np.testing.assert_array_equal(_materialize_z(new.entries),
                                      _oracle_z(entries))


@given(extent_lists(), st.integers(0, 250), st.integers(1, 80))
@settings(max_examples=100, deadline=None)
def test_slice_range_tiles_exactly(entries, start, length):
    out = slice_range(entries, start, length)
    # tiles [start, start+length) exactly, in order
    cursor = start
    for ext in out:
        assert ext.offset == cursor
        cursor = ext.end
    assert cursor == start + length
    want = _oracle(entries, 400)[start:start + length]
    got = _materialize(out, 400)[start:start + length]
    # holes read as zeros (-1 in the oracle stays -1 via zero extents)
    np.testing.assert_array_equal(got, want)
