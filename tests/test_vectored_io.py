"""Vectored, handle-based I/O API + batched slice-fetch scheduler.

Covers:
  * equivalence: ``readv`` over arbitrary ranges == concatenation of scalar
    ``pread`` results (randomized property test);
  * atomicity: a vectored batch is all-or-nothing under injected KV
    conflicts (§2.6 retry layer exhaustion leaves no trace);
  * coalescing: ``readv`` over N disjoint ranges issues fewer storage
    rounds than N — adjacent/near-adjacent slice pointers collapse into
    one covering retrieval per (server, backing-file) run;
  * the ``WtfFile`` handle surface and ``open_file`` lifecycle;
  * vectored ops participating in explicit multi-op transactions;
  * failover: batched fetches survive a storage-server crash.
"""
import random

import pytest

from repro.core import Cluster, TransactionAborted, WtfFile
from repro.util import jsonio


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=1 << 20)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def write_file(fs, path, data):
    with fs.open_file(path, "w") as f:
        f.write(data)


# ---------------------------------------------------------------- equivalence
def test_readv_matches_scalar_preads_property(fs):
    rng = random.Random(1234)
    # file assembled from several writes so it spans multiple slices
    data = bytearray()
    with fs.open_file("/f", "w") as f:
        for _ in range(16):
            chunk = bytes(rng.getrandbits(8) for _ in range(
                rng.randrange(1, 40_000)))
            f.write(chunk)
            data.extend(chunk)
    size = len(data)
    with fs.open_file("/f") as f:
        for _ in range(25):
            ranges = [(rng.randrange(0, size),
                       rng.randrange(0, 60_000)) for _ in
                      range(rng.randrange(1, 12))]
            vec = f.readv(ranges)
            scalar = [f.pread(n, off) for off, n in ranges]
            assert vec == scalar
            # pread clamps at EOF; readv must clamp identically
            assert all(bytes(data[o:o + n]) == v
                       for (o, n), v in zip(ranges, vec))


def test_preadv_consecutive_chunks(fs):
    write_file(fs, "/f", b"abcdefghij")
    with fs.open_file("/f") as f:
        assert f.preadv([3, 4, 3], 0) == [b"abc", b"defg", b"hij"]
        assert f.preadv([4, 10], 8) == [b"ij", b""]     # clamped at EOF
        assert f.tell() == 0                            # positional


def test_writev_gather_and_single_slice(cluster, fs):
    cluster.reset_io_stats()
    with fs.open_file("/w", "w") as f:
        n = f.writev([b"aa", b"bbb", b"cccc"])
        assert n == 9 and f.tell() == 9
    stats = cluster.total_stats()
    created = sum(s["slices_created"]
                  for s in stats["servers"].values())
    assert created <= 2, "gather-write must not create one slice per chunk"
    with fs.open_file("/w") as f:
        assert f.read() == b"aabbbcccc"


def test_pwritev_positional(fs):
    write_file(fs, "/p", b"0" * 12)
    with fs.open_file("/p", "rw") as f:
        f.pwritev([b"XY", b"Z"], 4)
        assert f.tell() == 0
        assert f.read() == b"0000XYZ00000"


def test_yankv_pastev_equivalence(fs):
    write_file(fs, "/src", bytes(range(200)))
    with fs.open_file("/src") as f:
        batches = f.yankv([(10, 20), (150, 30), (0, 5)])
    with fs.open_file("/dst", "w") as f:
        n = f.pastev(batches)
        assert n == 55
    with fs.open_file("/dst") as f:
        assert f.read() == (bytes(range(10, 30)) + bytes(range(150, 180))
                            + bytes(range(5)))


# ------------------------------------------------------------------ atomicity
def test_vectored_write_batch_is_atomic_under_conflicts(cluster, fs):
    write_file(fs, "/a", b"before")
    with fs.open_file("/a", "rw") as f:
        # more injected aborts than MAX_RETRIES: the batch must fail as a
        # unit and leave file + fd state untouched
        cluster.kv.inject_aborts(fs.MAX_RETRIES + 1)
        with pytest.raises(TransactionAborted):
            f.writev([b"X" * 10, b"Y" * 10])
        cluster.kv.inject_aborts(0)
        assert f.tell() == 0, "fd offset must roll back with the batch"
        assert f.read() == b"before"

    # a recoverable number of conflicts: the retry layer commits the batch
    with fs.open_file("/a", "rw") as f:
        cluster.kv.inject_aborts(3)
        assert f.writev([b"XX", b"YY"]) == 4
        assert f.read() == b"re"        # offset advanced past the 4 bytes
    with fs.open_file("/a") as f:
        assert f.read() == b"XXYYre"


def test_pastev_batch_is_atomic_under_conflicts(cluster, fs):
    write_file(fs, "/src", b"s" * 100)
    write_file(fs, "/dst", b"d" * 10)
    with fs.open_file("/src") as f:
        batches = f.yankv([(0, 40), (40, 40)])
    with fs.open_file("/dst", "rw") as f:
        cluster.kv.inject_aborts(fs.MAX_RETRIES + 1)
        with pytest.raises(TransactionAborted):
            f.pastev(batches)
        cluster.kv.inject_aborts(0)
        assert f.tell() == 0
    assert fs.file_length("/dst") == 10, "no partial paste may be visible"


def test_vectored_ops_in_explicit_transaction(cluster, fs):
    write_file(fs, "/t1", b"1" * 64)
    with fs.transaction():
        with fs.open_file("/t2", "w") as f2:
            f2.writev([b"a" * 8, b"b" * 8])
        with fs.open_file("/t1") as f1:
            got = f1.readv([(0, 8), (56, 8)])
        assert got == [b"1" * 8, b"1" * 8]
    with fs.open_file("/t2") as f:
        assert f.read() == b"a" * 8 + b"b" * 8


# ----------------------------------------------------------------- coalescing
def test_readv_coalesces_adjacent_slice_fetches(cluster, fs):
    # ONE write -> one slice per replica; N disjoint in-file ranges then
    # dereference sub-pointers of that slice, which the scheduler must
    # coalesce into at most one round per (server, backing-file) run.
    payload = bytes(i & 0xFF for i in range(256 << 10))
    write_file(fs, "/big", payload)
    cluster.reset_io_stats()
    n_ranges = 16
    step = len(payload) // n_ranges
    ranges = [(i * step, 4096) for i in range(n_ranges)]
    before_batches = fs.stats.fetch_batches
    with fs.open_file("/big") as f:
        parts = f.readv(ranges)
    assert parts == [payload[o:o + n] for o, n in ranges]
    slices_read = cluster.total_stats()["slices_read"]
    assert slices_read < n_ranges, \
        f"expected coalescing: {slices_read} rounds for {n_ranges} ranges"
    assert fs.stats.fetch_batches - before_batches < n_ranges
    assert fs.stats.slices_coalesced >= n_ranges - slices_read


def test_scalar_reads_also_route_through_scheduler(cluster, fs):
    write_file(fs, "/s", b"z" * 1000)
    before = fs.stats.fetch_batches
    with fs.open_file("/s") as f:
        f.read()
    assert fs.stats.fetch_batches > before


def test_batched_fetch_survives_server_crash(cluster):
    clu = cluster
    fs2 = clu.client()
    payload = bytes(range(256)) * 512          # 128 KiB
    write_file(fs2, "/ft", payload)
    # crash a server the data does NOT live on is a no-op; crash each server
    # in turn and ensure reads still work whenever any replica remains --
    # with replication=1 the hosting server must stay up, so instead verify
    # the fallback path: fetch with a gap-coalesced plan after GC-free crash
    # of every *other* server.
    stats = clu.total_stats()["servers"]
    hosting = [sid for sid, s in stats.items() if s["bytes_written"] > 0]
    for sid in clu.servers:
        if sid not in hosting:
            clu.fail_server(sid)
    with fs2.open_file("/ft") as f:
        got = f.readv([(0, 4096), (64 << 10, 4096)])
    assert got == [payload[:4096], payload[64 << 10:(64 << 10) + 4096]]


# ------------------------------------------------------------------- handles
def test_open_file_handle_lifecycle(fs):
    with fs.open_file("/h", "w") as f:
        assert isinstance(f, WtfFile)
        assert not f.closed
        f.write(b"data")
        fd = f.fd
    assert f.closed
    with pytest.raises(Exception):
        fs.read(fd, 1)                  # fd is gone after handle close
    f.close()                           # double close is a no-op

    f = fs.open_file("/h")
    assert f.size() == 4
    assert f.read() == b"data"
    f.close()


def test_handle_seek_tell_append(fs):
    with fs.open_file("/h2", "w") as f:
        f.write(b"abc")
        f.append(b"def")
        f.seek(1)
        assert f.tell() == 1
        assert f.read(4) == b"bcde"


# ------------------------------------------------------------- record batches
def test_record_writer_append_many(fs):
    from repro.data.records import RecordFile, RecordWriter

    w = RecordWriter(fs, "/recs", 8)
    assert w.append_many([]) == -1              # no-op, no spurious append
    assert w.append_many([b"a" * 8, b"b" * 8, b"c" * 8]) == 2
    assert w.append(b"d" * 8) == 3
    spec = w.close()
    assert spec.count == 4
    rf = RecordFile(fs, "/recs", 8)
    assert rf.read_records_batch([0, 2, 3]) == [b"a" * 8, b"c" * 8, b"d" * 8]
    rf.close()


# -------------------------------------------------------------------- jsonio
def test_jsonio_roundtrip():
    obj = {"op": "add", "name": "x", "ino": 123, "l": [1, 2, 3]}
    raw = jsonio.dumps(obj)
    assert isinstance(raw, bytes)
    assert jsonio.loads(raw) == obj
    assert jsonio.loads(raw.decode()) == obj
