"""Fault-tolerant trainer: restart resumes (weights + data cursor agree),
retention works, loss improves on a learnable stream."""
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Cluster
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.data.records import write_token_shard
from repro.models import get_model
from repro.train import AdamWConfig, TrainHyper
from repro.train.trainer import Trainer, TrainerConfig

SEQ, BATCH = 32, 4


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=1)
    yield c
    c.close()


def _setup(cluster, steps, ckpt_every=10):
    fs = cluster.client()
    cfg = get_smoke_config("smollm-360m").replace(max_seq=SEQ)
    model = get_model(cfg)
    if not fs.exists("/corpus"):
        fs.mkdir("/corpus")
        rng = np.random.RandomState(0)
        toks = np.zeros(BATCH * (SEQ + 1) * 32, np.int32)
        for i in range(1, len(toks)):
            toks[i] = (toks[i - 1] * 31 + 7) % cfg.vocab
        write_token_shard(fs, "/corpus/s0", iter(toks), SEQ + 1)
    pipe = DataPipeline(fs, PipelineConfig(
        src_paths=("/corpus/s0",), work_dir="/epochs",
        block_tokens=SEQ + 1, global_batch=BATCH, seed=0, prefetch=0))
    ckpt = CheckpointManager(fs, "/ckpt", keep=2)
    return Trainer(model, pipe, ckpt,
                   hyper=TrainHyper(adamw=AdamWConfig(lr=1e-3,
                                                      warmup_steps=5,
                                                      decay_steps=steps)),
                   cfg=TrainerConfig(total_steps=steps,
                                     ckpt_every=ckpt_every,
                                     log_every=5)), ckpt


def test_loss_improves_and_checkpoints(cluster):
    trainer, ckpt = _setup(cluster, steps=30)
    out = trainer.run()
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]
    assert ckpt.latest_step() == 30
    assert len(ckpt.list_steps()) <= 2          # retention


def test_restart_resumes_with_consistent_cursor(cluster):
    trainer, ckpt = _setup(cluster, steps=20)
    trainer.run()
    man = ckpt.read_manifest()
    assert man["step"] == 20
    cursor_at_20 = man["pipeline"]

    # "crash" after step 20; a fresh trainer continues to 40
    trainer2, ckpt2 = _setup(cluster, steps=40)
    state, pstate = trainer2.restore_or_init()
    assert int(state["step"]) == 20
    assert pstate.to_dict() == cursor_at_20
    out = trainer2.run()
    assert ckpt2.latest_step() == 40


def test_elastic_rescale_same_stream(cluster):
    trainer, _ = _setup(cluster, steps=10)
    t2 = trainer.with_hosts(host_id=1, num_hosts=2)
    # host 1 of 2 sees the second half of each global batch
    trainer.pipeline.state = t2.pipeline.state
    b_full = next(iter(trainer.pipeline))
    b_half = next(iter(t2.pipeline))
    np.testing.assert_array_equal(b_full["tokens"][BATCH // 2:],
                                  b_half["tokens"])
