"""Property-based system test: WTF vs. an in-memory byte oracle.

A random sequence of writes/appends/punches/pastes/compactions/GC cycles is
applied both to a WTF file and to a plain bytearray; the file's content must
match the oracle after every step.  This exercises the full stack: overlay
semantics, region splitting, relative appends, metadata compaction, tier-2
spills and tier-3 storage GC.
"""
import hypothesis.strategies as st
import pytest
from hypothesis import HealthCheck, given, settings

from repro.core import Cluster, GarbageCollector

REGION = 2048
MAXLEN = 3 * REGION          # exercise multi-region behaviour


class Oracle:
    def __init__(self):
        self.buf = bytearray()

    def write(self, off, data):
        if off > len(self.buf):
            self.buf.extend(b"\x00" * (off - len(self.buf)))
        end = off + len(data)
        self.buf[off:end] = data

    def append(self, data):
        self.buf.extend(data)

    def punch(self, off, n):
        self.write(off, b"\x00" * n)


op_strategy = st.one_of(
    st.tuples(st.just("write"), st.integers(0, MAXLEN - 1),
              st.binary(min_size=1, max_size=600)),
    st.tuples(st.just("append"), st.binary(min_size=1, max_size=600)),
    st.tuples(st.just("punch"), st.integers(0, MAXLEN - 1),
              st.integers(1, 400)),
    st.tuples(st.just("yankpaste"), st.integers(0, MAXLEN - 1),
              st.integers(1, 500), st.integers(0, MAXLEN - 1)),
    st.tuples(st.just("compact")),
    st.tuples(st.just("gc")),
)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.function_scoped_fixture])
@given(st.lists(op_strategy, min_size=1, max_size=25))
def test_random_ops_match_oracle(tmp_path_factory, ops):
    tmp = tmp_path_factory.mktemp("wtf")
    cluster = Cluster(n_servers=3, data_dir=str(tmp), replication=1,
                      region_size=REGION, num_backing_files=2)
    try:
        fs = cluster.client()
        gc = GarbageCollector(cluster, spill_threshold=8)
        oracle = Oracle()
        fd = fs.open("/f", "w")
        for op in ops:
            kind = op[0]
            if kind == "write":
                _, off, data = op
                fs.pwrite(fd, data, off)
                oracle.write(off, data)
            elif kind == "append":
                _, data = op
                fs.append(fd, data)
                oracle.append(data)
            elif kind == "punch":
                _, off, n = op
                fs.seek(fd, off)
                fs.punch(fd, n)
                oracle.punch(off, n)
            elif kind == "yankpaste":
                _, src, n, dst = op
                size = fs.stat("/f")["size"]
                if src >= size:
                    continue
                n = min(n, size - src)
                fs.seek(fd, src)
                exts = fs.yank(fd, n)
                fs.seek(fd, dst)
                fs.paste(fd, exts)
                oracle.write(dst, bytes(oracle.buf[src:src + n]))
            elif kind == "compact":
                ino = fs.stat("/f")["inode"]
                size = fs.stat("/f")["size"]
                for r in range((size // REGION) + 1):
                    gc.compact_region(ino, r)
            elif kind == "gc":
                gc.storage_gc_pass()
            # invariant: content equals the oracle after every op
            got = fs.pread(fd, MAXLEN * 2, 0)
            assert got == bytes(oracle.buf), f"diverged after {kind}"
        fs.close(fd)
    finally:
        cluster.close()
