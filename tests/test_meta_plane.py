"""Metadata-plane fast path: incremental region compaction, the
delta-maintained resolved index, scatter-gather retrieval, KV group
commit, and the bounded WAL.

Property-style differential checks run seeded here (the hypothesis
variants live in tests/test_overlay_property.py, collect-ignored when
hypothesis is absent): the incremental resolved index and the compacting
commute must be *structurally identical* / byte-identical to full
``overlay()``/``compact()`` over randomized overlay histories.
"""
import random
import threading

import pytest

from repro.core import Cluster
from repro.core.errors import KVConflict, StorageError
from repro.core.inode import CompactRegion, RegionData, region_key
from repro.core.metadata import ListAppend, WarpKV
from repro.core.slicing import (Extent, ResolvedIndexCache, SlicePointer,
                                compact, overlay, overlay_extend)
from repro.core.testing import make_flaky_server
from repro.core.wbuf import PendingPtr, _PendingSlice


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=1,
                region_size=1 << 20)
    yield c
    c.close()


def _rand_entries(rng, n, zeros=True):
    out = []
    for i in range(n):
        off = rng.randrange(0, 300)
        ln = rng.randrange(1, 80)
        if zeros and rng.random() < 0.2:
            out.append(Extent(off, ln, ()))            # punch
        else:
            out.append(Extent(off, ln,
                              (SlicePointer(0, f"f{i}", 1000 * i, ln),)))
    return out


# ------------------------------------------------- incremental resolved form
def test_overlay_extend_matches_full_overlay_seeded():
    """overlay_extend(overlay(prefix), suffix) must be STRUCTURALLY equal
    to overlay(prefix + suffix) — not merely byte-equal — so plans and op
    digests are independent of which path resolved them."""
    rng = random.Random(42)
    for _ in range(200):
        entries = _rand_entries(rng, rng.randrange(0, 30))
        split = rng.randrange(0, len(entries) + 1)
        base = overlay(entries[:split])
        assert overlay_extend(base, entries[split:]) == overlay(entries)


def test_overlay_extend_appending_one_at_a_time():
    rng = random.Random(7)
    entries = _rand_entries(rng, 40)
    resolved = []
    for i, e in enumerate(entries):
        resolved = overlay_extend(resolved, [e])
        assert resolved == overlay(entries[:i + 1])


def test_resolved_index_cache_hits_on_grown_tuple():
    rng = random.Random(3)
    cache = ResolvedIndexCache()
    base = tuple(_rand_entries(rng, 10, zeros=False))
    r1 = cache.resolve(("k",), base)
    grown = base + tuple(_rand_entries(rng, 3, zeros=False))
    r2 = cache.resolve(("k",), grown)
    assert r2 == overlay(grown)
    assert r1 == overlay(base)
    # identical tuple object → O(1) hit returning the stored resolved form
    assert cache.resolve(("k",), grown) is r2


def test_resolved_index_cache_replaced_tuple_recomputes():
    """A wholesale replacement (compaction/truncate/GC) shares no object
    identity with the cached tuple and must fully re-resolve."""
    rng = random.Random(4)
    cache = ResolvedIndexCache()
    entries = tuple(_rand_entries(rng, 20, zeros=False))
    cache.resolve(("k",), entries)
    replacement = tuple(compact(entries))
    got = cache.resolve(("k",), replacement)
    assert got == overlay(replacement)


def test_resolved_index_bypasses_pending_placeholders():
    """Write-behind pending extents are transaction-private: they must
    never be stored in (or served from) the shared index."""
    cache = ResolvedIndexCache()
    cell = _PendingSlice(b"x" * 10, ("pk",), 0, None)
    pending = (Extent(0, 10, (PendingPtr(cell, 0, 10),)),)
    got = cache.resolve(("k",), pending)
    assert len(got) == 1 and got[0].length == 10
    assert len(cache) == 0, "pending extents must bypass the index"


# ------------------------------------------------- commit-time compaction
def test_compact_region_commute_differential():
    """CompactRegion.apply must equal full compact() over randomized
    histories, preserve ``end``/``indirect``, and no-op below threshold."""
    rng = random.Random(11)
    for _ in range(100):
        entries = tuple(_rand_entries(rng, rng.randrange(0, 25)))
        rd = RegionData(entries, end=400, indirect=None)
        new, dropped = CompactRegion(1).apply(rd)
        if new is rd:
            assert tuple(compact(entries)) == entries
        else:
            assert new.entries == tuple(compact(entries))
            assert new.end == rd.end and new.indirect is rd.indirect
            assert dropped == len(entries) - len(new.entries)
    rd = RegionData(tuple(_rand_entries(rng, 5)), end=100)
    assert CompactRegion(10).apply(rd)[0] is rd, "below threshold: no-op"
    assert CompactRegion(2).apply(None)[0] is None, "wiped region: no-op"


def test_commit_time_compaction_bounds_entries(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path),
                region_size=1 << 20, region_compact_threshold=8)
    try:
        fs = c.client()
        fd = fs.open("/hot", "w")
        for i in range(100):
            fs.append(fd, bytes([i % 256]) * 16)
        ino = fs.stat("/hot")["inode"]
        rd = c.kv.get("regions", region_key(ino, 0))
        assert len(rd.entries) <= 8
        assert c.kv.stats.compactions > 0
        assert fs.pread(fd, 1600, 0) == b"".join(
            bytes([i % 256]) * 16 for i in range(100))
        fs.close(fd)
    finally:
        c.close()


def test_compaction_disabled_keeps_full_history(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path),
                region_size=1 << 20, region_compact_threshold=None)
    try:
        fs = c.client()
        fd = fs.open("/hot", "w")
        for i in range(40):
            fs.append(fd, b"x" * 16)
        fs.close(fd)
        ino = fs.stat("/hot")["inode"]
        rd = c.kv.get("regions", region_key(ino, 0))
        assert len(rd.entries) == 40
        assert c.kv.stats.compactions == 0
    finally:
        c.close()


def test_compaction_preserves_region_version():
    """The §2.5 contract sharpened: a compaction that preserves resolved
    bytes must not bump reader-visible versions.  A reader holding a read
    dependency on the region must survive a pure-compaction commit."""
    kv = WarpKV()
    ptrs = tuple(Extent(i * 4, 4, (SlicePointer(0, "b", i * 4, 4),))
                 for i in range(20))
    kv.put("regions", ("r", 0), RegionData(ptrs, end=80))
    ver_before, _ = kv._read_versioned("regions", ("r", 0))

    reader = kv.begin()
    reader.get("regions", ("r", 0))          # read dependency at ver_before

    t = kv.begin()
    t.commute("regions", ("r", 0), CompactRegion(2))
    t.commit()

    ver_after, val = kv._read_versioned("regions", ("r", 0))
    assert len(val.entries) < 20, "compaction must have applied"
    assert ver_after == ver_before, \
        "version-preserving compaction must not bump the version"
    reader.put("s", "out", 1)
    reader.commit()                          # must NOT conflict
    assert kv.stats.compactions == 1


def test_append_plus_compaction_bumps_version_once():
    """An appending commit that also compacts bumps the region version
    exactly once (for the append) — compaction adds no extra bump."""
    kv = WarpKV()
    ptrs = tuple(Extent(i * 4, 4, (SlicePointer(0, "b", i * 4, 4),))
                 for i in range(10))
    kv.put("regions", ("r", 0), RegionData(ptrs, end=40))
    ver0, _ = kv._read_versioned("regions", ("r", 0))
    from repro.core.inode import AppendExtents
    t = kv.begin()
    t.commute("regions", ("r", 0),
              AppendExtents([Extent(40, 4, (SlicePointer(0, "b", 40, 4),))]))
    t.commute("regions", ("r", 0), CompactRegion(2))
    t.commit()
    ver1, val = kv._read_versioned("regions", ("r", 0))
    assert ver1 == ver0 + 1
    assert len(val.entries) < 11


def test_parallel_appends_never_conflict_with_compaction(tmp_path):
    """§2.5 conflict behavior is unchanged: concurrent appenders to one
    region never abort each other, compaction threshold or not."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path),
                region_size=1 << 20, region_compact_threshold=4)
    try:
        setup = c.client()
        setup.time_fn = lambda: 1000
        fd = setup.open("/log", "w")
        # warm: the FIRST append to an empty file bumps max_region -1 -> 0
        # (a real inode change that rightly invalidates concurrent inode
        # readers); §2.5 zero-conflict applies to appends within a region
        setup.append(fd, b"\xff" * 8)
        setup.close(fd)
        n_threads, n_appends = 4, 30
        clients = [c.client() for _ in range(n_threads)]
        for cl in clients:
            cl.time_fn = lambda: 1000    # mtime rollover is the other
            # benign inode bump; pin the clock so the test is exact

        def work(i):
            fs = clients[i]
            fd = fs.open("/log", "rw")
            for _ in range(n_appends):
                fs.append(fd, bytes([i]) * 8)
            fs.close(fd)

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sum(cl.stats.txn_aborts for cl in clients) == 0
        assert sum(cl.stats.txn_retries for cl in clients) == 0, \
            "parallel appends must not even retry (§2.5)"
        fd = setup.open("/log", "r")
        data = setup.read(fd)
        assert len(data) == (n_threads * n_appends + 1) * 8
        counts = {i: data.count(bytes([i]) * 8) for i in range(n_threads)}
        assert all(v >= n_appends for v in counts.values())
    finally:
        c.close()


def test_compaction_and_gc_interact_safely(tmp_path):
    """Compaction drops obscured extents; the tier-3 GC may then reclaim
    their slices — reads must stay correct through full GC cycles, and
    GC tier-1 must not version-bump regions that are already compact."""
    from repro.core import GarbageCollector

    c = Cluster(n_servers=2, data_dir=str(tmp_path),
                region_size=1 << 20, region_compact_threshold=4)
    try:
        fs = c.client()
        fd = fs.open("/f", "w")
        for i in range(30):                   # repeated overwrites
            fs.pwrite(fd, bytes([i]) * 1000, 0)
        want = bytes([29]) * 1000
        gc = GarbageCollector(c)
        gc.full_cycle()
        gc.full_cycle()
        assert fs.pread(fd, 1000, 0) == want
        ino = fs.stat("/f")["inode"]
        ver_before, _ = c.kv._read_versioned("regions", region_key(ino, 0))
        r = gc.compact_region(ino, 0)
        assert r.get("noop") or r["before"] == r["after"]
        ver_after, _ = c.kv._read_versioned("regions", region_key(ino, 0))
        assert ver_after == ver_before, \
            "tier-1 GC must not bump versions of already-compact regions"
        fs.close(fd)
    finally:
        c.close()


# ------------------------------------------------- scatter-gather retrieval
def test_retrieve_slices_server_roundtrip(cluster):
    srv = cluster.servers[0]
    p1 = srv.create_slice(b"a" * 100, locality_hint=1)
    srv.create_slice(b"junk" * 500, locality_hint=1)   # the disk gap
    p2 = srv.create_slice(b"b" * 50, locality_hint=1)
    before = srv.stats.snapshot()
    got = srv.retrieve_slices([p2, p1.sub(10, 20)])
    assert bytes(got[0]) == b"b" * 50
    assert bytes(got[1]) == b"a" * 20
    after = srv.stats.snapshot()
    assert after["read_rounds"] - before["read_rounds"] == 1
    assert after["slices_read"] - before["slices_read"] == 2
    assert after["bytes_read"] - before["bytes_read"] == 70
    with pytest.raises(StorageError):
        srv.retrieve_slices([SlicePointer(99, "b", 0, 4)])


def _interleaved_cluster(tmp_path, k, **kw):
    c = Cluster(n_servers=1, data_dir=str(tmp_path), region_size=1 << 20,
                num_backing_files=1, fetch_gap_bytes=1, **kw)
    fs = c.client()
    fa, fb = fs.open("/a", "w"), fs.open("/b", "w")
    for i in range(k):
        fs.pwrite(fa, bytes([i]) * 4096, i * 4096)
        fs.pwrite(fb, b"\xee" * 4096, i * 4096)
    return c, fs, fa


def test_scatter_gather_one_round(tmp_path):
    k = 6
    c, fs, fa = _interleaved_cluster(tmp_path / "sg", k)
    try:
        c.reset_io_stats()
        out = fs.readv(fa, [(i * 4096, 4096) for i in range(k)])
        assert out == [bytes([i]) * 4096 for i in range(k)]
        st = c.total_stats()["servers"][0]
        assert st["read_rounds"] == 1, \
            "non-adjacent same-file batches must share one round"
        assert st["slices_read"] == k
        assert fs.stats.fetch_batches == 1
        assert fs.stats.slices_coalesced == k - 1
        # no gap bytes fetched: exactly the requested bytes moved
        assert st["bytes_read"] == k * 4096
    finally:
        c.close()


def test_scatter_gather_off_one_round_per_run(tmp_path):
    k = 6
    c, fs, fa = _interleaved_cluster(tmp_path / "nosg", k,
                                     scatter_gather=False)
    try:
        c.reset_io_stats()
        out = fs.readv(fa, [(i * 4096, 4096) for i in range(k)])
        assert out == [bytes([i]) * 4096 for i in range(k)]
        assert c.total_stats()["servers"][0]["read_rounds"] == k
    finally:
        c.close()


def test_scatter_gather_degrades_on_failure(tmp_path):
    """An injected retrieve_slices failure must fall back to per-batch /
    per-extent retrieval with correct bytes (§2.9 availability)."""
    k = 5
    c, fs, fa = _interleaved_cluster(tmp_path / "flaky", k)
    try:
        flaky = make_flaky_server(c, 0, {"retrieve_slices": {1}})
        out = fs.readv(fa, [(i * 4096, 4096) for i in range(k)])
        assert out == [bytes([i]) * 4096 for i in range(k)]
        assert flaky.injected == 1
    finally:
        c.close()


def test_scatter_gather_replica_failover(tmp_path):
    """With replication, killing the scatter-gather target mid-plan still
    serves every extent from the surviving replica."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path), replication=2,
                region_size=1 << 20, num_backing_files=1, fetch_gap_bytes=1)
    try:
        fs = c.client()
        fa, fb = fs.open("/a", "w"), fs.open("/b", "w")
        k = 4
        for i in range(k):
            fs.pwrite(fa, bytes([i + 1]) * 4096, i * 4096)
            fs.pwrite(fb, b"\xee" * 4096, i * 4096)
        c.fail_server(0)
        out = fs.readv(fa, [(i * 4096, 4096) for i in range(k)])
        assert out == [bytes([i + 1]) * 4096 for i in range(k)]
    finally:
        c.close()


# ------------------------------------------------- KV group commit
def test_group_commit_single_threaded_semantics():
    kv = WarpKV(group_commit=True)
    kv.put("s", "k", 1)
    t1 = kv.begin()
    assert t1.get("s", "k") == 1
    kv.put("s", "k", 2)
    t1.put("s", "other", 99)
    with pytest.raises(KVConflict):
        t1.commit()
    assert kv.get("s", "other") is None
    assert kv.stats.commit_lock_passes == kv.stats.commits \
        + kv.stats.aborts


def test_group_commit_concurrent_correctness_and_batching():
    kv = WarpKV(group_commit=True)
    n, m = 8, 60

    def worker(i):
        for j in range(m):
            txn = kv.begin()
            txn.commute("s", "lst", ListAppend([(i, j)]))
            txn.commit()

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    lst = kv.get("s", "lst")
    assert len(lst) == n * m and len(set(lst)) == n * m
    assert kv.stats.aborts == 0
    assert kv.stats.commits == n * m
    assert kv.stats.commit_lock_passes + kv.stats.grouped_commits \
        == kv.stats.commits


def test_group_commit_batch_isolates_failures():
    """A conflicting transaction in a batch aborts alone; batch-mates
    commit, exactly as back-to-back commits would."""
    kv = WarpKV(group_commit=True)
    kv.put("s", "k", 0)
    stale = kv.begin()
    stale.get("s", "k")
    kv.put("s", "k", 1)                      # invalidates `stale`
    ok = kv.begin()
    ok.commute("s", "lst", ListAppend(["x"]))
    ok.commit()
    stale.put("s", "w", 1)
    with pytest.raises(KVConflict):
        stale.commit()
    assert kv.get("s", "lst") == ["x"]
    assert kv.get("s", "w") is None


def test_group_commit_off_counts_every_pass():
    kv = WarpKV(group_commit=False)
    for i in range(10):
        kv.put("s", i, i)
    assert kv.stats.commits == 10
    assert kv.stats.commit_lock_passes == 10
    assert kv.stats.grouped_commits == 0


# ------------------------------------------------- bounded WAL
def test_wal_is_bounded_and_subscribe_converges():
    kv = WarpKV()
    kv.WAL_TAIL_MAX = 32                      # shrink the ring for the test
    keys = [f"k{i}" for i in range(5)]
    for round_ in range(200):
        for k in keys:
            kv.put("s", k, (k, round_))
    assert len(kv._wal_tail) <= 32
    assert kv.wal_entries() <= 32 + len(keys), \
        "WAL memory must be O(keyspace + tail), not O(history)"

    seen = {}
    versions = {}
    kv.subscribe(lambda sp, k, v, ver: (seen.__setitem__((sp, k), v),
                                        versions.__setitem__((sp, k), ver)))
    for k in keys:
        assert seen[("s", k)] == (k, 199), \
            "a late subscriber must converge on the latest value per key"
    # and the listener stays live for future commits
    kv.put("s", "k0", "fresh")
    assert seen[("s", "k0")] == "fresh"


def test_wal_bounded_under_client_workload(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), region_size=1 << 20)
    try:
        c.kv.WAL_TAIL_MAX = 64
        fs = c.client()
        fd = fs.open("/f", "w")
        for i in range(300):
            fs.pwrite(fd, b"z" * 64, (i % 10) * 64)
        fs.close(fd)
        assert len(c.kv._wal_tail) <= 64
    finally:
        c.close()
