"""WTF-backed data pipeline: shards, zero-copy shuffle/mixing, iteration."""
import numpy as np
import pytest

from repro.core import Cluster
from repro.data import (ByteTokenizer, DataPipeline, PipelineConfig,
                        PipelineState, RecordFile, RecordWriter,
                        mix_datasets, shuffle_epoch, write_token_shard)


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=256 * 1024)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def test_record_roundtrip(fs):
    w = RecordWriter(fs, "/shard", record_bytes=16)
    for i in range(10):
        w.append(bytes([i]) * 16)
    spec = w.close()
    assert spec.count == 10
    f = RecordFile(fs, "/shard", 16)
    assert f.count == 10
    assert f.read_record(3) == bytes([3]) * 16
    assert f.read_records(8, 5) == bytes([8]) * 16 + bytes([9]) * 16
    f.close()


def test_token_shard_packing(fs):
    toks = list(range(105))
    spec = write_token_shard(fs, "/toks", toks, block_tokens=10)
    assert spec.count == 10                 # tail 5 tokens dropped
    f = RecordFile(fs, "/toks", 40)
    np.testing.assert_array_equal(f.read_tokens(2), np.arange(20, 30))
    f.close()


def test_shuffle_is_permutation_and_zero_copy(cluster, fs):
    fs.mkdir("/data")
    records = []
    w = RecordWriter(fs, "/data/a", 8)
    for i in range(20):
        rec = i.to_bytes(4, "little") * 2
        records.append(rec)
        w.append(rec)
    w.close()

    writes_before = sum(s.stats.bytes_written
                        for s in cluster.servers.values())
    n = shuffle_epoch(fs, ["/data/a"], "/data/ep0", 8, seed=1)
    writes_after = sum(s.stats.bytes_written
                       for s in cluster.servers.values())
    assert n == 20
    assert writes_after - writes_before < 100, \
        "shuffle must move ~zero data bytes (dirent record only)"

    f = RecordFile(fs, "/data/ep0", 8)
    got = [f.read_record(i) for i in range(f.count)]
    f.close()
    assert sorted(got) == sorted(records), "shuffle must be a permutation"
    assert got != records, "seeded shuffle should actually permute"


def test_shuffle_is_deterministic(fs):
    fs.mkdir("/d")
    w = RecordWriter(fs, "/d/a", 4)
    for i in range(30):
        w.append(i.to_bytes(4, "little"))
    w.close()
    shuffle_epoch(fs, ["/d/a"], "/d/e1", 4, seed=42)
    shuffle_epoch(fs, ["/d/a"], "/d/e2", 4, seed=42)
    f1 = RecordFile(fs, "/d/e1", 4)
    f2 = RecordFile(fs, "/d/e2", 4)
    assert [f1.read_record(i) for i in range(30)] == \
           [f2.read_record(i) for i in range(30)]
    f1.close(); f2.close()


def test_mixture_weights(fs):
    fs.mkdir("/m")
    for name, byte in (("x", b"x"), ("y", b"y")):
        w = RecordWriter(fs, f"/m/{name}", 1)
        for _ in range(300):
            w.append(byte)
        w.close()
    n = mix_datasets(fs, [("/m/x", 3.0), ("/m/y", 1.0)], "/m/mix", 1,
                     seed=0, total_records=200)
    assert n == 200
    f = RecordFile(fs, "/m/mix", 1)
    data = f.read_records(0, 200)
    f.close()
    x_frac = data.count(b"x") / 200
    assert 0.6 < x_frac < 0.9, f"expected ~0.75 x-fraction, got {x_frac}"


def _make_corpus(fs, n_records=64, block=9):
    fs.mkdir("/corpus")
    w = RecordWriter(fs, "/corpus/s0", block * 4)
    for i in range(n_records):
        w.append_array(np.full(block, i, dtype=np.int32))
    w.close()


def test_pipeline_batches_and_shapes(fs):
    _make_corpus(fs)
    cfg = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                         block_tokens=9, global_batch=8, seed=0, prefetch=0)
    pipe = DataPipeline(fs, cfg)
    it = iter(pipe)
    batch = next(it)
    assert batch["tokens"].shape == (8, 8)
    assert batch["labels"].shape == (8, 8)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


def test_pipeline_epoch_covers_all_records_once(fs):
    _make_corpus(fs, n_records=32)
    cfg = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                         block_tokens=9, global_batch=8, seed=0, prefetch=0)
    pipe = DataPipeline(fs, cfg)
    seen = []
    it = iter(pipe)
    for _ in range(pipe.steps_per_epoch):
        b = next(it)
        seen.extend(b["tokens"][:, 0].tolist())
    assert sorted(seen) == sorted(range(32)), \
        "one epoch must visit every record exactly once"


def test_pipeline_multihost_partition(fs):
    """Hosts' shards must tile the global batch exactly."""
    _make_corpus(fs, n_records=32)
    base = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                          block_tokens=9, global_batch=8, seed=3, prefetch=0)
    whole = DataPipeline(fs, base)
    b_full = next(iter(whole))
    parts = []
    for h in range(4):
        import dataclasses
        cfg = dataclasses.replace(base, host_id=h, num_hosts=4)
        b = next(iter(DataPipeline(fs, cfg)))
        parts.append(b["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), b_full["tokens"])


def test_pipeline_resume_from_state(fs):
    """Restart mid-epoch from the checkpointed cursor → identical stream."""
    _make_corpus(fs, n_records=64)
    cfg = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                         block_tokens=9, global_batch=8, seed=0, prefetch=0)
    p1 = DataPipeline(fs, cfg)
    it1 = iter(p1)
    for _ in range(3):
        next(it1)
    state = PipelineState.from_dict(p1.state.to_dict())   # "checkpoint"
    want = next(it1)

    p2 = DataPipeline(fs, cfg, state=state)               # "restart"
    got = next(iter(p2))
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_pipeline_prefetch_matches_sync(fs):
    _make_corpus(fs, n_records=32)
    import dataclasses
    cfg = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                         block_tokens=9, global_batch=8, seed=0, prefetch=0)
    sync_batches = []
    it = iter(DataPipeline(fs, cfg))
    for _ in range(6):
        sync_batches.append(next(it)["tokens"])
    pre = iter(DataPipeline(fs, dataclasses.replace(cfg, prefetch=3)))
    for i in range(6):
        np.testing.assert_array_equal(next(pre)["tokens"], sync_batches[i])


def test_elastic_rescale_same_stream(fs):
    """2 hosts → 4 hosts at step 5: the union of host batches is unchanged."""
    _make_corpus(fs, n_records=64)
    cfg = PipelineConfig(src_paths=("/corpus/s0",), work_dir="/epochs",
                         block_tokens=9, global_batch=8, seed=0, prefetch=0,
                         host_id=0, num_hosts=2)
    p = DataPipeline(fs, cfg)
    it = iter(p)
    for _ in range(5):
        next(it)
    state = p.state
    # what a single host would see at the next step
    whole = DataPipeline(fs, PipelineConfig(
        src_paths=("/corpus/s0",), work_dir="/epochs", block_tokens=9,
        global_batch=8, seed=0, prefetch=0), state=state)
    want = next(iter(whole))["tokens"]
    parts = []
    for h in range(4):
        q = p.with_hosts(h, 4)
        parts.append(next(iter(q))["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), want)
