"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs; decode
archs additionally run one serve step against a fresh cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import get_model
from repro.models.common import padded_vocab
from repro.train import (TrainHyper, init_state, make_serve_step,
                         make_train_step)

BATCH, SEQ = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab),
    }
    if cfg.vlm is not None:
        batch["patch_embeds"] = jax.random.normal(
            rng, (BATCH, cfg.vlm.num_patches, cfg.vlm.vision_dim),
            jnp.float32)
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            rng, (BATCH, cfg.encdec.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch).replace(max_seq=SEQ)
    model = get_model(cfg)
    params = model.init(rng)
    logits = model.forward(params, _batch(cfg, rng))
    assert logits.shape == (BATCH, SEQ, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, rng):
    cfg = get_smoke_config(arch).replace(max_seq=SEQ)
    model = get_model(cfg)
    state = init_state(model, rng)
    step = jax.jit(make_train_step(model, TrainHyper()))
    state, metrics = step(state, _batch(cfg, rng))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state["step"]) == 1
    # params actually moved
    l0 = jax.tree.leaves(state["params"])[0]
    assert l0.dtype == jnp.dtype(cfg.param_dtype)


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step(arch, rng):
    cfg = get_smoke_config(arch).replace(max_seq=SEQ)
    model = get_model(cfg)
    params = model.init(rng)
    cache = model.init_cache(BATCH, max_len=SEQ)
    if cfg.encdec is not None:
        # cross K/V comes from a (stub) encoder pass at prefill time
        from repro.models import whisper as W
        enc = W.encode(params, jnp.zeros(
            (BATCH, cfg.encdec.encoder_seq, cfg.d_model)), cfg)
        cache["cross"] = W.make_cross_kv(params, enc, cfg)
    serve = jax.jit(make_serve_step(model))
    toks = jnp.zeros((BATCH, 1), jnp.int32)
    pos = jnp.zeros((BATCH,), jnp.int32)
    for t in range(3):
        toks_next, cache = serve(params, cache,
                                 {"tokens": toks, "pos": pos + t})
        assert toks_next.shape == (BATCH,)
        toks = toks_next[:, None]


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b"])
def test_grad_accumulation_matches_single(arch, rng):
    cfg = get_smoke_config(arch).replace(max_seq=SEQ)
    model = get_model(cfg)
    state = init_state(model, rng)
    batch = _batch(cfg, rng)
    s1 = jax.jit(make_train_step(model, TrainHyper(accum_steps=1)))
    s2 = jax.jit(make_train_step(model, TrainHyper(accum_steps=2)))
    _, m1 = s1(jax.tree.map(jnp.copy, state), batch)
    _, m2 = s2(jax.tree.map(jnp.copy, state), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
