"""Write-path store scheduler (``wsched``) + fault-injection harness.

Covers:
  * coalescing: a gather-write of N small chunks in one region issues ONE
    store round per replica (``store_batches``/``slices_store_coalesced``);
  * fan-out: a write spanning regions stores each region's slice through
    its own (server, backing-file) group;
  * replication: batched stores place replicas on distinct servers, fall
    back to the next ring owner on injected ``StorageError``, and record
    under-replication in ``degraded_stores`` instead of failing silently;
  * atomicity: a mid-batch server crash never yields a partially visible
    vectored write — either every byte commits or none are observable;
  * replay: the §2.6 op log holds the batch's slice pointers, so a
    replayed ``pwritev`` re-points its slices instead of re-storing them;
  * equivalence: ``store_batching=False`` produces identical contents with
    one round per slice (the scalar pipeline the scheduler replaces).
"""
import pytest

from repro.core import Cluster, StorageError, StoreRequest
from repro.core.testing import make_flaky_kv, make_flaky_server
from repro.core.wsched import plan_store_groups


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=64 * 1024)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def read_file(fs, path):
    with fs.open_file(path) as f:
        return f.read()


def region_entries(cluster, fs, path, region=0):
    ino = fs.stat(path)["inode"]
    return cluster.kv.get("regions", (ino, region)).entries


# ------------------------------------------------------------------ planning
class _FixedRing:
    """Stand-in ring: every key owns the same candidate list."""

    def __init__(self, owners):
        self._owners = list(owners)

    def owners(self, key, n):
        return self._owners[:n]


def test_plan_groups_pack_small_runs_and_isolate_large():
    reqs = [StoreRequest(0, b"a" * 10, "k", 7),
            StoreRequest(1, b"b" * 10, "k", 7),
            StoreRequest(2, b"L" * 100, "k", 7),     # over the threshold
            StoreRequest(3, b"c" * 10, "k", 7)]
    [g] = plan_store_groups(reqs, _FixedRing([0, 1]), 2, max_coalesce=64)
    assert [len(u.spans) for u in g.units] == [2, 1, 1]
    assert g.units[0].data == b"a" * 10 + b"b" * 10
    # span order must match request order — pointers are carved from it
    assert [r.key for u in g.units for r, _, _ in u.spans] == [0, 1, 2, 3]


def test_plan_groups_split_by_hint():
    reqs = [StoreRequest(0, b"x", "k", 1), StoreRequest(1, b"y", "k", 2)]
    groups = plan_store_groups(reqs, _FixedRing([0]), 1)
    assert len(groups) == 2, "different backing files must not share a store"


# ---------------------------------------------------------------- coalescing
def test_writev_small_chunks_single_store_round(cluster, fs):
    with fs.open_file("/w", "w") as f:
        before = fs.stats.store_batches
        f.writev([b"a" * 100, b"b" * 100, b"c" * 100, b"d" * 100])
        assert fs.stats.store_batches - before == 1
    assert fs.stats.slices_store_coalesced >= 3
    assert read_file(fs, "/w") == b"a" * 100 + b"b" * 100 + b"c" * 100 \
        + b"d" * 100


def test_carved_pointers_are_disk_adjacent(cluster, fs):
    with fs.open_file("/adj", "w") as f:
        f.writev([b"1" * 64, b"2" * 64, b"3" * 64])
    entries = region_entries(cluster, fs, "/adj")
    ptrs = [e.ptrs[0] for e in entries]
    assert len({(p.server_id, p.backing_file) for p in ptrs}) == 1
    for a, b in zip(ptrs, ptrs[1:]):
        assert a.offset + a.length == b.offset, \
            "covering store must lay chunk slices contiguously"


def test_server_side_round_accounting(cluster, fs):
    cluster.reset_io_stats()
    with fs.open_file("/acct", "w") as f:
        f.writev([b"q" * 200] * 8)
    st = cluster.total_stats()
    created = sum(s["slices_created"] for s in st["servers"].values())
    # one data round (8 chunks coalesced) + one dirent-append round
    assert created == 2
    assert st["slices_written"] >= created


# ------------------------------------------------------------------- fan-out
def test_cross_region_write_fans_out_per_region(cluster, fs):
    data = bytes(i & 0xFF for i in range(256 * 1024))      # 4 regions
    with fs.open_file("/fan", "w") as f:
        before = fs.stats.store_batches
        f.pwritev([data], 0)
        assert fs.stats.store_batches - before == 4
    assert read_file(fs, "/fan") == data
    servers = {region_entries(cluster, fs, "/fan", r)[0].ptrs[0].server_id
               for r in range(4)}
    assert len(servers) > 1, "regions must spread across the ring"


# --------------------------------------------------------------- replication
def test_batched_replicas_land_on_distinct_servers(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path / "r"), replication=2,
                region_size=64 * 1024)
    fs = c.client()
    with fs.open_file("/r", "w") as f:
        f.writev([b"rep" * 50, b"lic" * 50])
    for e in region_entries(c, fs, "/r"):
        assert len(e.ptrs) == 2
        assert e.ptrs[0].server_id != e.ptrs[1].server_id
    assert c.degraded_stores == 0
    c.close()


def test_store_fallback_on_injected_failure(cluster, fs):
    # learn the ring target for (inode, region 0), then make it flaky
    with fs.open_file("/fb", "w") as f:
        f.writev([b"probe"])
    target = region_entries(cluster, fs, "/fb")[0].ptrs[0].server_id
    flaky = make_flaky_server(cluster, target, {"create_slices": {1}})
    with fs.open_file("/fb", "rw") as f:
        f.pwritev([b"X" * 64, b"Y" * 64], 5)
    assert flaky.injected == 1
    assert read_file(fs, "/fb") == b"probe" + b"X" * 64 + b"Y" * 64
    moved = region_entries(cluster, fs, "/fb")[-1].ptrs[0].server_id
    assert moved != target, "fallback must pick the next ring owner"


def test_degraded_replication_is_counted_not_silent(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "d"), replication=2,
                region_size=64 * 1024)
    fs = c.client()
    c.servers[0].crash()            # dead but still in the ring
    with fs.open_file("/deg", "w") as f:
        f.writev([b"only-one-replica" * 10])
    assert read_file(fs, "/deg") == b"only-one-replica" * 10
    assert c.degraded_stores >= 1
    assert fs.stats.degraded_stores >= 1
    for e in region_entries(c, fs, "/deg"):
        assert len(e.ptrs) == 1
    c.close()


def test_scalar_store_slice_degraded_counter(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "s"), replication=2,
                region_size=64 * 1024)
    fs = c.client()
    c.servers[1].crash()
    with fs.open_file("/sc", "w") as f:
        f.write(b"scalar-write" * 10)          # scalar path: store_slice
    assert c.degraded_stores >= 1
    assert c.total_stats()["degraded_stores"] == c.degraded_stores
    c.close()


# ----------------------------------------------------------------- atomicity
def test_mid_batch_crash_with_fallback_commits_fully(tmp_path):
    """One server dies mid-batch; the batch must still commit WHOLE."""
    c = Cluster(n_servers=4, data_dir=str(tmp_path / "mb"), replication=2,
                region_size=64 * 1024)
    fs = c.client()
    with fs.open_file("/mb", "w") as f:
        f.writev([b"seed"])
    target = region_entries(c, fs, "/mb")[0].ptrs[0].server_id
    make_flaky_server(c, target, {"create_slices": {1}}, crash=True)
    data = bytes(i & 0xFF for i in range(200 * 1024))      # multi-region
    with fs.open_file("/mb", "rw") as f:
        f.pwritev([data], 4)
    assert read_file(fs, "/mb") == b"seed" + data
    assert not c.servers[target].alive
    c.close()


def test_mid_batch_crash_never_partially_visible(tmp_path):
    """The acceptance property: if the batch cannot complete, NOTHING of it
    is observable — no bytes, no size change, no region metadata."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "a"), replication=1,
                region_size=32 * 1024)
    fs = c.client()
    with fs.open_file("/atom", "w") as f:
        f.write(b"untouched")
    before_entries = region_entries(c, fs, "/atom")
    # every server crashes at its first batched store: no fallback exists
    for sid in list(c.servers):
        make_flaky_server(c, sid, {"create_slices": {1}}, crash=True)
    other = c.client()
    data = bytes(range(256)) * 512                         # 4 regions
    with fs.open_file("/atom", "rw") as f:
        with pytest.raises(StorageError):
            f.pwritev([data[:64 * 1024], data[64 * 1024:]], 0)
        assert f.tell() == 0, "fd state must be untouched by the failure"
    for sid in c.servers:
        c.servers[sid].recover()
    assert read_file(other, "/atom") == b"untouched"
    assert other.stat("/atom")["size"] == len(b"untouched")
    assert region_entries(c, other, "/atom") == before_entries, \
        "no partial extent of the failed batch may be visible"
    c.close()


def test_vectored_write_all_or_nothing_under_kv_aborts(cluster, fs):
    """Mid-commit KV failures (FlakyKV) either replay invisibly or leave no
    trace — combined with slice-before-metadata ordering this is the §2.6
    half of batch atomicity."""
    with fs.open_file("/kv", "w") as f:
        f.write(b"base")
    flaky = make_flaky_kv(cluster, fail_commits={2})
    c2 = cluster.client()                   # created after install: flaky kv
    with c2.open_file("/kv", "rw") as f:    # commit #1: open is harmless
        f.pwritev([b"AB" * 50, b"CD" * 50], 0)   # commit #2 fails, replays
    assert flaky.injected == 1
    assert c2.stats.txn_retries >= 1
    assert read_file(fs, "/kv") == b"AB" * 50 + b"CD" * 50


# -------------------------------------------------------------------- replay
def test_replayed_pwritev_reuses_its_slices(cluster, fs):
    """§2.6: the op log records the batch's pointers — a replay must not
    re-store the payload."""
    with fs.open_file("/rp", "w") as f:
        f.write(b"head")
    other = cluster.client()
    payload = [b"P" * 8_000, b"Q" * 8_000]

    def srv_writes():
        return sum(s.stats.bytes_written for s in cluster.servers.values())

    with fs.transaction():
        fd = fs.open("/rp", "rw")
        fs.seek(fd, 0, 2)                   # SEEK_END, no app-visible value
        fs.writev(fd, payload)
        written_after_op = srv_writes()
        ofd = other.open("/rp", "rw")
        other.seek(ofd, 0, 2)
        other.write(ofd, b"x")              # moves EOF → forces a replay
        other.close(ofd)
    assert fs.stats.txn_retries >= 1
    assert srv_writes() - written_after_op <= 1
    assert read_file(fs, "/rp") == b"head" + b"x" + b"".join(payload)


# -------------------------------------------------------------- scalar mode
def test_store_batching_disabled_same_contents_more_rounds(tmp_path):
    datasets = [[b"a" * 100, b"b" * 100, b"c" * 100],
                [bytes(range(256)) * 300]]                 # cross-region
    results = {}
    for batching in (True, False):
        d = str(tmp_path / f"b{batching}")
        c = Cluster(n_servers=4, data_dir=d, replication=1,
                    region_size=64 * 1024, store_batching=batching)
        fs = c.client()
        with fs.open_file("/f", "w") as f:
            for chunks in datasets:
                f.writev(chunks)
        results[batching] = (read_file(fs, "/f"), fs.stats.store_batches)
        c.close()
    assert results[True][0] == results[False][0]
    assert results[True][1] < results[False][1], \
        "batching must issue fewer store rounds than the scalar pipeline"


def test_reset_io_stats_clears_degraded_and_wrapped_server_stats(tmp_path):
    """``reset_io_stats`` must zero the cluster degraded counter and reach
    THROUGH a ``FlakyStorageServer`` wrapper to the real server's stats —
    post-reset accounting would otherwise be silently frozen/stale."""
    c = Cluster(n_servers=2, data_dir=str(tmp_path / "rs"), replication=2,
                region_size=64 * 1024)
    fs = c.client()
    c.servers[0].crash()
    with fs.open_file("/pre", "w") as f:
        f.writev([b"setup" * 100])            # degraded setup-phase store
    c.servers[0].recover()
    flaky = make_flaky_server(c, 1, {"create_slices": set()})
    assert c.degraded_stores > 0
    c.reset_io_stats()
    assert c.total_stats()["degraded_stores"] == 0
    with fs.open_file("/post", "w") as f:
        f.writev([b"measured" * 100])
    st = c.total_stats()["servers"]
    assert st[1]["bytes_written"] > 0, \
        "wrapped server's post-reset I/O must be visible"
    assert flaky._inner.stats.slices_written > 0
    c.close()


def test_checkpoint_save_routes_through_write_scheduler(cluster, fs):
    from repro.checkpoint.manager import CheckpointManager
    import numpy as np

    mgr = CheckpointManager(fs, root="/ck")
    before = fs.stats.store_batches
    mgr.save(1, {"w": np.arange(64 * 1024, dtype=np.int8)})
    assert fs.stats.store_batches > before
    got = mgr.restore({"w": np.zeros(64 * 1024, dtype=np.int8)})
    assert (got["w"] == np.arange(64 * 1024, dtype=np.int8)).all()
