"""Streaming multi-producer log (``core.wlog``).

The contract under test: producers are concurrent appenders on ONE file
(§2.5 relative appends — they commute), consumers tail the committed
prefix via the bounded-WAL subscribe stream, delivery is at-least-once
with byte-identical streams across consumers, and a batch of records
becomes visible atomically (no torn frames, ever).
"""
import threading

import pytest

from repro.core import Cluster
from repro.core.wlog import WtfLog, content_digest, frame

REGION = 256 * 1024


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=2, data_dir=str(tmp_path), region_size=REGION)
    yield c
    c.close()


def drain(consumer, want, timeout=30.0):
    out = []
    while consumer.records < want:
        got = consumer.poll(timeout=timeout)
        assert got, f"timed out at {consumer.records}/{want} records"
        out.extend(got)
    return out


def test_roundtrip_single_producer(cluster):
    log = WtfLog(cluster, "/l")
    cons = log.consumer()
    prod = log.producer()
    msgs = [f"msg-{i}".encode() for i in range(20)]
    for m in msgs:
        prod.produce(m)
    prod.close()
    assert drain(cons, len(msgs)) == msgs
    assert cons.position == sum(len(frame(m)) for m in msgs)
    assert prod.flushes == len(msgs)
    cons.close()


def test_batching_amortizes_commits(cluster):
    log = WtfLog(cluster, "/l")
    prod = log.producer(batch_records=8)
    commits0 = cluster.kv.stats.commits
    for i in range(24):
        prod.produce(b"x%d" % i)
    prod.close()
    assert prod.flushes == 3
    assert cluster.kv.stats.commits - commits0 <= 3 + 1   # +1 fd open slack
    cons = log.consumer()
    assert [p[:1] for p in drain(cons, 24)] == [b"x"] * 24
    cons.close()


def test_concurrent_producers_consumers_byte_identical(cluster):
    log = WtfLog(cluster, "/l")
    N, M = 4, 40
    consumers = [log.consumer() for _ in range(2)]
    streams = [[] for _ in consumers]

    def consume(c, out):
        out.extend(drain(c, N * M))

    cthreads = [threading.Thread(target=consume, args=(c, o))
                for c, o in zip(consumers, streams)]
    for t in cthreads:
        t.start()

    def produce(i):
        p = log.producer(batch_records=4)
        for j in range(M):
            p.produce(f"p{i}s{j:04d}".encode())
        p.close()

    pthreads = [threading.Thread(target=produce, args=(i,))
                for i in range(N)]
    for t in pthreads: t.start()
    for t in pthreads: t.join()
    for t in cthreads: t.join()

    # byte-identical delivery: same payloads, same order
    assert streams[0] == streams[1]
    assert consumers[0].digest() == consumers[1].digest()
    # per-producer FIFO within the interleaving
    for i in range(N):
        mine = [p for p in streams[0] if p.startswith(b"p%d" % i)]
        assert mine == [f"p{i}s{j:04d}".encode() for j in range(M)]
    for c in consumers:
        c.close()


def test_late_consumer_catches_up_from_replay(cluster):
    """A consumer attaching after all commits rebuilds its watermark
    entirely from the WAL snapshot replay — no event, no poll wake, just
    the committed prefix."""
    log = WtfLog(cluster, "/l")
    prod = log.producer(batch_records=4)
    msgs = [b"early-%03d" % i for i in range(30)]
    for m in msgs:
        prod.produce(m)
    prod.close()
    late = log.consumer()
    assert drain(late, len(msgs)) == msgs
    late.close()


def test_at_least_once_restart(cluster):
    log = WtfLog(cluster, "/l")
    prod = log.producer()
    msgs = [b"r%02d" % i for i in range(12)]
    for m in msgs:
        prod.produce(m)
    prod.close()

    c1 = log.consumer()
    drain(c1, len(msgs))
    checkpoint = c1.position
    c1.close()

    # restart from the saved cursor: nothing is redelivered
    c2 = log.consumer(from_offset=checkpoint)
    assert c2.poll(timeout=0.05) == []
    assert c2.records == 0
    # …and new records flow from there
    tail = log.producer()
    tail.produce(b"after-restart")
    tail.close()
    assert drain(c2, 1) == [b"after-restart"]
    c2.close()

    # restart from an older checkpoint: the suffix is REdelivered —
    # duplicates possible, loss impossible
    c3 = log.consumer(from_offset=0)
    got = drain(c3, len(msgs) + 1)
    assert got == msgs + [b"after-restart"]
    assert content_digest(got) == content_digest(msgs + [b"after-restart"])
    c3.close()


def test_no_torn_frames_under_chunked_polls(cluster):
    """A frame split across poll windows (max_bytes smaller than one
    record) must be reassembled, never delivered torn."""
    log = WtfLog(cluster, "/l")
    prod = log.producer()
    big = bytes(range(256)) * 64           # 16 KiB record
    prod.produce(big)
    prod.produce(b"tiny")
    prod.close()
    cons = log.consumer()
    out = []
    while cons.records < 2:
        out.extend(cons.poll(timeout=5.0, max_bytes=1000))
    assert out == [big, b"tiny"]
    cons.close()


def test_producer_write_behind_equivalent(cluster):
    """A write-behind producer defers its payload stores to the commit
    flush; the delivered stream must be indistinguishable."""
    log = WtfLog(cluster, "/l")
    cons = log.consumer()
    prod = log.producer(batch_records=4, write_behind=True)
    msgs = [b"wb-%02d" % i for i in range(16)]
    for m in msgs:
        prod.produce(m)
    prod.close()
    assert drain(cons, len(msgs)) == msgs
    assert cons.position == cluster.client().file_length("/l")
    cons.close()
