"""End-to-end filesystem behaviour: POSIX surface + hierarchy (paper §2.4)."""
import os

import pytest

from repro.core import (SEEK_CUR, SEEK_END, SEEK_SET, AlreadyExists,
                        BadFileDescriptor, Cluster, InvalidOffset,
                        IsADirectory, NotADirectory, NotFound,
                        NotOpenForWriting, WtfError)


@pytest.fixture()
def cluster(tmp_path):
    c = Cluster(n_servers=4, data_dir=str(tmp_path), replication=1,
                region_size=1024)
    yield c
    c.close()


@pytest.fixture()
def fs(cluster):
    return cluster.client()


def test_write_read_roundtrip(fs):
    fd = fs.open("/a", "w")
    assert fs.write(fd, b"hello world") == 11
    fs.seek(fd, 0)
    assert fs.read(fd) == b"hello world"
    fs.close(fd)


def test_one_lookup_open_deep_path(fs):
    fs.mkdir("/d1")
    fs.mkdir("/d1/d2")
    fs.mkdir("/d1/d2/d3")
    fd = fs.open("/d1/d2/d3/file", "w")
    fs.write(fd, b"deep")
    fs.close(fd)
    gets_before = fs.kv.stats.gets
    fd = fs.open("/d1/d2/d3/file", "r")
    gets_after = fs.kv.stats.gets
    # one lookup for the path + one for the inode — no per-component traversal
    assert gets_after - gets_before <= 2
    assert fs.read(fd) == b"deep"
    fs.close(fd)


def test_overwrite_middle(fs):
    fd = fs.open("/f", "w")
    fs.write(fd, b"A" * 100)
    fs.seek(fd, 40)
    fs.write(fd, b"B" * 20)
    fs.seek(fd, 0)
    data = fs.read(fd)
    assert data == b"A" * 40 + b"B" * 20 + b"A" * 40
    fs.close(fd)


def test_cross_region_write(fs):
    """region_size=1024: a 3000-byte write spans 3 regions (Figure 3)."""
    payload = bytes(range(256)) * 12   # 3072 bytes
    fd = fs.open("/big", "w")
    fs.write(fd, payload)
    fs.seek(fd, 0)
    assert fs.read(fd) == payload
    assert fs.stat("/big")["size"] == 3072
    fs.close(fd)


def test_sparse_file_reads_zeros(fs):
    fd = fs.open("/sparse", "w")
    fs.seek(fd, 5000)
    fs.write(fd, b"end")
    fs.seek(fd, 0)
    data = fs.read(fd)
    assert len(data) == 5003
    assert data[:5000] == b"\x00" * 5000
    assert data[5000:] == b"end"
    fs.close(fd)


def test_seek_semantics(fs):
    fd = fs.open("/s", "w")
    fs.write(fd, b"0123456789")
    assert fs.seek(fd, 2) == 2
    assert fs.seek(fd, 3, SEEK_CUR) == 5
    # SEEK_END hides the offset from the application (§2.6)
    assert fs.seek(fd, 0, SEEK_END) is None
    assert fs.tell(fd) == 10
    fs.close(fd)


def test_append_mode_and_calls(fs):
    fd = fs.open("/log", "w")
    fs.write(fd, b"one\n")
    fs.close(fd)
    fd = fs.open("/log", "a")
    fs.append(fd, b"two\n")
    fs.append(fd, b"three\n")
    fs.close(fd)
    fd = fs.open("/log", "r")
    assert fs.read(fd) == b"one\ntwo\nthree\n"
    fs.close(fd)


def test_append_crossing_region_boundary(fs):
    fd = fs.open("/roll", "w")
    fs.write(fd, b"x" * 1000)      # region 0 nearly full (1024)
    fs.append(fd, b"y" * 100)      # cannot fit → fallback write at EOF
    fs.seek(fd, 0)
    data = fs.read(fd)
    assert data == b"x" * 1000 + b"y" * 100
    assert fs.stat("/roll")["size"] == 1100
    fs.close(fd)


def test_mkdir_listdir(fs):
    fs.mkdir("/dir")
    fd = fs.open("/dir/f1", "w"); fs.write(fd, b"1"); fs.close(fd)
    fd = fs.open("/dir/f2", "w"); fs.write(fd, b"2"); fs.close(fd)
    fs.mkdir("/dir/sub")
    assert fs.listdir("/dir") == ["f1", "f2", "sub"]
    with pytest.raises(AlreadyExists):
        fs.mkdir("/dir")
    with pytest.raises(NotFound):
        fs.mkdir("/missing/sub")


def test_hardlink_semantics(fs):
    fd = fs.open("/orig", "w"); fs.write(fd, b"shared"); fs.close(fd)
    fs.link("/orig", "/alias")
    assert fs.stat("/alias")["links"] == 2
    assert fs.stat("/alias")["inode"] == fs.stat("/orig")["inode"]
    fd = fs.open("/alias", "r")
    assert fs.read(fd) == b"shared"
    fs.close(fd)
    fs.unlink("/orig")
    assert not fs.exists("/orig")
    assert fs.stat("/alias")["links"] == 1
    fd = fs.open("/alias", "r")
    assert fs.read(fd) == b"shared"
    fs.close(fd)


def test_unlink_last_link_removes_metadata(fs):
    fd = fs.open("/gone", "w"); fs.write(fd, b"bye"); fs.close(fd)
    ino = fs.stat("/gone")["inode"]
    fs.unlink("/gone")
    assert not fs.exists("/gone")
    assert fs.kv.get("inodes", ino) is None
    assert "gone" not in fs.listdir("/")


def test_rename(fs):
    fs.mkdir("/src"); fs.mkdir("/dst")
    fd = fs.open("/src/f", "w"); fs.write(fd, b"move me"); fs.close(fd)
    fs.rename("/src/f", "/dst/g")
    assert fs.listdir("/src") == []
    assert fs.listdir("/dst") == ["g"]
    fd = fs.open("/dst/g", "r")
    assert fs.read(fd) == b"move me"
    fs.close(fd)


def test_open_truncate(fs):
    fd = fs.open("/t", "w"); fs.write(fd, b"old content"); fs.close(fd)
    fd = fs.open("/t", "w")            # w → truncate
    fs.write(fd, b"new")
    fs.close(fd)
    assert fs.stat("/t")["size"] == 3


def test_errors(fs):
    with pytest.raises(NotFound):
        fs.open("/nope", "r")
    fs.mkdir("/d")
    with pytest.raises(IsADirectory):
        fs.open("/d", "w")
    fd = fs.open("/file", "w"); fs.write(fd, b"x"); fs.close(fd)
    with pytest.raises(NotADirectory):
        fs.open("/file/sub", "w")
    with pytest.raises(AlreadyExists):
        fs.open("/file", "x")


def test_pread_pwrite(fs):
    fd = fs.open("/p", "w")
    fs.write(fd, b"0123456789")
    assert fs.pread(fd, 4, 3) == b"3456"
    fs.pwrite(fd, b"XY", 5)
    assert fs.pread(fd, 10, 0) == b"01234XY789"
    assert fs.tell(fd) == 10           # p-ops do not move the offset
    fs.close(fd)


def test_multiple_clients_see_writes_on_completion(cluster):
    """WTF guarantees all readers see a write upon its completion (§4.2)."""
    c1, c2 = cluster.client(), cluster.client()
    fd1 = c1.open("/shared", "w")
    c1.write(fd1, b"visible")
    fd2 = c2.open("/shared", "r")
    assert c2.read(fd2) == b"visible"


# ----------------------------------------------------- fd write-mode matrix
def test_read_only_fd_rejects_write_ops(fs):
    """``_Fd.writable`` is enforced: every mutating op on an ``"r"`` fd
    raises an EBADF-style error instead of silently mutating the file."""
    fd = fs.open("/ro", "w"); fs.write(fd, b"immutable"); fs.close(fd)
    rd = fs.open("/ro", "r")
    for call in (lambda: fs.write(rd, b"x"),
                 lambda: fs.pwrite(rd, b"x", 0),
                 lambda: fs.writev(rd, [b"x", b"y"]),
                 lambda: fs.pwritev(rd, [b"x"], 0),
                 lambda: fs.append(rd, b"x"),
                 lambda: fs.truncate(rd, 0),
                 lambda: fs.punch(rd, 1)):
        with pytest.raises(NotOpenForWriting):
            call()
    # the EBADF-style error is a BadFileDescriptor subclass
    with pytest.raises(BadFileDescriptor):
        fs.write(rd, b"x")
    # reads and yanks stay legal on a read-only fd
    assert fs.pread(rd, 9, 0) == b"immutable"
    assert sum(e.length for e in fs.yank(rd, 4)) == 4
    fs.close(rd)
    assert fs.stat("/ro")["size"] == 9


def test_read_only_fd_rejects_slice_writes(fs):
    fd = fs.open("/src0", "w"); fs.write(fd, b"payload"); fs.close(fd)
    rd = fs.open("/src0", "r")
    exts = fs.yank(rd, 7)
    fs.seek(rd, 0)
    for call in (lambda: fs.paste(rd, exts),
                 lambda: fs.pastev(rd, [exts]),
                 lambda: fs.append_slices(rd, exts)):
        with pytest.raises(NotOpenForWriting):
            call()
    fs.close(rd)


@pytest.mark.parametrize("mode", ["w", "a", "rw"])
def test_writable_modes_accept_writes(fs, mode):
    fd = fs.open("/wm", "w"); fs.write(fd, b"seed"); fs.close(fd)
    fd = fs.open("/wm", mode)
    assert fs.write(fd, b"ok") == 2
    fs.truncate(fd, 0)
    fs.close(fd)


def test_handle_repr_surfaces_mode(fs):
    with fs.open_file("/reprd", "w") as f:
        assert "mode='w'" in repr(f)
        assert f"fd={f.fd}" in repr(f)
    assert "closed" in repr(f)
    with fs.open_file("/reprd", "r") as f:
        assert "mode='r'" in repr(f)


# ------------------------------------------------------- rename edge cases
def test_rename_into_file_component_rejected(fs):
    """The destination parent must be a directory — never append a dirent
    into a regular file's data."""
    fd = fs.open("/plain.txt", "w"); fs.write(fd, b"data"); fs.close(fd)
    fd = fs.open("/mv", "w"); fs.write(fd, b"m"); fs.close(fd)
    size_before = fs.stat("/plain.txt")["size"]
    with pytest.raises(NotADirectory):
        fs.rename("/mv", "/plain.txt/x")
    assert fs.stat("/plain.txt")["size"] == size_before, \
        "the file's data must be untouched by the failed rename"
    assert fs.exists("/mv")


def test_rename_dir_into_own_subtree_rejected(fs):
    fs.mkdir("/tree"); fs.mkdir("/tree/sub")
    with pytest.raises(WtfError):
        fs.rename("/tree", "/tree/sub/cycle")
    # prefix similarity alone is NOT a cycle
    fs.mkdir("/treeish")
    fs.rename("/treeish", "/tree/sub/ok")
    assert fs.listdir("/tree/sub") == ["ok"]
    # a FILE named like a prefix moves freely into a sibling dir
    fd = fs.open("/tr", "w"); fs.write(fd, b"f"); fs.close(fd)
    fs.rename("/tr", "/tree/tr2")
    assert fs.exists("/tree/tr2")


def test_rename_missing_dest_parent_still_notfound(fs):
    fd = fs.open("/m", "w"); fs.write(fd, b"m"); fs.close(fd)
    with pytest.raises(NotFound):
        fs.rename("/m", "/nodir/m")


# ------------------------------------------------------- negative offsets
def test_negative_offsets_rejected(fs):
    fd = fs.open("/neg", "w")
    fs.write(fd, b"0123456789")
    with pytest.raises(InvalidOffset):
        fs.seek(fd, -1)
    with pytest.raises(InvalidOffset):
        fs.seek(fd, -100, SEEK_CUR)
    with pytest.raises(InvalidOffset):
        fs.seek(fd, -11, SEEK_END)
    assert fs.tell(fd) == 10, "failed seeks must not move the offset"
    with pytest.raises(InvalidOffset):
        fs.pread(fd, 4, -1)
    with pytest.raises(InvalidOffset):
        fs.preadv(fd, [4], -2)
    with pytest.raises(InvalidOffset):
        fs.readv(fd, [(0, 4), (-3, 4)])
    with pytest.raises(InvalidOffset):
        fs.readv(fd, [(0, -4)])
    with pytest.raises(InvalidOffset):
        fs.yankv(fd, [(-1, 4)])
    with pytest.raises(InvalidOffset):
        fs.pwrite(fd, b"x", -1)
    with pytest.raises(InvalidOffset):
        fs.pwritev(fd, [b"x"], -1)
    # InvalidOffset is a WtfError (EINVAL-style), and legal seeks still work
    assert issubclass(InvalidOffset, WtfError)
    assert fs.seek(fd, 3) == 3
    assert fs.seek(fd, -2, SEEK_CUR) == 1
    fs.seek(fd, -10, SEEK_END)
    assert fs.read(fd, 2) == b"01"
    fs.close(fd)
