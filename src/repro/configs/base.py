"""Model/architecture configuration schema.

One `ModelConfig` describes any architecture in the assigned pool: dense GQA
transformers, MoE, Mamba2/xLSTM SSMs, the Zamba2 hybrid, Whisper enc-dec,
and the LLaVA VLM backbone.  Family-specific knobs live in optional
sub-configs; `arch_kind` drives which forward function the registry picks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                   # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # pad the expert dimension of the weight/dispatch tensors so EP can
    # shard a non-divisible expert count (granite: 40 → 48 on a 16-way
    # axis); padded experts receive no routing weight and no tokens
    padded_experts: Optional[int] = None

    @property
    def e_pad(self) -> int:
        return self.padded_experts or self.num_experts


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64             # N
    head_dim: int = 64              # P (per SSM head)
    conv_width: int = 4
    expand: int = 2                 # inner dim = expand * d_model
    chunk: int = 64                 # SSD chunk length


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 7            # one sLSTM block per N mLSTM blocks
    qk_dim_factor: float = 0.5
    v_dim_factor: float = 1.0
    chunk: int = 64


@dataclass(frozen=True)
class HybridConfig:
    attn_period: int = 6            # shared attention block every N blocks
    shared_attention: bool = True   # Zamba2-style weight-shared block


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 24
    encoder_seq: int = 1500         # whisper: 30 s of audio @ 50 Hz


@dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 576          # anyres base tile
    vision_dim: int = 1024          # stubbed vision tower output width


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_kind: str                  # dense | moe | mamba2_hybrid | xlstm |
                                    # whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    sliding_window: Optional[int] = None   # sub-quadratic attention for
                                           # long-context hybrid cells
    long_context_window: Optional[int] = None  # window the launcher applies
                                               # to attn for long_500k only
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    parallel_block: bool = False    # command-r: attn ∥ FFN from one norm
    accum_steps: int = 1            # gradient-accumulation microbatches
                                    # (training memory / HBM fit)
    max_seq: int = 4_096            # learned-position table size (whisper)
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "xla"          # xla | pallas | pallas_interpret
    remat: str = "none"             # none | full | dots
    scan_layers: bool = True
    # long-context capability (drives the long_500k dry-run cell)
    subquadratic: bool = False
    # per-arch logical→mesh rule overrides (e.g. heads→None when the head
    # count does not divide the model axis); tuple-of-pairs for hashability
    rules_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None \
            else self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (drives 6·N·D in the roofline)."""
        d, hd = self.d_model, self.head_dim_
        attn = (d * self.n_heads * hd              # wq
                + 2 * d * self.n_kv_heads * hd     # wk, wv
                + self.n_heads * hd * d)           # wo
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.moe is not None:
            ff = self.moe.num_experts * 3 * d * self.moe.d_expert \
                + d * self.moe.num_experts
        elif self.d_ff > 0:
            ff = 3 * d * self.d_ff                 # SwiGLU
        else:
            ff = 0
        norms = 2 * d
        if self.arch_kind in ("mamba2_hybrid", "xlstm"):
            # SSM blocks are sized separately; rough closed forms below
            return self._ssm_param_count()
        per_layer = attn + ff + norms
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = self.n_layers * per_layer + embed + d
        if self.encdec is not None:
            # encoder self-attn + ffn + cross-attn already included per
            # layer for decoder; add encoder stack
            enc = self.encdec.encoder_layers * (attn + 3 * d * self.d_ff
                                                + norms)
            cross = self.n_layers * attn
            total += enc + cross
        if self.vlm is not None:
            total += self.vlm.vision_dim * d + d
        return total

    def _ssm_param_count(self) -> int:
        d = self.d_model
        s = self.ssm or SSMConfig()
        inner = s.expand * d
        if self.arch_kind == "xlstm":
            x = self.xlstm or XLSTMConfig()
            qk = int(d * x.qk_dim_factor)
            per = d * (2 * qk + 2 * d) + 2 * d * self.d_ff if self.d_ff \
                else d * (2 * qk + 2 * d) + 8 * d * d // 3
            return self.n_layers * per + 2 * self.vocab * d
        # mamba2: in_proj (d → 2*inner + 2*n_groups*state + heads), out_proj
        nheads = inner // s.head_dim
        per = (d * (2 * inner + 2 * s.state_dim + nheads)
               + inner * d + s.conv_width * (inner + 2 * s.state_dim)
               + 2 * nheads + 2 * d)
        total = self.n_layers * per + 2 * self.vocab * d
        if self.hybrid is not None and self.hybrid.shared_attention:
            hd = self.head_dim_
            attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d + 3 * d * self.d_ff)
            total += attn        # one shared block
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full_ff = self.moe.num_experts * 3 * d * self.moe.d_expert
        active_ff = self.moe.top_k * 3 * d * self.moe.d_expert
        return self.param_count() - self.n_layers * (full_ff - active_ff)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                       # train_4k | prefill_32k | ...
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
