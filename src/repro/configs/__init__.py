"""Config registry: ``--arch <id>`` → ModelConfig.

Every assigned architecture has its own module exporting CONFIG (the exact
public-literature configuration) and smoke() (a reduced same-family config
for CPU tests).
"""
from typing import Dict, List

from .base import SHAPES, ModelConfig, ShapeConfig

from . import (command_r_35b, granite_moe_3b_a800m, llava_next_34b,
               mistral_large_123b, olmoe_1b_7b, qwen2_7b, smollm_360m,
               whisper_medium, xlstm_1p3b, zamba2_1p2b)

_MODULES = {
    "mistral-large-123b": mistral_large_123b,
    "command-r-35b": command_r_35b,
    "qwen2-7b": qwen2_7b,
    "smollm-360m": smollm_360m,
    "llava-next-34b": llava_next_34b,
    "zamba2-1.2b": zamba2_1p2b,
    "xlstm-1.3b": xlstm_1p3b,
    "whisper-medium": whisper_medium,
    "olmoe-1b-7b": olmoe_1b_7b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
}

ARCHS: List[str] = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return _MODULES[name].CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _MODULES[name].smoke()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells():
    """All (arch, shape) dry-run cells, with skip rationale for the
    impossible ones (documented in DESIGN.md §Arch-applicability)."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.subquadratic:
                skip = ("full-attention architecture: 500k decode needs "
                        "sub-quadratic attention (see DESIGN.md)")
            out.append((arch, sname, skip))
    return out


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "get_smoke_config", "get_shape", "cells"]
