"""xlstm-1.3b — sLSTM + mLSTM blocks (7 mLSTM : 1 sLSTM).
[arXiv:2405.04517; unverified]

Fully recurrent (no attention): long_500k runs with O(1) state.
"""
from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_kind="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                         # FFN lives inside the blocks
    vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8, qk_dim_factor=0.5, v_dim_factor=1.0,
                      chunk=128),
    subquadratic=True,
    remat="dots",
    rules_overrides=(("heads", None),),   # 4 heads < 16-way model axis
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                          vocab=512, remat="none",
                          xlstm=XLSTMConfig(slstm_every=2, chunk=16))
