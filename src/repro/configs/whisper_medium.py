"""whisper-medium — encoder-decoder audio transformer; conv frontend is a
stub (input_specs() provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]

Decode shapes drive the *decoder* (decoder self-attn KV cache of seq_len,
cross-attention over the fixed 1500-frame encoder output).
"""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_kind="whisper",
    n_layers=24,                    # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=24, encoder_seq=1500),
    remat="none",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab=512, max_seq=64,
                          encdec=EncDecConfig(encoder_layers=2,
                                              encoder_seq=30))
