"""mistral-large-123b — dense GQA transformer.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    arch_kind="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1e6,
    # remat="dots" was tried (§Perf B4): collective −14% but saved dot
    # outputs blow the live set to 1.29 TB/device — "full" + 16-way
    # gradient accumulation is the config that fits HBM
    remat="full",
    accum_steps=16,
    # kv=8 does not divide the 16-way model axis → K/V replicated under TP
    rules_overrides=(("kv_heads", None),),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          head_dim=16, d_ff=256, vocab=512, remat="none",
                          accum_steps=1)
