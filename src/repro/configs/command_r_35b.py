"""command-r-35b — dense GQA, no biases, parallel attn∥FFN block.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_kind="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    parallel_block=True,            # Cohere block: x + attn(ln x) + ffn(ln x)
    tie_embeddings=True,            # command-r ties in/out embeddings
    rope_theta=8e6,
    remat="full",
    rules_overrides=(("kv_heads", None),),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          head_dim=16, d_ff=256, vocab=512, remat="none")
