"""qwen2-7b — dense GQA with QKV biases.  [arXiv:2407.10671; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_kind="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    remat="dots",
    # 28 heads / 4 kv heads do not divide the 16-way model axis
    rules_overrides=(("heads", None), ("kv_heads", None)),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=512, remat="none")
