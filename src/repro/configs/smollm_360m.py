"""smollm-360m — llama-architecture small dense GQA.
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_kind="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    tie_embeddings=True,
    remat="none",
    rules_overrides=(("heads", None), ("kv_heads", None)),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=3, n_kv_heads=1,
                          head_dim=32, d_ff=192, vocab=512)
