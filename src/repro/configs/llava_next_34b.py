"""llava-next-34b — VLM: dense GQA backbone + anyres patch-embedding stub.
The vision tower is a stub per the assignment: input_specs() provides
precomputed patch embeddings [B, num_patches, vision_dim].
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_kind="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    vlm=VLMConfig(num_patches=576, vision_dim=1024),
    remat="full",
    rules_overrides=(("heads", None), ("kv_heads", None)),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          head_dim=16, d_ff=256, vocab=512, remat="none",
                          vlm=VLMConfig(num_patches=8, vision_dim=32))
