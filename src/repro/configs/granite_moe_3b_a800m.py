"""granite-moe-3b-a800m — MoE, 40 experts top-8 (following the explicit
`MoE 40e top-8` spec; the source-bracket note says 32 — recorded in
DESIGN.md §Arch-applicability).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_kind="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    tie_embeddings=True,
    # 40 experts do not divide the 16-way model axis: pad the expert
    # dimension to 48 (dead experts get no routing weight, no tokens) so
    # EP shards 16-way — §Perf hillclimb iteration on this cell
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512,
                  padded_experts=48),
    remat="none",
    # 24 heads / 8 kv do not divide the 16-way model axis; the expert
    # hidden dim must stay unsharded once "experts" maps to model
    rules_overrides=(("heads", None), ("kv_heads", None), ("mlp", None)),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab=512,
                          moe=MoEConfig(num_experts=8, top_k=2,
                                        d_expert=64))
