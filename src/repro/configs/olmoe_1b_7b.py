"""olmoe-1b-7b — MoE, 64 experts top-8.  [arXiv:2409.02060; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_kind="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    remat="dots",
    # EP: experts shard 16-way on "model"; the expert hidden dim must then
    # stay unsharded (a spec may not map one mesh axis twice)
    rules_overrides=(("mlp", None),),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab=512, remat="none",
                          moe=MoEConfig(num_experts=8, top_k=2,
                                        d_expert=128))
