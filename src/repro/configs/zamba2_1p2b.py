"""zamba2-1.2b — hybrid: Mamba2 backbone + one weight-shared attention+MLP
block applied every 6 layers.  [arXiv:2411.15242; hf]

`long_context_window` makes the shared-attention sites sliding-window for
the long_500k cell (SSM state is O(1); only attention needs bounding).
"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_kind="mamba2_hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2,
                  chunk=128),
    hybrid=HybridConfig(attn_period=6, shared_attention=True),
    subquadratic=True,
    long_context_window=8192,
    remat="dots",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, remat="none",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=16),
        hybrid=HybridConfig(attn_period=2, shared_attention=True))
