"""Pure-jnp oracle for the chunked SSD scan: the sequential recurrence,
one token at a time — the ground truth both the chunked jnp path and the
Pallas kernel must match."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_recurrent_ref(xw: jax.Array, dta: jax.Array, b: jax.Array,
                      c: jax.Array,
                      s0: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token SSD recurrence (fp32).

    xw:  [B, S, H, P]  dt-weighted inputs (x · dt)
    dta: [B, S, H]     log-decay per step (dt · A, A < 0)
    b,c: [B, S, N]
    Returns (y [B, S, H, P], s_final [B, H, P, N]).
    """
    bsz, s, h, p = xw.shape
    n = b.shape[-1]
    f32 = jnp.float32
    xw, dta = xw.astype(f32), dta.astype(f32)
    b, c = b.astype(f32), c.astype(f32)

    def step(state, inp):
        xw_t, dta_t, b_t, c_t = inp
        state = state * jnp.exp(dta_t)[:, :, None, None] \
            + jnp.einsum("bhp,bn->bhpn", xw_t, b_t)
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    init = jnp.zeros((bsz, h, p, n), f32) if s0 is None \
        else s0.astype(f32)
    xs = (jnp.moveaxis(xw, 1, 0), jnp.moveaxis(dta, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    s_final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), s_final
