"""jit'd wrapper matching the model-side calling convention
(xh [B,S,H,P], dt [B,S,H] post-softplus, a_log [H], b/c [B,S,N], D [H])
— the same contract as `repro.models.mamba2.ssd_chunked`."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, a_log, b, c, d_skip, *, chunk: int = 128,
             interpret: bool = False):
    """Returns (y [B,S,H,P], s_final [B,H,P,N]); y includes the D·x skip."""
    f32 = jnp.float32
    dt = dt.astype(f32)
    la = -jnp.exp(a_log.astype(f32))
    dta = (dt * la).transpose(0, 2, 1)             # [B,H,S]
    xw = (xh.astype(f32) * dt[..., None]).transpose(0, 2, 1, 3)  # [B,H,S,P]
    y, s_final = ssd_scan_kernel(xw, dta, b.astype(f32), c.astype(f32),
                                 chunk=chunk, interpret=interpret)
    y = y.transpose(0, 2, 1, 3)                    # [B,S,H,P]
    y = y + d_skip.astype(f32)[None, None, :, None] * xh.astype(f32)
    return y, s_final
