"""Chunked SSD (Mamba-2) scan as a Pallas TPU kernel.

Grid = (B, H, n_chunks); the chunk dimension is sequential ("arbitrary"),
carrying the per-(batch, head) SSM state [P, N] in fp32 VMEM scratch.
Per chunk the kernel does the Mamba-2 §6 block decomposition:

  y_intra = (tril(C Bᵀ ⊙ exp(lᵢ−lⱼ))) · XW          (quadratic in Q only)
  y_inter = exp(l) ⊙ (C · Sᵀ)
  S'      = exp(l_Q)·S + Σⱼ exp(l_Q−lⱼ)·XWⱼ ⊗ Bⱼ

Inputs are pre-weighted outside the kernel (xw = x·dt, dta = dt·A): the
elementwise prologue fuses into the surrounding XLA graph, the kernel owns
the scan structure.  VMEM per step ≈ Q·(P+2N+Q)·4B ≈ 0.25 MB at
Q=128, P=N=64 — MXU dims are multiples of 64; Q is the 128-aligned axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# CompilerParams was TPUCompilerParams before the jax rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(xw_ref, dta_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xw = xw_ref[0, 0].astype(jnp.float32)        # [Q, P]
    dta = dta_ref[0, 0].astype(jnp.float32)      # [Q]
    b = b_ref[0].astype(jnp.float32)             # [Q, N]
    c = c_ref[0].astype(jnp.float32)             # [Q, N]

    l = jnp.cumsum(dta)                          # [Q]
    # intra-chunk: M[i,j] = (c_i·b_j)·exp(l_i−l_j) for j ≤ i
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q,Q]
    ldiff = l[:, None] - l[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(jj <= ii, g * jnp.exp(ldiff), 0.0)
    y = jax.lax.dot(m, xw, preferred_element_type=jnp.float32)

    # inter-chunk: exp(l_i) · (c_i · Sᵀ)
    s = s_ref[...]                               # [P, N]
    y = y + jnp.exp(l)[:, None] * jax.lax.dot_general(
        c, s, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [Q, P]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(l_Q)·S + Σ_j exp(l_Q−l_j)·xw_j ⊗ b_j
    decay_end = jnp.exp(l[chunk - 1] - l)        # [Q]
    s_new = s * jnp.exp(l[chunk - 1]) + jax.lax.dot_general(
        xw * decay_end[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [P, N]
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def finish():
        sfin_ref[0, 0] = s_new


def ssd_scan_kernel(xw: jax.Array, dta: jax.Array, b: jax.Array,
                    c: jax.Array, *, chunk: int = 128,
                    interpret: bool = False):
    """xw: [B, H, S, P]; dta: [B, H, S]; b/c: [B, S, N].
    Returns (y [B, H, S, P], s_final [B, H, P, N])."""
    bsz, h, s, p = xw.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def xw_map(bi, hi, ci):
        return (bi, hi, ci, 0)

    def dta_map(bi, hi, ci):
        return (bi, hi, ci)

    def bc_map(bi, hi, ci):
        return (bi, ci, 0)

    def sfin_map(bi, hi, ci):
        return (bi, hi, 0, 0)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), xw_map),
            pl.BlockSpec((1, 1, chunk), dta_map),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, chunk, n), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), xw_map),
            pl.BlockSpec((1, 1, p, n), sfin_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, s, p), xw.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xw, dta, b, c)
    return y, s_final
