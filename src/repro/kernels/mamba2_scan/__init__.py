from .ops import ssd_scan
from .ref import ssd_recurrent_ref

__all__ = ["ssd_scan", "ssd_recurrent_ref"]
