"""jit'd public wrapper for the flash attention kernel.

Layout contract with the models: q [B, S, H, D], k/v [B, S, Hkv, D]
(sequence-major, as produced by the QKV projections).  The wrapper moves
heads outward — the kernel wants contiguous [*, S, D] panes — and attaches
a custom VJP whose backward recomputes attention with the pure-jnp
reference (flash forward is the serving/prefill hot path; training defaults
to attn_impl="xla" where XLA's own fused attention applies)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True,
                    sliding_window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D] → [B, Sq, H, D]."""
    qt = jnp.moveaxis(q, 2, 1)          # [B, H, Sq, D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    out = flash_attention_fwd(qt, kt, vt, causal=causal,
                              sliding_window=sliding_window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return jnp.moveaxis(out, 1, 2)


def _fwd(q, k, v, causal, sliding_window, block_q, block_k, interpret):
    out = flash_attention(q, k, v, causal, sliding_window, block_q,
                          block_k, interpret)
    return out, (q, k, v)


def _bwd(causal, sliding_window, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(
            q_, k_, v_, causal=causal, sliding_window=sliding_window),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
