"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  sliding_window: Optional[int] = None) -> jax.Array:
    """q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D] with H % Hkv == 0.
    fp32 softmax, output in q.dtype — the exact contract the Pallas kernel
    must meet."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = h // hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window is not None:
        mask = mask & (kpos > qpos - sliding_window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
