"""FlashAttention-2 forward as a Pallas TPU kernel.

Tiling: grid = (B·H, Sq/BQ, Skv/BK); the innermost (kv) grid dimension is
sequential ("arbitrary"), so fp32 scratch accumulators persist across kv
blocks for a fixed (head, q-block):

  acc [BQ, D]  running un-normalized output
  m   [BQ]     running row max          (log-sum-exp streaming)
  l   [BQ]     running denominator

VMEM working set per step: q (BQ·D) + k,v (2·BK·D) + acc ≈
(128·128 + 2·128·128 + 128·128) · 4 B ≈ 256 kB — far under the ~16 MB VMEM
budget; BQ=BK=128 keeps every MXU matmul dimension at the native 128.
Causal blocks strictly above the diagonal are skipped with `pl.when`
(the classic ~2× saving for causal masks).

GQA is handled in the index maps: kv head = q head // (H/Hkv) — no
`repeat_kv` materialization anywhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# CompilerParams was TPUCompilerParams before the jax rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool,
                sliding_window: Optional[int],
                block_q: int, block_k: int, kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip fully-masked blocks (strictly above the causal diagonal or
    # entirely left of the sliding window)
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if sliding_window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1
            > q_start - sliding_window)

    @pl.when(relevant)
    def compute():
        q = q_ref[0].astype(jnp.float32)            # [BQ, D]
        k = k_ref[0].astype(jnp.float32)            # [BK, D]
        v = v_ref[0].astype(jnp.float32)            # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [BQ, BK]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if sliding_window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])             # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        sliding_window: Optional[int] = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D]; k/v: [BH_kv... actually [BH, Skv, D] after the ops
    wrapper flattens (batch, head) and resolves GQA groups via index maps.
    This entry takes q [B, H, Sq, D] and k/v [B, Hkv, Skv, D]."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    groups = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    q_blocks = pl.cdiv(sq, block_q)
    kv_blocks = pl.cdiv(skv, block_k)

    grid = (b * h, q_blocks, kv_blocks)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh % h) // groups + (bh // h) * hkv, ki, 0)

    kernel = functools.partial(
        _fwd_kernel, scale=1.0 / np.sqrt(d), causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        kv_blocks=kv_blocks)

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
