"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, page_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """q: [B, H, D]; k_pages/v_pages: [Hkv, P, T, D];
    page_table: [B, pages_per_seq] int32 (-1 = unused);
    lengths: [B] int32.  Returns [B, H, D].

    Gathers each sequence's pages (the metadata-list walk, materialized),
    then does masked softmax attention for the single query token.
    """
    b, h, d = q.shape
    hkv, _, t, _ = k_pages.shape
    groups = h // hkv
    pp = page_table.shape[1]

    tbl = jnp.maximum(page_table, 0)                   # [B, PP]
    k = jnp.moveaxis(k_pages[:, tbl], 0, 2)            # [B, PP, Hkv, T, D]
    v = jnp.moveaxis(v_pages[:, tbl], 0, 2)
    k = k.transpose(0, 1, 3, 2, 4).reshape(b, pp * t, hkv, d)
    v = v.transpose(0, 1, 3, 2, 4).reshape(b, pp * t, hkv, d)
    k = jnp.repeat(k, groups, axis=2)                  # [B, S, H, D]
    v = jnp.repeat(v, groups, axis=2)

    logits = jnp.einsum("bhd,bshd->bhs", q, k,
                        preferred_element_type=jnp.float32) / np.sqrt(d)
    pos = jnp.arange(pp * t)[None, :]
    mask = pos < lengths[:, None]                      # [B, S]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs.astype(v.dtype), v)
