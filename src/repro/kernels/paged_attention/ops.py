"""jit'd wrapper: adapts the serving PagedKVCache layout
([L, P, T, Hkv, D] pools + python page tables) to the kernel layout and
dispatches per layer."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import paged_attention_kernel
from .ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def paged_attention(q, k_pages, v_pages, page_table, lengths,
                    *, interpret: bool = False, use_kernel: bool = True):
    """q: [B, H, D]; k_pages/v_pages: [P, T, Hkv, D] (pool layout);
    page_table: [B, PP]; lengths: [B] → [B, H, D]."""
    kp = jnp.moveaxis(k_pages, 2, 0)      # [Hkv, P, T, D]
    vp = jnp.moveaxis(v_pages, 2, 0)
    if use_kernel:
        return paged_attention_kernel(q, kp, vp, page_table, lengths,
                                      interpret=interpret)
    return paged_attention_ref(q, kp, vp, page_table, lengths)
