"""Paged decode attention as a Pallas TPU kernel.

This is WTF's slice-pointer indirection turned into a kernel input format:
the page table (= the metadata list) is SCALAR-PREFETCHED, and the K/V
page index maps dereference it directly —

    index_map(b, hkv, i, table, lens) -> (hkv, table[b, i], 0, 0)

so the kernel streams exactly the pages a sequence references, in table
order, without ever materializing the gathered K/V.  Streaming softmax
state (acc/m/l) persists in VMEM scratch across the page grid dimension.

Tiling: grid = (B, Hkv, pages_per_seq); per-step VMEM = one K page + one
V page (T·D each) + the q head-group pane (G·D) ≈ tens of kB.  Pages past
a sequence's length are skipped with `pl.when` (no wasted bandwidth on
short sequences — the table walk stops where the metadata ends).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# CompilerParams was TPUCompilerParams before the jax rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page_tokens: int, pages: int,
            scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[b]
    start = i * page_tokens

    @pl.when(start < length)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [T, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [T, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, T]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == pages - 1)
    def finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; k_pages/v_pages: [Hkv, P, T, D];
    page_table: [B, PP] int32 (-1 = unused); lengths: [B].
    Returns [B, H, D]."""
    b, h, d = q.shape
    hkv, _, t, _ = k_pages.shape
    groups = h // hkv
    pp = page_table.shape[1]

    qg = q.reshape(b, hkv, groups, d)
    table = jnp.maximum(page_table, 0).astype(jnp.int32)

    def q_map(bi, hi, i, tbl, lens):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, i, tbl, lens):
        return (hi, tbl[bi, i], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pp),
        in_specs=[
            pl.BlockSpec((1, 1, groups, d), q_map),
            pl.BlockSpec((1, 1, t, d), kv_map),
            pl.BlockSpec((1, 1, t, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, groups, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((groups, d), jnp.float32),
            pltpu.VMEM((groups,), jnp.float32),
            pltpu.VMEM((groups,), jnp.float32),
        ],
    )

    kernel = functools.partial(_kernel, page_tokens=t, pages=pp,
                               scale=1.0 / np.sqrt(d))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, groups, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, h, d)
