"""The file-slicing algebra (paper §2.1, Figure 2).

A *slice* is an immutable, byte-addressable, arbitrarily sized sequence of
bytes living on a storage server.  A *slice pointer* is the self-contained
tuple (server id, backing file, offset, length) that locates it; sub-ranges of
slices are derived with plain arithmetic and never touch the data.

A file region's metadata is an ordered list of *extents*: each extent overlays
a slice (or zeros, for ``punch``) at a region-relative offset, and later
entries take precedence over earlier ones.  ``compact`` reduces such a list to
the minimal non-overlapping form, merging extents that are adjacent both in
the file and on disk (the payoff of locality-aware placement, §2.7).

Everything in this module is pure data manipulation: no I/O, no locking.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Sequence, Tuple


@dataclass(frozen=True, slots=True)
class SlicePointer:
    """Self-contained locator for an immutable slice (paper §2.1).

    Everything needed to retrieve the bytes is here; no other bookkeeping
    exists anywhere in the system.
    """

    server_id: int
    backing_file: str
    offset: int          # byte offset of the slice within the backing file
    length: int          # number of bytes

    def sub(self, start: int, length: int) -> "SlicePointer":
        """Derive a pointer to ``[start, start+length)`` of this slice.

        This is the 'simple arithmetic' the paper relies on to build new
        slice pointers that reference subsequences of existing slices.
        """
        if start < 0 or length < 0 or start + length > self.length:
            raise ValueError(
                f"sub-slice [{start},{start + length}) out of bounds "
                f"for slice of length {self.length}"
            )
        return SlicePointer(self.server_id, self.backing_file,
                            self.offset + start, length)

    def is_adjacent(self, other: "SlicePointer") -> bool:
        """True if ``other`` begins exactly where this slice ends on disk."""
        return (self.server_id == other.server_id
                and self.backing_file == other.backing_file
                and self.offset + self.length == other.offset)


@dataclass(frozen=True, slots=True)
class Extent:
    """One overlay entry in a region's metadata list.

    ``offset`` is region-relative.  ``ptrs`` holds one slice pointer per
    replica (paper §2.9: each metadata entry references multiple replica
    pointers; readers may use any).  A *zero extent* (``ptrs == ()``) reads
    back as zeros — produced by ``punch`` — and obscures extents below it.
    """

    offset: int
    length: int
    ptrs: Tuple[SlicePointer, ...] = ()

    @property
    def end(self) -> int:
        return self.offset + self.length

    @property
    def is_zero(self) -> bool:
        return not self.ptrs

    def sub(self, start: int, length: int) -> "Extent":
        """Extent covering ``[offset+start, offset+start+length)``."""
        if start < 0 or length < 0 or start + length > self.length:
            raise ValueError("sub-extent out of bounds")
        return Extent(
            offset=self.offset + start,
            length=length,
            ptrs=tuple(p.sub(start, length) for p in self.ptrs),
        )

    def at(self, new_offset: int) -> "Extent":
        """Same bytes, overlaid at a different offset (used by paste)."""
        return dataclasses.replace(self, offset=new_offset)

    def can_merge(self, nxt: "Extent") -> bool:
        """True if ``nxt`` continues this extent both in the file and on disk
        for every replica (so the pair collapses into one pointer, §2.7)."""
        if self.end != nxt.offset:
            return False
        if self.is_zero and nxt.is_zero:
            return True
        if len(self.ptrs) != len(nxt.ptrs) or self.is_zero != nxt.is_zero:
            return False
        return all(a.is_adjacent(b) for a, b in zip(self.ptrs, nxt.ptrs))

    def merge(self, nxt: "Extent") -> "Extent":
        if not self.can_merge(nxt):
            raise ValueError("extents are not mergeable")
        if self.is_zero:
            return Extent(self.offset, self.length + nxt.length, ())
        return Extent(
            self.offset,
            self.length + nxt.length,
            tuple(SlicePointer(a.server_id, a.backing_file, a.offset,
                               a.length + b.length)
                  for a, b in zip(self.ptrs, nxt.ptrs)),
        )


def overlay(entries: Sequence[Extent]) -> list[Extent]:
    """Resolve an ordered overlay list into non-overlapping extents.

    Later entries take precedence (Figure 2: slice C obscures A and B; E
    obscures D and part of C).  Returns extents sorted by offset.  Holes
    (never-written gaps) are simply absent from the output.

    Reverse sweep with a sorted coverage map: each entry contributes only
    its not-yet-covered sub-ranges, so the common append-only list resolves
    in O(n log n) (the first implementation rebuilt and re-sorted the
    resolved list per entry — O(n²) — which made bulk yank/paste quadratic;
    see EXPERIMENTS.md §Perf, WTF-side iteration 1).

    The output is *canonical*: each fragment is a maximal visible
    contiguous sub-range of one entry, sorted by offset — the unique
    decomposition of "which entry is visible at each byte".
    ``overlay_extend`` relies on this to update a resolved form
    incrementally and land on the structurally identical result.
    """
    frags: list[Extent] = []
    # sorted, disjoint covered intervals as a flat boundary list
    # [s0, e0, s1, e1, ...]
    bounds: list[int] = []
    for entry in reversed(entries):
        if entry.length == 0:
            continue
        lo, hi = entry.offset, entry.end
        # find uncovered gaps of [lo, hi) against the coverage map
        i = bisect.bisect_right(bounds, lo)
        pos = lo
        gaps: list[tuple[int, int]] = []
        if i % 2 == 1:                    # lo lands inside a covered run
            pos = bounds[i] if i < len(bounds) else hi
            i += 1
        while pos < hi:
            nxt_start = bounds[i] if i < len(bounds) else hi
            g_end = min(nxt_start, hi)
            if pos < g_end:
                gaps.append((pos, g_end))
            if i + 1 < len(bounds):
                pos = bounds[i + 1]
            else:
                pos = hi
            i += 2
        for g_lo, g_hi in gaps:
            frags.append(entry.sub(g_lo - entry.offset, g_hi - g_lo))
        # insert [lo, hi) into the coverage map (merge touching runs)
        li = bisect.bisect_left(bounds, lo)
        ri = bisect.bisect_right(bounds, hi)
        new: list[int] = []
        if li % 2 == 0:                   # lo starts outside coverage
            new.append(lo)
        if ri % 2 == 0:                   # hi ends outside coverage
            new.append(hi)
        bounds[li:ri] = new
    frags.sort(key=lambda e: e.offset)
    return frags


def _overlay_cached_impl(entries: Tuple[Extent, ...]) -> tuple:
    return tuple(overlay(entries))


try:
    from functools import lru_cache
    _overlay_cached_impl = lru_cache(maxsize=512)(_overlay_cached_impl)
except Exception:                                   # pragma: no cover
    pass


def overlay_cached(entries: Sequence[Extent]) -> list[Extent]:
    """`overlay` memoized on the (immutable) entries tuple — region lists
    are read far more often than they change (every read/yank plans against
    the same committed RegionData), so repeated resolution is pure waste.

    Entries holding non-``SlicePointer`` pointers (the write-behind
    buffer's pending placeholders, which carry the full payload bytes) are
    never memoized: caching them would pin dead payloads in this
    process-global LRU long after their transaction ended, and such lists
    are transaction-transient anyway."""
    if not isinstance(entries, tuple) or any(
            type(p) is not SlicePointer for e in entries for p in e.ptrs):
        return overlay(entries)
    return list(_overlay_cached_impl(entries))


def overlay_extend(resolved: Sequence[Extent],
                   entries: Sequence[Extent]) -> list[Extent]:
    """Incrementally overlay ``entries`` (in order, later wins) on an
    already-resolved form — the delta maintenance behind the region
    resolved index.

    ``resolved`` must be a canonical ``overlay`` result (sorted, disjoint,
    maximal fragments).  Appending k extents costs O(k log n) bisects plus
    the splice, instead of re-running ``overlay`` over the region's whole
    write history — the difference between O(1) and O(history) planning for
    a hot region absorbing a small-append stream.  Because the canonical
    decomposition is unique, the result is *structurally identical* to
    ``overlay(old_entries + entries)`` (property-tested), so read plans,
    op digests and §2.6 replays are unaffected by which path produced them.

    ``resolved`` is never mutated; a fresh list is returned.
    """
    out = list(resolved)
    for e in entries:
        if e.length == 0:
            continue
        lo, hi = e.offset, e.end
        # first fragment that can overlap [lo, hi): fragments are sorted
        # and disjoint, so offsets AND ends are both increasing
        i = bisect.bisect_right(out, lo, key=lambda f: f.end)
        j = i
        left: Optional[Extent] = None
        right: Optional[Extent] = None
        while j < len(out) and out[j].offset < hi:
            f = out[j]
            if f.offset < lo:
                left = f.sub(0, lo - f.offset)
            if f.end > hi:
                right = f.sub(hi - f.offset, f.end - hi)
            j += 1
        out[i:j] = [x for x in (left, e, right) if x is not None]
    return out


class ResolvedIndexCache:
    """Delta-maintained resolved overlays, one entry per hot region.

    Region overlay lists only ever *grow* between compactions, and WarpKV
    appends extend the stored tuple (``old + new``), so successive
    versions of a region share their prefix as identical objects.  This
    cache exploits that: keyed on ``(inode, region)``, it remembers the
    last entries tuple and its resolved form, and when asked about a
    longer tuple with an identical prefix it applies only the delta via
    ``overlay_extend`` — O(k log n) for k appended extents — instead of
    re-resolving the entire history (the quadratic planning cost a hot
    region's small-append + re-read stream used to pay).

    The prefix check compares object *identity*, so a false hit is
    impossible: any wholesale replacement (compaction, truncate, GC
    tier-1/2, a relative append's commit-time re-resolution) fails the
    check and falls back to a full ``overlay``.  Entries carrying
    non-``SlicePointer`` pointers (write-behind pending placeholders)
    bypass the cache entirely, mirroring ``overlay_cached``: they are
    transaction-private and must never be pinned here.

    Thread-safe (async op bodies plan from pool workers).  Stored resolved
    lists are never mutated — ``overlay_extend`` copies — so returned
    references are safe to read outside the lock.
    """

    __slots__ = ("maxsize", "_lock", "_entries")

    def __init__(self, maxsize: int = 512):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        # key -> (entries_tuple, resolved_list)
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def resolve(self, key: tuple, entries: Tuple[Extent, ...],
                stats=None) -> list[Extent]:
        """Resolved overlay of ``entries``; ``stats`` (duck-typed
        ``ClientStats``) records ``resolved_index_hits``/``_misses``.

        Resolution itself runs OUTSIDE the cache lock — a cold large
        region must not stall every other planner (async op bodies plan
        concurrently).  Racing resolutions of the same key just do
        duplicate work; the canonical form makes either result correct.
        """
        if any(type(p) is not SlicePointer for e in entries for p in e.ptrs):
            return overlay(entries)          # pending placeholders: bypass
        base = None
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                self._entries.move_to_end(key)
                tup, res = ent
                n = len(tup)
                if len(entries) >= n:
                    i = 0
                    while i < n and entries[i] is tup[i]:
                        i += 1
                    if i == n:
                        if len(entries) == n:
                            if stats is not None:
                                stats.add(resolved_index_hits=1)
                            return res
                        base = res
        if base is not None:
            out = overlay_extend(base, entries[n:])
        else:
            out = overlay(entries)
        with self._lock:
            self._store(key, entries, out)
        if stats is not None:
            if base is not None:
                stats.add(resolved_index_hits=1)
            else:
                stats.add(resolved_index_misses=1)
        return out

    def _store(self, key: tuple, tup: Tuple[Extent, ...],
               resolved: list) -> None:
        self._entries[key] = (tup, resolved)
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


def merge_adjacent(extents: Sequence[Extent]) -> list[Extent]:
    """Collapse runs that are contiguous in the file *and* on disk into
    single pointers — the compaction payoff of locality-aware placement."""
    merged: list[Extent] = []
    for ext in extents:
        if merged and merged[-1].can_merge(ext):
            merged[-1] = merged[-1].merge(ext)
        else:
            merged.append(ext)
    return merged


def compact(entries: Sequence[Extent]) -> list[Extent]:
    """Minimal metadata list equivalent to ``entries`` (Figure 2 'Compacted').

    Overlay resolution + adjacent-slice merging.  The result reconstructs the
    identical bytes while never referencing data obscured by later writes.
    """
    return merge_adjacent(overlay(entries))


def visible_length(entries: Sequence[Extent]) -> int:
    """Highest written offset in the overlay list (region-relative end)."""
    return max((e.end for e in entries), default=0)


def slice_range(
    entries: Sequence[Extent], start: int, length: int
) -> list[Extent]:
    """Extents covering ``[start, start+length)`` of the resolved overlay.

    Gaps (holes) are returned as zero extents so that the output tiles the
    requested range exactly.  This is the read/yank planner: each returned
    extent is either a zero run or a sub-sliced pointer to fetch.
    """
    return slice_resolved(overlay_cached(entries), start, length)


def slice_resolved(
    resolved: Sequence[Extent], start: int, length: int
) -> list[Extent]:
    """``slice_range`` against an already-resolved overlay.

    Vectored ops plan many ranges against the same region; resolving (and
    cache-hashing) the entry list once per op instead of once per range,
    and bisecting into the sorted disjoint overlay instead of scanning it,
    is what keeps a 4096-range ``yankv`` O(n log n) instead of O(n²)."""
    if length <= 0:
        return []
    end = start + length
    out: list[Extent] = []
    cursor = start
    # first extent that can overlap [start, end): the one at or before start
    i = bisect.bisect_right(resolved, start, key=lambda e: e.offset) - 1
    if i < 0:
        i = 0
    for ext in resolved[i:]:
        if ext.offset >= end:
            break
        if ext.end <= start:
            continue
        lo = max(ext.offset, start)
        hi = min(ext.end, end)
        if lo > cursor:                      # hole before this extent
            out.append(Extent(cursor, lo - cursor, ()))
        out.append(ext.sub(lo - ext.offset, hi - lo))
        cursor = hi
    if cursor < end:                         # trailing hole
        out.append(Extent(cursor, end - cursor, ()))
    return out


def shift(entries: Iterable[Extent], delta: int) -> list[Extent]:
    """Translate extents by ``delta`` bytes (region <-> file coordinates)."""
    return [dataclasses.replace(e, offset=e.offset + delta) for e in entries]


def split_by_regions(
    offset: int, length: int, region_size: int
) -> Iterator[Tuple[int, int, int, int]]:
    """Split a file-absolute byte range into per-region pieces.

    Yields (region_index, region_relative_offset, piece_offset_in_range,
    piece_length) — used by writes/pastes that cross region boundaries
    (Figure 3: write C is atomically applied to both region lists).
    """
    pos = offset
    end = offset + length
    while pos < end:
        region = pos // region_size
        rel = pos - region * region_size
        take = min(end - pos, region_size - rel)
        yield region, rel, pos - offset, take
        pos += take


# ---------------------------------------------------------------------------
# Serialization — extents must round-trip through slices themselves for the
# tier-2 GC (metadata spilled into a slice, §2.8) and for directory files.
# ---------------------------------------------------------------------------

def encode_extents(extents: Sequence[Extent]) -> bytes:
    from repro.util import jsonio

    return jsonio.dumps([
        {
            "o": e.offset,
            "l": e.length,
            "p": [[p.server_id, p.backing_file, p.offset, p.length]
                  for p in e.ptrs],
        }
        for e in extents
    ])


def decode_extents(data: bytes) -> list[Extent]:
    from repro.util import jsonio

    return [
        Extent(
            offset=d["o"],
            length=d["l"],
            ptrs=tuple(SlicePointer(*p) for p in d["p"]),
        )
        for d in jsonio.loads(data)
    ]
