"""The file-slicing algebra (paper §2.1, Figure 2).

A *slice* is an immutable, byte-addressable, arbitrarily sized sequence of
bytes living on a storage server.  A *slice pointer* is the self-contained
tuple (server id, backing file, offset, length) that locates it; sub-ranges of
slices are derived with plain arithmetic and never touch the data.

A file region's metadata is an ordered list of *extents*: each extent overlays
a slice (or zeros, for ``punch``) at a region-relative offset, and later
entries take precedence over earlier ones.  ``compact`` reduces such a list to
the minimal non-overlapping form, merging extents that are adjacent both in
the file and on disk (the payoff of locality-aware placement, §2.7).

Everything in this module is pure data manipulation: no I/O, no locking.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Sequence, Tuple


@dataclass(frozen=True, slots=True)
class SlicePointer:
    """Self-contained locator for an immutable slice (paper §2.1).

    Everything needed to retrieve the bytes is here; no other bookkeeping
    exists anywhere in the system.
    """

    server_id: int
    backing_file: str
    offset: int          # byte offset of the slice within the backing file
    length: int          # number of bytes

    def sub(self, start: int, length: int) -> "SlicePointer":
        """Derive a pointer to ``[start, start+length)`` of this slice.

        This is the 'simple arithmetic' the paper relies on to build new
        slice pointers that reference subsequences of existing slices.
        """
        if start < 0 or length < 0 or start + length > self.length:
            raise ValueError(
                f"sub-slice [{start},{start + length}) out of bounds "
                f"for slice of length {self.length}"
            )
        return SlicePointer(self.server_id, self.backing_file,
                            self.offset + start, length)

    def is_adjacent(self, other: "SlicePointer") -> bool:
        """True if ``other`` begins exactly where this slice ends on disk."""
        return (self.server_id == other.server_id
                and self.backing_file == other.backing_file
                and self.offset + self.length == other.offset)


@dataclass(frozen=True, slots=True)
class Extent:
    """One overlay entry in a region's metadata list.

    ``offset`` is region-relative.  ``ptrs`` holds one slice pointer per
    replica (paper §2.9: each metadata entry references multiple replica
    pointers; readers may use any).  A *zero extent* (``ptrs == ()``) reads
    back as zeros — produced by ``punch`` — and obscures extents below it.
    """

    offset: int
    length: int
    ptrs: Tuple[SlicePointer, ...] = ()

    @property
    def end(self) -> int:
        return self.offset + self.length

    @property
    def is_zero(self) -> bool:
        return not self.ptrs

    def sub(self, start: int, length: int) -> "Extent":
        """Extent covering ``[offset+start, offset+start+length)``."""
        if start < 0 or length < 0 or start + length > self.length:
            raise ValueError("sub-extent out of bounds")
        return Extent(
            offset=self.offset + start,
            length=length,
            ptrs=tuple(p.sub(start, length) for p in self.ptrs),
        )

    def at(self, new_offset: int) -> "Extent":
        """Same bytes, overlaid at a different offset (used by paste)."""
        return dataclasses.replace(self, offset=new_offset)

    def can_merge(self, nxt: "Extent") -> bool:
        """True if ``nxt`` continues this extent both in the file and on disk
        for every replica (so the pair collapses into one pointer, §2.7)."""
        if self.end != nxt.offset:
            return False
        if self.is_zero and nxt.is_zero:
            return True
        if len(self.ptrs) != len(nxt.ptrs) or self.is_zero != nxt.is_zero:
            return False
        return all(a.is_adjacent(b) for a, b in zip(self.ptrs, nxt.ptrs))

    def merge(self, nxt: "Extent") -> "Extent":
        if not self.can_merge(nxt):
            raise ValueError("extents are not mergeable")
        if self.is_zero:
            return Extent(self.offset, self.length + nxt.length, ())
        return Extent(
            self.offset,
            self.length + nxt.length,
            tuple(SlicePointer(a.server_id, a.backing_file, a.offset,
                               a.length + b.length)
                  for a, b in zip(self.ptrs, nxt.ptrs)),
        )


def overlay(entries: Sequence[Extent]) -> list[Extent]:
    """Resolve an ordered overlay list into non-overlapping extents.

    Later entries take precedence (Figure 2: slice C obscures A and B; E
    obscures D and part of C).  Returns extents sorted by offset.  Holes
    (never-written gaps) are simply absent from the output.

    Reverse sweep with a sorted coverage map: each entry contributes only
    its not-yet-covered sub-ranges, so the common append-only list resolves
    in O(n log n) (the first implementation rebuilt and re-sorted the
    resolved list per entry — O(n²) — which made bulk yank/paste quadratic;
    see EXPERIMENTS.md §Perf, WTF-side iteration 1).
    """
    import bisect

    frags: list[Extent] = []
    # sorted, disjoint covered intervals as a flat boundary list
    # [s0, e0, s1, e1, ...]
    bounds: list[int] = []
    for entry in reversed(entries):
        if entry.length == 0:
            continue
        lo, hi = entry.offset, entry.end
        # find uncovered gaps of [lo, hi) against the coverage map
        i = bisect.bisect_right(bounds, lo)
        pos = lo
        gaps: list[tuple[int, int]] = []
        if i % 2 == 1:                    # lo lands inside a covered run
            pos = bounds[i] if i < len(bounds) else hi
            i += 1
        while pos < hi:
            nxt_start = bounds[i] if i < len(bounds) else hi
            g_end = min(nxt_start, hi)
            if pos < g_end:
                gaps.append((pos, g_end))
            if i + 1 < len(bounds):
                pos = bounds[i + 1]
            else:
                pos = hi
            i += 2
        for g_lo, g_hi in gaps:
            frags.append(entry.sub(g_lo - entry.offset, g_hi - g_lo))
        # insert [lo, hi) into the coverage map (merge touching runs)
        li = bisect.bisect_left(bounds, lo)
        ri = bisect.bisect_right(bounds, hi)
        new: list[int] = []
        if li % 2 == 0:                   # lo starts outside coverage
            new.append(lo)
        if ri % 2 == 0:                   # hi ends outside coverage
            new.append(hi)
        bounds[li:ri] = new
    frags.sort(key=lambda e: e.offset)
    return frags


def _overlay_cached_impl(entries: Tuple[Extent, ...]) -> tuple:
    return tuple(overlay(entries))


try:
    from functools import lru_cache
    _overlay_cached_impl = lru_cache(maxsize=512)(_overlay_cached_impl)
except Exception:                                   # pragma: no cover
    pass


def overlay_cached(entries: Sequence[Extent]) -> list[Extent]:
    """`overlay` memoized on the (immutable) entries tuple — region lists
    are read far more often than they change (every read/yank plans against
    the same committed RegionData), so repeated resolution is pure waste.

    Entries holding non-``SlicePointer`` pointers (the write-behind
    buffer's pending placeholders, which carry the full payload bytes) are
    never memoized: caching them would pin dead payloads in this
    process-global LRU long after their transaction ended, and such lists
    are transaction-transient anyway."""
    if not isinstance(entries, tuple) or any(
            type(p) is not SlicePointer for e in entries for p in e.ptrs):
        return overlay(entries)
    return list(_overlay_cached_impl(entries))


def merge_adjacent(extents: Sequence[Extent]) -> list[Extent]:
    """Collapse runs that are contiguous in the file *and* on disk into
    single pointers — the compaction payoff of locality-aware placement."""
    merged: list[Extent] = []
    for ext in extents:
        if merged and merged[-1].can_merge(ext):
            merged[-1] = merged[-1].merge(ext)
        else:
            merged.append(ext)
    return merged


def compact(entries: Sequence[Extent]) -> list[Extent]:
    """Minimal metadata list equivalent to ``entries`` (Figure 2 'Compacted').

    Overlay resolution + adjacent-slice merging.  The result reconstructs the
    identical bytes while never referencing data obscured by later writes.
    """
    return merge_adjacent(overlay(entries))


def visible_length(entries: Sequence[Extent]) -> int:
    """Highest written offset in the overlay list (region-relative end)."""
    return max((e.end for e in entries), default=0)


def slice_range(
    entries: Sequence[Extent], start: int, length: int
) -> list[Extent]:
    """Extents covering ``[start, start+length)`` of the resolved overlay.

    Gaps (holes) are returned as zero extents so that the output tiles the
    requested range exactly.  This is the read/yank planner: each returned
    extent is either a zero run or a sub-sliced pointer to fetch.
    """
    return slice_resolved(overlay_cached(entries), start, length)


def slice_resolved(
    resolved: Sequence[Extent], start: int, length: int
) -> list[Extent]:
    """``slice_range`` against an already-resolved overlay.

    Vectored ops plan many ranges against the same region; resolving (and
    cache-hashing) the entry list once per op instead of once per range,
    and bisecting into the sorted disjoint overlay instead of scanning it,
    is what keeps a 4096-range ``yankv`` O(n log n) instead of O(n²)."""
    if length <= 0:
        return []
    import bisect

    end = start + length
    out: list[Extent] = []
    cursor = start
    # first extent that can overlap [start, end): the one at or before start
    i = bisect.bisect_right(resolved, start, key=lambda e: e.offset) - 1
    if i < 0:
        i = 0
    for ext in resolved[i:]:
        if ext.offset >= end:
            break
        if ext.end <= start:
            continue
        lo = max(ext.offset, start)
        hi = min(ext.end, end)
        if lo > cursor:                      # hole before this extent
            out.append(Extent(cursor, lo - cursor, ()))
        out.append(ext.sub(lo - ext.offset, hi - lo))
        cursor = hi
    if cursor < end:                         # trailing hole
        out.append(Extent(cursor, end - cursor, ()))
    return out


def shift(entries: Iterable[Extent], delta: int) -> list[Extent]:
    """Translate extents by ``delta`` bytes (region <-> file coordinates)."""
    return [dataclasses.replace(e, offset=e.offset + delta) for e in entries]


def split_by_regions(
    offset: int, length: int, region_size: int
) -> Iterator[Tuple[int, int, int, int]]:
    """Split a file-absolute byte range into per-region pieces.

    Yields (region_index, region_relative_offset, piece_offset_in_range,
    piece_length) — used by writes/pastes that cross region boundaries
    (Figure 3: write C is atomically applied to both region lists).
    """
    pos = offset
    end = offset + length
    while pos < end:
        region = pos // region_size
        rel = pos - region * region_size
        take = min(end - pos, region_size - rel)
        yield region, rel, pos - offset, take
        pos += take


# ---------------------------------------------------------------------------
# Serialization — extents must round-trip through slices themselves for the
# tier-2 GC (metadata spilled into a slice, §2.8) and for directory files.
# ---------------------------------------------------------------------------

def encode_extents(extents: Sequence[Extent]) -> bytes:
    from repro.util import jsonio

    return jsonio.dumps([
        {
            "o": e.offset,
            "l": e.length,
            "p": [[p.server_id, p.backing_file, p.offset, p.length]
                  for p in e.ptrs],
        }
        for e in extents
    ])


def decode_extents(data: bytes) -> list[Extent]:
    from repro.util import jsonio

    return [
        Extent(
            offset=d["o"],
            length=d["l"],
            ptrs=tuple(SlicePointer(*p) for p in d["p"]),
        )
        for d in jsonio.loads(data)
    ]
