"""First-class file handles: ``WtfFile``.

Raw integer fds force call sites to thread ``(client, fd)`` pairs around and
to remember ``close`` on every path (TxForest, arXiv 1908.10273, makes the
case for typed handles over raw fds).  ``WtfFile`` wraps the pair as a
context manager carrying the full scalar + vectored I/O surface; it is what
``WtfClient.open_file`` returns and what the internal consumers
(checkpointing, data pipeline, benchmarks) use instead of fd juggling.

The handle adds no transactional semantics of its own: every method
delegates to the owning client, so a handle used inside
``client.transaction()`` participates in that transaction like any other
call.

``buffered=True`` (``open_file(path, mode, buffered=True)``) opts the
handle's data-writing calls into the client's write-behind buffer even when
the ``Cluster(write_behind=...)`` knob is off: payloads are recorded as
pending stores and flush in one scheduled pass at the enclosing commit
boundary — the surrounding ``WtfTransaction``'s commit, or the auto-commit
of each op.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from .slicing import Extent


class WtfFile:
    """A file handle bound to one ``WtfClient`` fd.  Not thread-safe (one
    client per thread, per the client library's contract)."""

    __slots__ = ("client", "fd", "path", "mode", "buffered", "_closed")

    def __init__(self, client, fd: int, path: str, mode: str,
                 buffered: bool = False):
        self.client = client
        self.fd = fd
        self.path = path
        self.mode = mode
        self.buffered = buffered
        self._closed = False

    def _buffered_call(self, fn, *args):
        """Run a data-writing client call with the write-behind flag raised
        when this handle opted in (restores the client's flag after)."""
        if not self.buffered:
            return fn(*args)
        c = self.client
        prev = c._op_buffered
        c._op_buffered = True
        try:
            return fn(*args)
        finally:
            c._op_buffered = prev

    # ------------------------------------------------------------- lifecycle
    def __enter__(self) -> "WtfFile":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    def close(self) -> None:
        if not self._closed:
            self.client.close(self.fd)
            self._closed = True

    @property
    def closed(self) -> bool:
        return self._closed

    def __repr__(self) -> str:
        state = "closed" if self._closed else f"fd={self.fd}"
        buf = " buffered" if self.buffered else ""
        return f"<WtfFile {self.path!r} mode={self.mode!r} {state}{buf}>"

    # ------------------------------------------------------------ scalar I/O
    def read(self, size: int = -1) -> bytes:
        return self.client.read(self.fd, size)

    def pread(self, size: int, offset: int) -> bytes:
        return self.client.pread(self.fd, size, offset)

    def write(self, data: bytes) -> int:
        return self._buffered_call(self.client.write, self.fd, data)

    def pwrite(self, data: bytes, offset: int) -> int:
        return self._buffered_call(self.client.pwrite, self.fd, data,
                                   offset)

    def append(self, data: bytes) -> int:
        return self._buffered_call(self.client.append, self.fd, data)

    def seek(self, offset: int, whence: int = 0):
        return self.client.seek(self.fd, offset, whence)

    def tell(self) -> int:
        return self.client.tell(self.fd)

    def truncate(self, length: int = 0) -> None:
        return self.client.truncate(self.fd, length)

    def size(self) -> int:
        return self.client.stat(self.path)["size"]

    # ---------------------------------------------------------- vectored I/O
    def readv(self, ranges: Sequence[Tuple[int, int]]) -> List[bytes]:
        return self.client.readv(self.fd, ranges)

    def preadv(self, sizes: Sequence[int], offset: int) -> List[bytes]:
        return self.client.preadv(self.fd, sizes, offset)

    def writev(self, chunks: Sequence[bytes]) -> int:
        return self._buffered_call(self.client.writev, self.fd, chunks)

    def pwritev(self, chunks: Sequence[bytes], offset: int) -> int:
        return self._buffered_call(self.client.pwritev, self.fd, chunks,
                                   offset)

    # ----------------------------------------------------------- async I/O
    # Futures flavor (``IoFuture``): the op runs on the cluster's unified
    # I/O runtime, so the caller overlaps its next op's planning with this
    # op's data rounds.  See ``posix_ops`` for the submission semantics.
    def readv_async(self, ranges: Sequence[Tuple[int, int]]):
        return self.client.readv_async(self.fd, ranges)

    def preadv_async(self, sizes: Sequence[int], offset: int):
        return self.client.preadv_async(self.fd, sizes, offset)

    def writev_async(self, chunks: Sequence[bytes]):
        return self._buffered_call(self.client.writev_async, self.fd,
                                   chunks)

    def pwritev_async(self, chunks: Sequence[bytes], offset: int):
        return self._buffered_call(self.client.pwritev_async, self.fd,
                                   chunks, offset)

    # --------------------------------------------------------------- slicing
    def yank(self, size: int, want_data: bool = False):
        return self.client.yank(self.fd, size, want_data)

    def yankv(self, ranges: Sequence[Tuple[int, int]]
              ) -> List[Tuple[Extent, ...]]:
        return self.client.yankv(self.fd, ranges)

    def paste(self, extents: Sequence[Extent]) -> int:
        return self.client.paste(self.fd, extents)

    def pastev(self, batches: Sequence[Sequence[Extent]]) -> int:
        return self.client.pastev(self.fd, batches)

    def punch(self, amount: int) -> int:
        return self.client.punch(self.fd, amount)

    def append_slices(self, extents: Sequence[Extent]) -> int:
        return self.client.append_slices(self.fd, extents)
