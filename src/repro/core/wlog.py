"""Kafka-style multi-producer durable log over ONE WTF file (§2.5 applied).

The workload the unserialized append path unlocks: many producers append
records to a single log file concurrently — each append is the paper's
commutative bounded *relative append*, so producers never conflict — while
consumers tail the committed prefix through the metadata plane's bounded-WAL
``subscribe`` stream, with no polling of file length and no busy reads.

Layout: the log file is a sequence of length-prefixed frames::

    [4-byte LE payload length][payload] [4-byte LE payload length][payload] …

One ``produce`` batch is flushed as ONE append (one transaction), so a frame
— and a whole batch of frames — becomes visible atomically: committed EOF
always lands on a frame boundary, and a reader of the committed prefix can
never observe a torn record.

Delivery pipeline (per consumer)::

    producer commit → WarpKV/ShardedKV WAL → subscribe fan-in (per-shard
    seq) → watermark advance (listener) → pread of [consumed, watermark) →
    frame reassembly → poll() returns payloads

The subscribe listener runs under the committing shard's locks, so it does
the absolute minimum: fold region events for the log's inode into a
monotone *committed-bytes watermark* (``region_index * region_size +
region.end``) and record the per-shard sequence high-water mark.  All real
work — the transactional ``pread`` and frame parsing — happens on the
consumer's own thread in ``poll``.  Because the per-shard sequence numbers
are gap-free, ``shard_seqs`` is a complete account of how much of each
shard's stream the consumer has folded in.

Replay contract: **at-least-once**.  ``LogConsumer.position`` is the
frame-aligned absolute offset just past the last fully-delivered record; a
consumer restarted with ``consumer(from_offset=saved_position)`` re-reads
nothing, while a restart from an older checkpoint re-delivers the suffix
(duplicates possible, loss impossible — the bytes are durable and the
watermark is rebuilt from the WAL snapshot replay, so no delivery depends
on the lost consumer's state).

Producers and consumers each own a private ``WtfClient`` and are
thread-confined (one producer/consumer per thread, any number of threads).

Determinism guarantee the benchmarks assert: consumers of the same log
deliver byte-identical streams (same payloads, same order — file order),
regardless of shard count or lease configuration; across *runs* the
interleaving of producers differs, so cross-run comparison uses the
order-independent ``content_digest`` plus per-producer FIFO, which together
pin exactly what the log promises.
"""
from __future__ import annotations

import hashlib
import struct
import threading
import time
from typing import Iterable, List, Optional

from .client_runtime import normalize_path
from .errors import WtfError
from .testing import witness_lock

_LEN = struct.Struct("<I")
FRAME_HEADER = _LEN.size


def frame(payload: bytes) -> bytes:
    """One length-prefixed log frame."""
    return _LEN.pack(len(payload)) + payload


def content_digest(payloads: Iterable[bytes]) -> str:
    """Order-independent digest of a record multiset.

    Concurrent producers interleave differently run to run, so two runs of
    the same workload agree on the record *multiset*, not the file order.
    Summing per-record hashes is commutative and multiset-exact (a dropped,
    duplicated, or corrupted record changes the sum), which is precisely
    the cross-run/cross-config delivery check.
    """
    acc = 0
    for p in payloads:
        acc = (acc + int.from_bytes(
            hashlib.blake2b(p, digest_size=16).digest(), "little")) % (1 << 128)
    return f"{acc:032x}"


class WtfLog:
    """Handle for one durable log file; mints producers and consumers."""

    def __init__(self, cluster, path: str, create: bool = True):
        self.cluster = cluster
        self.path = path
        boot = cluster.client()
        if create and cluster.kv.get("paths", normalize_path(path)) is None:
            fd = boot.open(path, "w")
            boot.close(fd)
        ino_id = cluster.kv.get("paths", normalize_path(path))
        if ino_id is None:
            raise WtfError(f"no such log file: {path}")
        ino = cluster.kv.get("inodes", ino_id)
        self.inode_id = ino_id
        self.region_size = ino.region_size

    def producer(self, batch_records: int = 1,
                 write_behind: bool = False) -> "LogProducer":
        return LogProducer(self, batch_records=batch_records,
                           write_behind=write_behind)

    def consumer(self, from_offset: int = 0) -> "LogConsumer":
        return LogConsumer(self, from_offset=from_offset)


class LogProducer:
    """One appending producer (thread-confined).

    ``produce`` frames the payload into a local batch; every
    ``batch_records`` records the batch is flushed as ONE append — one
    transaction, one commit — so batching divides the per-record commit
    cost.  ``write_behind=True`` routes the append through a buffered
    handle: the payload store defers into the client's write-behind buffer
    and lands via the batched store scheduler at the commit flush.
    """

    def __init__(self, log: WtfLog, batch_records: int = 1,
                 write_behind: bool = False):
        if batch_records < 1:
            raise ValueError(
                f"batch_records must be >= 1, got {batch_records}")
        self.log = log
        self.batch_records = batch_records
        self._client = log.cluster.client()
        self._handle = self._client.open_file(log.path, "a",
                                              buffered=write_behind)
        self._batch: List[bytes] = []
        self.produced_records = 0
        self.produced_bytes = 0
        self.flushes = 0

    def produce(self, payload: bytes) -> None:
        self._batch.append(frame(payload))
        self.produced_records += 1
        self.produced_bytes += len(payload)
        if len(self._batch) >= self.batch_records:
            self.flush()

    def flush(self) -> None:
        if not self._batch:
            return
        blob = b"".join(self._batch)
        self._batch.clear()
        n = self._handle.write(blob)
        if n != len(blob):
            raise WtfError(f"short log append: {n} != {len(blob)}")
        self.flushes += 1

    def close(self) -> None:
        self.flush()
        self._handle.close()


class LogConsumer:
    """One tailing consumer (thread-confined).

    Wakes on committed appends via the WAL subscribe stream, reads the
    newly-committed byte range transactionally, and returns whole records.
    ``digest`` is a running hash over delivered payloads in delivery
    order — byte-identical across every consumer of the same log.
    """

    def __init__(self, log: WtfLog, from_offset: int = 0):
        if from_offset < 0:
            raise ValueError(f"from_offset must be >= 0, got {from_offset}")
        self.log = log
        self._client = log.cluster.client()
        self._fd = self._client.open(log.path, "r")
        self._cond = threading.Condition(
            witness_lock(threading.Lock(), "wlog.consumer"))
        self._committed = 0           # monotone committed-bytes watermark
        self._read_pos = from_offset  # bytes handed to the reassembler
        self._closed = False
        self._buf = bytearray()
        self._parse_off = 0
        self.position = from_offset   # frame-aligned at-least-once cursor
        self.records = 0
        self.shard_seqs: dict[int, int] = {}
        self._digest = hashlib.blake2b(digest_size=16)
        # Subscribe LAST: replay (under the WAL lock, atomic with listener
        # registration) folds every already-committed region of this inode
        # into the watermark, so a late consumer starts complete.
        self._cancel = log.cluster.kv.subscribe(self._on_wal, with_meta=True)

    # -- WAL listener: runs under the committing shard's locks; minimal ----
    def _on_wal(self, space, key, value, version, shard, seq) -> None:
        with self._cond:
            self.shard_seqs[shard] = seq
            if (space == "regions" and isinstance(key, tuple)
                    and key[0] == self.log.inode_id and value is not None):
                end = key[1] * self.log.region_size + value.end
                if end > self._committed:
                    self._committed = end
                    self._cond.notify_all()

    # -- pull side ---------------------------------------------------------
    def poll(self, timeout: Optional[float] = 1.0,
             max_bytes: Optional[int] = None) -> List[bytes]:
        """Return the next batch of complete records, blocking up to
        ``timeout`` seconds for new committed bytes (``[]`` on timeout or
        after ``close``)."""
        with self._cond:
            if timeout is not None:
                deadline = time.monotonic() + timeout
            while self._committed <= self._read_pos and not self._closed:
                if timeout is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not self._cond.wait(remaining):
                        return []
            if self._closed:
                return []
            hi = self._committed
        if max_bytes is not None:
            hi = min(hi, self._read_pos + max_bytes)
        if hi <= self._read_pos:
            return []
        data = self._client.pread(self._fd, hi - self._read_pos,
                                  self._read_pos)
        # wtf-lint: ignore[WTF003] -- poll() is consumer-thread-confined by contract; _cond only publishes the commit watermark
        self._buf += data
        self._read_pos += len(data)  # wtf-lint: ignore[WTF003] -- consumer-thread-confined (see above)
        out: List[bytes] = []
        while True:
            avail = len(self._buf) - self._parse_off
            if avail < FRAME_HEADER:
                break
            (ln,) = _LEN.unpack_from(self._buf, self._parse_off)
            if avail < FRAME_HEADER + ln:
                break                 # partial frame: wait for more bytes
            start = self._parse_off + FRAME_HEADER
            payload = bytes(self._buf[start:start + ln])
            self._parse_off = start + ln
            self._digest.update(payload)
            self.records += 1  # wtf-lint: ignore[WTF003] -- consumer-thread-confined (see poll above)
            out.append(payload)
        if self._parse_off:
            self.position += self._parse_off  # wtf-lint: ignore[WTF003] -- consumer-thread-confined (see poll above)
            del self._buf[:self._parse_off]
            self._parse_off = 0
        return out

    @property
    def committed(self) -> int:
        """Current committed-bytes watermark (absolute file offset)."""
        with self._cond:
            return self._committed

    def digest(self) -> str:
        """Hash over delivered payloads in delivery order."""
        return self._digest.hexdigest()

    def close(self) -> None:
        self._cancel()
        with self._cond:
            self._closed = True
            self._cond.notify_all()
