"""Storage servers (paper §2.2, §2.7, §2.8).

A storage server deals *exclusively* with slices and is oblivious to files,
offsets, or concurrent writers.  Its complete API is two calls:

    create_slice(data, locality_hint) -> SlicePointer
    retrieve_slice(ptr)               -> bytes

The server keeps a directory of sequentially-written backing files.  Multiple
backing files (a) avoid writer contention, (b) can spread across filesystems,
and (c) let locality hints group writes for the same metadata region into the
same backing file so that sequential file writes land sequentially on disk
(§2.7) — which is what makes compaction collapse them into single pointers.

GC (§2.8 tier 3): the server rewrites a backing file, seeking past garbage
extents, which yields a sparse file occupying space proportional to live
bytes.  Offsets are preserved, so outstanding slice pointers stay valid.
Files with the *most* garbage are collected first — they cost the least I/O
and reclaim the most space.

Readahead: each server can keep a bounded pool of speculative read buffers
(``_ReadaheadPool``).  A per-backing-file detector watches retrieval rounds;
once a file shows a sequential streak the server reads ahead of the stream
(window sized by the runtime's EWMA cost model via ``readahead_window``)
and later rounds are served from memory.  Safe because backing-file byte
ranges are immutable once written: appends only ever extend the file, GC
preserves live bytes at their offsets, and speculation is clamped to
``_BackingFile.stable_size()`` so a buffer can never capture a reservation
whose write is still in flight.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .errors import StorageError
from .iort import AtomicStatsMixin
from .placement import stable_hash
from .slicing import SlicePointer
from .testing import witness_lock


@dataclass(slots=True)
class StorageStats(AtomicStatsMixin):
    """I/O accounting — the primary hardware-independent metric (Table 2).

    ``slices_created`` counts store *rounds* accepted (one ``create_slice``
    or ``create_slices`` call each); ``slices_written`` counts the logical
    slices those rounds carried, so ``slices_written - slices_created`` is
    the number of round trips the write-path scheduler saved this server.

    The read side mirrors it since the scatter-gather RPC: ``read_rounds``
    counts retrieval *rounds* accepted (one ``retrieve_slice`` or
    ``retrieve_slices`` call each); ``slices_read`` counts the pointer
    retrievals those rounds served, so ``slices_read - read_rounds`` is
    the round trips the vectored read path saved this server.

    Rounds arrive concurrently from the runtime pool; mutation goes
    through ``add`` (atomic) — a bare ``+=`` would drop updates.
    """

    bytes_written: int = 0
    # Bytes actually read from the backing files (disk traffic): pool-hit
    # retrievals do NOT count here — their bytes were counted once, at
    # speculation time, under ``readahead_bytes`` as well.
    bytes_read: int = 0
    slices_created: int = 0
    slices_written: int = 0
    slices_read: int = 0
    read_rounds: int = 0
    # Pointer retrievals served from the readahead pool / bytes read
    # speculatively into it.
    readahead_hits: int = 0
    readahead_bytes: int = 0
    gc_bytes_reclaimed: int = 0
    gc_bytes_rewritten: int = 0
    # Seconds spent waiting to *reserve* an append offset.  The write
    # syscall itself happens outside the reservation lock, so this is
    # pure queueing delay — if concurrent appenders serialize anywhere
    # in the storage layer, it shows up here first.
    append_lock_wait_s: float = 0.0
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


def _merge_intervals(intervals: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Merge (offset, length) pairs into sorted disjoint (start, end)."""
    out: List[Tuple[int, int]] = []
    for off, ln in sorted(intervals):
        if out and off <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], off + ln))
        else:
            out.append((off, off + ln))
    return out


def _intersect_intervals(a: List[Tuple[int, int]],
                         b: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Intersection of two sorted disjoint (start, end) lists."""
    out: List[Tuple[int, int]] = []
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if lo < hi:
            out.append((lo, hi))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _subtract_intervals(a: List[Tuple[int, int]],
                        sub: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """``a`` minus ``sub``; both sorted disjoint (start, end) lists."""
    out: List[Tuple[int, int]] = []
    j = 0
    for s, e in a:
        cur = s
        while j < len(sub) and sub[j][1] <= cur:
            j += 1
        k = j
        while k < len(sub) and sub[k][0] < e:
            if sub[k][0] > cur:
                out.append((cur, sub[k][0]))
            cur = max(cur, sub[k][1])
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


class _BackingFile:
    """One sequentially-appended slice container.

    Appends *reserve* an offset range under the lock, then issue the
    positional write syscall OUTSIDE it, so concurrent appenders overlap
    their disk I/O instead of serializing on one file lock (§2.5's
    parallel-append guarantee has to survive the storage layer too).
    The reservation protocol:

    - ``_reserve`` bumps ``size`` and an in-flight counter under the lock
      and captures the file descriptor; the caller then ``os.pwrite``s
      into its private range — disjoint ranges never conflict.
    - GC's sparse rewrite and ``close`` must not swap the fd out from
      under an in-flight write: they set ``_blocked`` (new reservations
      park) and wait on the condition until ``_inflight`` drains.
    - Every reservation is also marked *pending handoff* until the client
      acknowledges the end of the creating transaction
      (``release_range``).  A slice is durable on disk before the commit
      publishes its pointer (§2.1), so between ``create_slice`` returning
      and the commit landing the bytes look like garbage to a metadata
      scan — and a commit can take longer than two whole GC scans.  The
      tier-3 rewrite therefore never collects a pending range, no matter
      how many scans called it garbage.
    """

    def __init__(self, path: str, stats: Optional[StorageStats] = None):
        self.path = path
        self.lock = witness_lock(threading.Lock(), "storage.backing")
        self._idle = threading.Condition(self.lock)
        self.size = 0
        self._inflight = 0
        self._blocked = False
        self._stats = stats
        self._fh = open(path, "wb+", buffering=0)
        # Sorted disjoint (start, end) ranges reserved but not yet
        # acknowledged as committed/abandoned by the creating client.
        self.pending: List[Tuple[int, int]] = []
        # Handoff ACKs race the GC's scan pipeline: a commit lands AFTER
        # the metadata walk built the live list but BEFORE the server's
        # pass runs, so the just-released range still looks like garbage
        # to that pass.  Releases therefore stay shielded until a walk
        # that STARTED after the release has confirmed them garbage:
        # (monotonic-timestamp, start, end), pruned once old enough.
        # Only recorded while GC is live on this server (``gc_active``).
        self._released: List[Tuple[float, int, int]] = []
        self.gc_active = False

    def _reserve(self, length: int) -> Tuple[int, int]:
        """Claim ``[size, size+length)``; returns (offset, fileno)."""
        t0 = time.perf_counter()
        with self.lock:
            while self._blocked:
                self._idle.wait()
            wait = time.perf_counter() - t0
            off = self.size
            self.size += length
            self._inflight += 1
            self.pending.append((off, off + length))
            fd = self._fh.fileno()
        if self._stats is not None and wait > 1e-7:
            self._stats.add(append_lock_wait_s=wait)
        return off, fd

    def release_range(self, offset: int, length: int) -> None:
        """Handoff over: the creating transaction committed (the range is
        referenced) or finally aborted (it is ordinary garbage) — either
        way scans whose walk starts after this instant see the truth.
        Idempotent."""
        with self.lock:
            self.pending = _subtract_intervals(
                self.pending, [(offset, offset + length)])
            if self.gc_active:
                self._released.append(
                    (time.monotonic(), offset, offset + length))

    def gc_shield(self, cutoff: float) -> List[Tuple[int, int]]:
        """Ranges the GC rewrite must preserve regardless of the two-scan
        verdict: everything still pending, plus every range released at or
        after ``cutoff`` (the start of the walk behind the PREVIOUS scan —
        older releases were either live in that walk or garbage it could
        trust).  Returns sorted disjoint intervals; prunes the log."""
        with self.lock:
            self._released = [r for r in self._released if r[0] >= cutoff]
            ivs = list(self.pending) + [(s, e)
                                        for _, s, e in self._released]
        ivs.sort()
        out: List[Tuple[int, int]] = []
        for s, e in ivs:
            if out and s <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], e))
            else:
                out.append((s, e))
        return out

    def _release(self) -> None:
        with self.lock:
            self._inflight -= 1
            if self._inflight == 0:
                self._idle.notify_all()

    def _quiesce_locked(self) -> None:
        """With ``self.lock`` held: park new reservations and wait until
        every in-flight write has retired.  Caller must ``_unblock``."""
        self._blocked = True
        while self._inflight:
            self._idle.wait()

    def _unblock_locked(self) -> None:
        self._blocked = False
        self._idle.notify_all()

    @staticmethod
    def _pwrite_all(fd: int, data, off: int) -> None:
        view = memoryview(data)
        written = 0
        while written < len(view):
            written += os.pwrite(fd, view[written:], off + written)

    def append(self, data: bytes) -> int:
        off, fd = self._reserve(len(data))
        try:
            self._pwrite_all(fd, data, off)
        finally:
            self._release()
        return off

    def append_many(self, parts: Sequence[bytes]) -> int:
        """Append ``parts`` back-to-back in ONE reservation; returns the
        offset of the first part.  Parts are contiguous on disk, so the
        per-part pointers carved from the return value are adjacent —
        exactly what ``Extent.can_merge`` collapses at the metadata layer."""
        blob = b"".join(parts)
        off, fd = self._reserve(len(blob))
        try:
            self._pwrite_all(fd, blob, off)
        finally:
            self._release()
        return off

    def stable_size(self) -> int:
        """Prefix of the file guaranteed torn-write free: every byte below
        the first still-pending reservation is fully on disk (reservations
        are pending from ``_reserve`` until the client's handoff release,
        which happens after the write retires).  Readahead clamps here so
        a speculative buffer can never capture bytes a concurrent appender
        is still writing."""
        with self.lock:
            return self.pending[0][0] if self.pending else self.size

    def read(self, offset: int, length: int) -> bytes:
        # Positional read: no shared file-offset state between readers.
        return os.pread(self._fh.fileno(), length, offset)

    def read_into(self, buf, offset: int) -> int:
        """Positional read straight into ``buf`` (a writable memoryview) —
        the zero-copy half of the scatter-gather retrieval: parts land in
        the caller's backing buffer with no intermediate ``bytes``."""
        if hasattr(os, "preadv"):
            return os.preadv(self._fh.fileno(), [buf], offset)
        # platforms without preadv: one intermediate copy, same contract
        data = os.pread(self._fh.fileno(), len(buf), offset)
        buf[:len(data)] = data
        return len(data)

    def close(self) -> None:
        with self.lock:
            self._quiesce_locked()
            self._fh.close()


# Sequential detector: a round starting within this many bytes of the
# previous round's end (either side — coalesced batches can overlap their
# predecessor's tail) extends the streak.
_SEQ_SLOP = 256 << 10
# Rounds of in-order access before the server starts speculating.  Two
# keeps one-shot scans (and the counter assertions of single-round tests)
# readahead-free while real streams pay exactly one cold round.
_SEQ_THRESHOLD = 2
# Speculation window when no runtime cost model is wired in.
_DEFAULT_READAHEAD_WINDOW = 512 << 10
# Default per-server pool capacity (``Cluster(readahead=True)``): a few
# concurrent streams' worth of windows.
DEFAULT_READAHEAD_POOL_BYTES = 8 << 20


class _ReadaheadPool:
    """Bounded per-server pool of speculative read buffers.

    ``observe`` feeds one retrieval round into a per-backing-file
    sequential detector; once a file has streaked ``_SEQ_THRESHOLD``
    in-order rounds it returns a ``(start, stop)`` range worth reading
    ahead, and the server publishes the bytes with ``put``.  Later rounds
    covered by a pooled buffer are served from memory via ``lookup``.
    Buffers are keyed ``(backing_file, start)``, evicted LRU beyond
    ``capacity`` bytes; GC's sparse rewrite calls ``drop_file`` so punched
    garbage never lingers (pointer reads could never observe it anyway —
    punched ranges are unreferenced — but the memory is dead weight).

    Lock order: ``_lock`` is declared ``storage.readahead`` (rank 115), a
    leaf *under* ``storage.backing`` — the rewrite invalidates the pool
    while holding the backing-file lock.  Consequently nothing here may
    touch a backing file: the server performs the speculative read outside
    the pool lock and only then publishes the buffer.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._lock = witness_lock(threading.Lock(), "storage.readahead")
        # global LRU of (file, start) -> immutable bytes
        self._bufs: "OrderedDict[Tuple[str, int], bytes]" = OrderedDict()
        # per-file index of pooled buffer starts (lookup scans one file's
        # handful of windows, not the whole pool)
        self._starts: Dict[str, Set[int]] = {}
        self._nbytes = 0
        # per-file detector state: name -> (last_end, streak, ra_end)
        self._streams: Dict[str, Tuple[int, int, int]] = {}

    def lookup(self, name: str, offset: int, length: int):
        """Bytes for ``[offset, offset+length)`` if pooled, else None.
        Returns the pooled ``bytes`` itself on an exact match, a zero-copy
        ``memoryview`` slice otherwise."""
        if length <= 0:
            return None
        with self._lock:
            for start in self._starts.get(name, ()):
                buf = self._bufs.get((name, start))
                if (buf is not None and start <= offset
                        and offset + length <= start + len(buf)):
                    self._bufs.move_to_end((name, start))
                    if start == offset and length == len(buf):
                        return buf
                    lo = offset - start
                    return memoryview(buf)[lo:lo + length]
        return None

    def observe(self, name: str, offset: int, end: int,
                window: int) -> Optional[Tuple[int, int]]:
        """Feed one retrieval round ``[offset, end)`` into the detector;
        returns the ``(start, stop)`` range worth speculating, or None.
        ``ra_end`` (the pool's high-water mark for this stream) advances
        in ``put`` — only bytes actually pooled count, so a clamped or
        failed speculative read simply retries on a later round."""
        with self._lock:
            last_end, streak, ra_end = self._streams.get(name, (0, 0, 0))
            if last_end - _SEQ_SLOP <= offset <= last_end + _SEQ_SLOP:
                streak += 1
            else:
                streak, ra_end = 1, 0
            new_end = max(end, last_end) if streak > 1 else end
            want = None
            if streak >= _SEQ_THRESHOLD and window > 0:
                start = max(new_end, ra_end)
                stop = new_end + window
                if stop - start >= max(1, window // 2):
                    want = (start, stop)
            self._streams[name] = (new_end, streak, ra_end)
            return want

    def put(self, name: str, start: int, data: bytes) -> None:
        n = len(data)
        if n == 0 or n > self.capacity:
            return
        with self._lock:
            key = (name, start)
            old = self._bufs.pop(key, None)
            if old is not None:
                self._nbytes -= len(old)
            self._bufs[key] = data
            self._starts.setdefault(name, set()).add(start)
            self._nbytes += n
            st = self._streams.get(name)
            if st is not None:
                self._streams[name] = (st[0], st[1], max(st[2], start + n))
            while self._nbytes > self.capacity:
                (ename, estart), ebuf = self._bufs.popitem(last=False)
                self._nbytes -= len(ebuf)
                starts = self._starts.get(ename)
                if starts is not None:
                    starts.discard(estart)
                    if not starts:
                        del self._starts[ename]

    def drop_file(self, name: str) -> None:
        """Forget every buffer and the detector state for ``name`` (GC
        sparse rewrite; called with the backing-file lock held)."""
        with self._lock:
            for start in self._starts.pop(name, ()):
                buf = self._bufs.pop((name, start), None)
                if buf is not None:
                    self._nbytes -= len(buf)
            self._streams.pop(name, None)

    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes


class StorageServer:
    """One data node.  Thread-safe; writes are real file I/O."""

    def __init__(self, server_id: int, root_dir: str,
                 num_backing_files: int = 8,
                 fail_injected: bool = False,
                 service_time_s: float = 0.0,
                 readahead_pool_bytes: int = 0):
        self.server_id = server_id
        self.root_dir = root_dir
        self.num_backing_files = num_backing_files
        self.stats = StorageStats()
        self.alive = True
        self._fail_injected = fail_injected
        # Modeled per-round service time (network RTT + device latency)
        # for scaling benchmarks, mirroring the metadata plane's
        # ``kv_service_time``: in-process calls return in microseconds,
        # which hides round-trip *overlap* — the very thing parallel
        # appenders buy.  The sleep releases the GIL and is taken outside
        # every lock, so concurrent rounds genuinely overlap.
        self.service_time_s = service_time_s
        os.makedirs(root_dir, exist_ok=True)
        # Speculative-read pool (off when 0 — direct constructions and the
        # hdfs_like baseline stay readahead-free).  ``readahead_window``
        # is wired post-construction by Cluster to the runtime's EWMA
        # estimate (IoRuntime.readahead_bytes); until then a fixed window.
        self._ra_pool = (_ReadaheadPool(readahead_pool_bytes)
                         if readahead_pool_bytes > 0 else None)
        self.readahead_window: Optional[Callable[[], int]] = None
        self._files: Dict[str, _BackingFile] = {}
        self._files_lock = witness_lock(threading.Lock(), "storage.files")
        # round-robin cursor for unhinted placement; itertools.count is a
        # single atomic step, safe to bump from concurrent pool threads
        self._rr = itertools.count()
        # Two-scan GC safety rule (§2.8): a garbage byte range is only
        # collected once it has been unreferenced in two *consecutive*
        # filesystem scans (per-file garbage interval lists, intersected
        # pass over pass).
        self._gc_prev_garbage: Dict[str, List[Tuple[int, int]]] = {}
        # Start of the metadata walk behind the previous pass's live list;
        # -inf = no previous walk, shield every recorded release.
        self._gc_prev_walk_start = float("-inf")

    def _service_delay(self) -> None:
        if self.service_time_s > 0.0:
            time.sleep(self.service_time_s)

    # ------------------------------------------------------------------ API
    def create_slice(self, data: bytes,
                     locality_hint: Optional[int] = None) -> SlicePointer:
        """Write ``data`` to disk; return its self-contained pointer.

        The pointer is handed to the caller only *after* the bytes are
        durable in the backing file, which is what lets WTF serialize any
        observer of the pointer after the writing transaction (§2.1).
        """
        if not self.alive:
            raise StorageError(f"server {self.server_id} is down")
        self._service_delay()
        bf = self._pick_backing_file(locality_hint)
        off = bf.append(data)
        self.stats.add(bytes_written=len(data), slices_created=1,
                       slices_written=1)
        name = os.path.basename(bf.path)
        return SlicePointer(self.server_id, name, off, len(data))

    def create_slices(self, parts: Sequence[bytes],
                      locality_hint: Optional[int] = None
                      ) -> List[SlicePointer]:
        """Vectored store: write ``parts`` contiguously in ONE round.

        The write-path scheduler's server-side half (§2.7, §2.9): all parts
        land back-to-back in a single backing file under one lock, so one
        round trip durably stores the whole batch and the returned per-part
        pointers are disk-adjacent (the metadata layer can merge them back
        into a single covering pointer).  Pointers are returned only after
        every byte is durable — the §2.1 invariant holds batch-wide.
        """
        if not self.alive:
            raise StorageError(f"server {self.server_id} is down")
        if not parts:
            return []
        self._service_delay()
        bf = self._pick_backing_file(locality_hint)
        base = bf.append_many(parts)
        total = sum(len(p) for p in parts)
        self.stats.add(bytes_written=total, slices_created=1,
                       slices_written=len(parts))
        name = os.path.basename(bf.path)
        out: List[SlicePointer] = []
        off = base
        for p in parts:
            out.append(SlicePointer(self.server_id, name, off, len(p)))
            off += len(p)
        return out

    def release_slices(self, ptrs: Iterable[SlicePointer]) -> None:
        """Close the create→commit handoff window for ``ptrs`` (see
        ``_BackingFile``): called by the client once the transaction that
        created the slices has committed or finally aborted.  Unknown
        pointers and pointers for other servers are ignored; releasing a
        range twice is a no-op."""
        for p in ptrs:
            if p.server_id != self.server_id:
                continue
            with self._files_lock:
                bf = self._files.get(p.backing_file)
            if bf is not None:
                bf.release_range(p.offset, p.length)

    def retrieve_slice(self, ptr: SlicePointer) -> bytes:
        """Follow a pointer: open the named file, read, return (§2.2).

        Returns a bytes-like buffer: ``bytes`` off disk, possibly a
        zero-copy ``memoryview`` when served from the readahead pool.
        """
        if not self.alive:
            raise StorageError(f"server {self.server_id} is down")
        if ptr.server_id != self.server_id:
            raise StorageError(
                f"pointer for server {ptr.server_id} sent to {self.server_id}")
        self._service_delay()
        bf = self._get_backing_file(ptr.backing_file)
        data = None
        if self._ra_pool is not None:
            data = self._ra_pool.lookup(ptr.backing_file, ptr.offset,
                                        ptr.length)
        if data is not None:
            self.stats.add(slices_read=1, read_rounds=1, readahead_hits=1)
        else:
            data = bf.read(ptr.offset, ptr.length)
            if len(data) != ptr.length:
                raise StorageError(
                    f"short read: wanted {ptr.length} got {len(data)} "
                    f"from {ptr.backing_file}@{ptr.offset}")
            self.stats.add(bytes_read=len(data), slices_read=1,
                           read_rounds=1)
        if self._ra_pool is not None:
            self._maybe_readahead(bf, ptr.backing_file, ptr.offset,
                                  ptr.offset + ptr.length)
        return data

    def retrieve_slices(self, ptrs: Sequence[SlicePointer]
                        ) -> List[memoryview]:
        """Vectored retrieval: serve many pointers in ONE round (§2.2).

        The read-side mirror of ``create_slices`` — a fetch batch of
        *non-adjacent* extents on this server costs one round trip instead
        of one per run, and unlike a covering retrieval no gap bytes are
        read or shipped.  All parts land back-to-back in a single backing
        buffer and the returned ``memoryview``s alias it zero-copy; the
        caller slices them further without touching the bytes.

        The call is all-or-nothing: any dead server, wrong-server pointer
        or short read raises ``StorageError`` and the client degrades to
        per-batch/per-extent retrieval with full §2.9 replica failover.
        """
        if not self.alive:
            raise StorageError(f"server {self.server_id} is down")
        if not ptrs:
            return []
        self._service_delay()
        pool = self._ra_pool
        total = sum(p.length for p in ptrs)
        buf: Optional[memoryview] = None
        out: List[memoryview] = []
        spans: Dict[str, Tuple[int, int]] = {}
        hits = disk_bytes = 0
        off = 0
        for p in ptrs:
            if p.server_id != self.server_id:
                raise StorageError(
                    f"pointer for server {p.server_id} sent to "
                    f"{self.server_id}")
            bf = self._get_backing_file(p.backing_file)
            part = pool.lookup(p.backing_file, p.offset, p.length) \
                if pool is not None else None
            if part is not None:
                hits += 1
            else:
                if buf is None:
                    buf = memoryview(bytearray(total))
                part = buf[off:off + p.length]
                got = bf.read_into(part, p.offset) if p.length else 0
                if got != p.length:
                    raise StorageError(
                        f"short read: wanted {p.length} got {got} "
                        f"from {p.backing_file}@{p.offset}")
                disk_bytes += p.length
            out.append(part)
            off += p.length
            if pool is not None and p.length:
                lo, hi = spans.get(p.backing_file,
                                   (p.offset, p.offset + p.length))
                spans[p.backing_file] = (min(lo, p.offset),
                                         max(hi, p.offset + p.length))
        self.stats.add(bytes_read=disk_bytes, slices_read=len(ptrs),
                       read_rounds=1, readahead_hits=hits)
        # Feed the detector one span per backing file touched this round
        # (coalesced batches arrive as one round; the detector tracks the
        # stream, not individual pointers), then speculate if it streaks.
        for name, (lo, hi) in spans.items():
            self._maybe_readahead(self._get_backing_file(name), name,
                                  lo, hi)
        return out

    def _maybe_readahead(self, bf: _BackingFile, name: str,
                         lo: int, hi: int) -> None:
        """Feed ``[lo, hi)`` into the sequential detector and, on a
        streak, read ahead of the stream into the pool.  The speculative
        read happens outside every lock and is clamped to
        ``stable_size()`` so it can never observe a torn append."""
        pool = self._ra_pool
        if pool is None:
            return
        window = (self.readahead_window() if self.readahead_window
                  is not None else _DEFAULT_READAHEAD_WINDOW)
        # Never speculate less than one observed round: pool lookups
        # require full containment, so a stream of large covering reads
        # against a smaller window would pool buffers that can never
        # serve the next round — guaranteed misses.
        window = max(window, hi - lo)
        want = pool.observe(name, lo, hi, window)
        if want is None:
            return
        start, stop = want
        stop = min(stop, bf.stable_size())
        if stop <= start:
            return
        data = bf.read(start, stop - start)
        if data:
            self.stats.add(bytes_read=len(data),
                           readahead_bytes=len(data))
            pool.put(name, start, data)

    # ----------------------------------------------------------- placement
    def _pick_backing_file(self, hint: Optional[int]) -> _BackingFile:
        """Server-local hashing, salted differently from the cross-server
        ring (§2.7), so same-region writes share a backing file but regions
        that collide on a server spread across its files."""
        if hint is not None:
            idx = stable_hash(hint, salt="backing") % self.num_backing_files
        else:
            idx = next(self._rr) % self.num_backing_files
        name = f"backing_{idx:04d}.dat"
        return self._get_backing_file(name, create=True)

    def _get_backing_file(self, name: str, create: bool = False) -> _BackingFile:
        bf = self._files.get(name)
        if bf is None:
            with self._files_lock:
                bf = self._files.get(name)
                if bf is None:
                    path = os.path.join(self.root_dir, name)
                    if not create and not os.path.exists(path):
                        raise StorageError(f"no backing file {name}")
                    # wtf-lint: ignore[WTF002] -- creation is atomic under the directory lock; once per file, never on the append fast path
                    bf = _BackingFile(path, stats=self.stats)
                    if not create:
                        bf.size = os.path.getsize(path)
                    self._files[name] = bf
        return bf

    # ------------------------------------------------------------------- GC
    def disk_usage(self) -> int:
        """Apparent bytes across backing files (holes excluded by the OS;
        we track logical size here and real usage via ``real_usage``)."""
        return sum(bf.size for bf in self._files.values())

    def real_usage(self) -> int:
        """Blocks actually allocated (sparse holes don't count)."""
        total = 0
        for bf in self._files.values():
            st = os.stat(bf.path)
            total += st.st_blocks * 512
        return total

    def gc_pass(self, live: Iterable[SlicePointer],
                max_files: Optional[int] = None,
                walk_started_at: Optional[float] = None) -> dict:
        """One garbage-collection pass given the filesystem-wide live list.

        ``live`` is the in-use pointer list the metadata scan produced for
        this server (delivered via a reserved WTF directory in the real
        system — the driver in ``gc.py`` does exactly that).  Applies the
        two-consecutive-scans rule, then sparse-rewrites the files with the
        most garbage first.  ``walk_started_at`` (``time.monotonic``) is
        when the metadata walk behind ``live`` began — handoff releases
        newer than the *previous* pass's walk start stay shielded, since
        neither walk can have observed their commit.
        """
        now = time.monotonic()
        if walk_started_at is None:
            walk_started_at = now
        # Releases older than the previous walk's start were visible to
        # it: committed→live (not garbage) or abandoned→trustable garbage.
        cutoff = self._gc_prev_walk_start
        self._gc_prev_walk_start = walk_started_at
        with self._files_lock:
            for bf in self._files.values():
                bf.gc_active = True

        live_by_file: Dict[str, List[Tuple[int, int]]] = {}
        for p in live:
            if p.server_id != self.server_id:
                continue
            live_by_file.setdefault(p.backing_file, []).append(
                (p.offset, p.length))

        # Compute garbage intervals: bytes in each file not covered by live.
        garbage_now: Dict[str, List[Tuple[int, int]]] = {}
        garbage_per_file: Dict[str, int] = {}
        for name, bf in list(self._files.items()):
            merged = _merge_intervals(live_by_file.get(name, []))
            cursor, gaps = 0, []
            for off, end in merged:
                if off > cursor:
                    gaps.append((cursor, off))
                cursor = max(cursor, end)
            if bf.size > cursor:
                gaps.append((cursor, bf.size))
            garbage_now[name] = gaps
            garbage_per_file[name] = sum(e - s for s, e in gaps)

        # Two-scan rule: only byte ranges that were garbage last scan too
        # may be reclaimed — and never a range still pending its
        # create→commit handoff (a commit can outlast any number of
        # scans, so the scan-count rule alone cannot close that window).
        # The confirmed intervals — not the live list — drive the rewrite
        # below: every unconfirmed byte is preserved verbatim.
        confirmed: Dict[str, List[Tuple[int, int]]] = {}
        collectable: Dict[str, int] = {}
        for name, gaps in garbage_now.items():
            both = _intersect_intervals(
                gaps, self._gc_prev_garbage.get(name, []))
            bf = self._files.get(name)
            if bf is not None:
                both = _subtract_intervals(both, bf.gc_shield(cutoff))
            confirmed[name] = both
            collectable[name] = sum(e - s for s, e in both)
        self._gc_prev_garbage = garbage_now

        # Most-garbage-first ordering (§2.8): those files reclaim the most
        # space for the least rewrite I/O.
        by_garbage = sorted(garbage_per_file.items(),
                            key=lambda kv: kv[1], reverse=True)
        reclaimed = rewritten = files_compacted = 0
        for name, garbage in by_garbage:
            if garbage == 0 or collectable.get(name, 0) == 0:
                continue
            r, w = self._sparse_rewrite(name, confirmed.get(name, []))
            reclaimed += r
            rewritten += w
            files_compacted += 1
            if max_files is not None and files_compacted >= max_files:
                break
        self.stats.add(gc_bytes_reclaimed=reclaimed,
                       gc_bytes_rewritten=rewritten)
        return {"reclaimed": reclaimed, "rewritten": rewritten,
                "files": files_compacted}

    def _sparse_rewrite(self, name: str,
                        punch: List[Tuple[int, int]]) -> Tuple[int, int]:
        """Rewrite a backing file punching holes ONLY in ``punch`` — the
        (start, end) ranges confirmed garbage by two consecutive scans.
        Every other byte is copied verbatim: data appended after the scan
        built its live list (durable but not yet visible to the metadata
        walk) must survive the rewrite.  Offsets are preserved, so
        pointers stay valid."""
        bf = self._get_backing_file(name)
        # wtf-lint: ignore[WTF002] -- rewrite I/O under the file lock is the design: the file is quiesced (appends parked, writes drained)
        with bf.lock:
            # The rewrite swaps the file descriptor; an append writing
            # through the old fd would land in the replaced inode and be
            # lost.  Park new reservations and drain in-flight writes
            # before touching the fd (appends resume once we unblock).
            bf._quiesce_locked()
            try:
                size = bf.size
                keep: List[Tuple[int, int]] = []
                cursor = 0
                for s, e in punch:              # sorted disjoint (s, e)
                    s, e = max(0, min(s, size)), max(0, min(e, size))
                    if s > cursor:
                        keep.append((cursor, s))
                    cursor = max(cursor, e)
                if size > cursor:
                    keep.append((cursor, size))
                tmp = bf.path + ".gc"
                written = 0
                with open(tmp, "wb") as out:
                    for off, end in keep:
                        data = os.pread(bf._fh.fileno(), end - off, off)
                        out.seek(off)           # seek past garbage → hole
                        out.write(data)
                        written += end - off
                    out.truncate(max(size, 0))
                old_real = os.stat(bf.path).st_blocks * 512
                os.replace(tmp, bf.path)
                bf._fh.close()
                bf._fh = open(bf.path, "rb+", buffering=0)
                new_real = os.stat(bf.path).st_blocks * 512
                reclaimed = max(0, old_real - new_real)
                if self._ra_pool is not None:
                    # storage.backing (held) -> storage.readahead: the
                    # declared descending edge; drops any buffer holding
                    # pre-punch bytes of this file.
                    self._ra_pool.drop_file(name)
                return reclaimed, written
            finally:
                bf._unblock_locked()

    # ------------------------------------------------------------- failures
    def crash(self) -> None:
        self.alive = False

    def recover(self) -> None:
        self.alive = True

    def close(self) -> None:
        for bf in self._files.values():
            bf.close()
