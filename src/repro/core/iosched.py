"""Batched slice-fetch scheduler — the client's data-plane I/O engine.

The scalar client dereferenced slice pointers one at a time: every extent in
a read plan became its own storage-server round.  The paper's whole pitch is
that slice pointers make *metadata* cheap; this module makes *dereferencing*
them cheap too, which is where the batching wins of the sort benchmark (§4)
actually come from:

  1. **Coalescing.**  Planned fetches are sorted by (server, backing file,
     disk offset) and runs that are adjacent — or separated by less than
     ``max_gap`` bytes — collapse into a single covering retrieval.  Thanks
     to locality-aware placement (§2.7), sequential file writes land
     sequentially in one backing file, so a vectored read over N ranges
     typically needs one round per (server, backing-file) run rather than N.
  2. **Fan-out.**  Batches destined for different servers are issued
     concurrently from a thread pool, so a read striped over the cluster
     completes in one server's latency, not the sum.

Failure handling: coalescing picks one live replica per extent up front; if
a covering retrieval fails mid-flight, the scheduler degrades to per-extent
fetches with the full §2.9 replica-failover path, so batching never reduces
availability.

Accounting: each covering retrieval counts once in ``StorageStats``
(``slices_read``/``bytes_read``), and the caller's ``ClientStats`` records
``fetch_batches`` (rounds issued) and ``slices_coalesced`` (pointer
dereferences saved) — the measurable effectiveness of the scheduler.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Sequence, Tuple

from .errors import StorageError
from .slicing import Extent, SlicePointer

# Coalesce fetches whose on-disk gap is at most this many bytes.  Gap bytes
# are fetched and discarded: a small bounded over-read is far cheaper than an
# extra round trip, exactly like a disk elevator's seek threshold.  Kept
# deliberately below typical record sizes so sparse key-only access patterns
# (e.g. the sort benchmark reading 10-byte keys out of 64 KiB records) are
# NOT coalesced into whole-file reads — the threshold trades one round trip
# against at most 32 KiB of discarded bytes.
DEFAULT_MAX_GAP = 32 << 10


class _FetchBatch:
    """One coalesced storage-server round: a covering range in one backing
    file plus the parts (plan slot, chosen replica pointer, source extent)
    it satisfies."""

    __slots__ = ("server_id", "backing_file", "start", "end", "parts")

    def __init__(self, server_id: int, backing_file: str, start: int,
                 end: int, parts: List[tuple]):
        self.server_id = server_id
        self.backing_file = backing_file
        self.start = start
        self.end = end
        self.parts = parts               # [(plan_idx, chunk_idx, extent, ptr)]

    @property
    def covering(self) -> SlicePointer:
        return SlicePointer(self.server_id, self.backing_file, self.start,
                            self.end - self.start)


def plan_batches(tagged: Sequence[tuple],
                 max_gap: int = DEFAULT_MAX_GAP) -> List[_FetchBatch]:
    """Group tagged fetches ``(plan_idx, chunk_idx, extent, ptr)`` into
    coalesced per-(server, backing-file) batches."""
    ordered = sorted(
        tagged, key=lambda t: (t[3].server_id, t[3].backing_file,
                               t[3].offset))
    batches: List[_FetchBatch] = []
    for item in ordered:
        ptr = item[3]
        cur = batches[-1] if batches else None
        if (cur is not None
                and cur.server_id == ptr.server_id
                and cur.backing_file == ptr.backing_file
                and ptr.offset <= cur.end + max_gap):
            cur.end = max(cur.end, ptr.offset + ptr.length)
            cur.parts.append(item)
        else:
            batches.append(_FetchBatch(ptr.server_id, ptr.backing_file,
                                       ptr.offset, ptr.offset + ptr.length,
                                       [item]))
    return batches


class SliceScheduler:
    """Executes batched slice fetches against a ``Cluster``.

    One scheduler per cluster, shared by all clients (it is stateless apart
    from its lazily created thread pool).  ``fetch_many`` is the entry
    point; ``WtfClient._fetch``/``_fetch_many`` route every data-plane read
    through it, so scalar reads and vectored reads share one code path and
    one accounting scheme.
    """

    def __init__(self, cluster, max_workers: int = 8,
                 max_gap: int = DEFAULT_MAX_GAP):
        self.cluster = cluster
        self.max_gap = max_gap
        self._max_workers = max(1, max_workers)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()

    # --------------------------------------------------------------- pool
    def _pool_get(self) -> ThreadPoolExecutor:
        if self._pool is None:
            with self._pool_lock:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=self._max_workers,
                        thread_name_prefix="wtf-iosched")
        return self._pool

    def pool(self) -> ThreadPoolExecutor:
        """The cluster's shared data-plane pool (lazily created).  The
        write scheduler (``wsched``) fans its store rounds out on this same
        pool, so one executor serves both directions of the data plane."""
        return self._pool_get()

    def close(self) -> None:
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    # -------------------------------------------------------------- fetch
    def fetch_many(self, plans: Sequence[Sequence[Extent]],
                   stats=None) -> List[bytes]:
        """Fetch one ``bytes`` result per extent plan.

        Each plan is an ordered extent list (as produced by
        ``_plan_range``); zero extents are materialized locally and pointer
        extents are coalesced and fetched across all plans at once.
        """
        chunks: List[List[Optional[bytes]]] = [
            [None] * len(plan) for plan in plans]
        tagged: List[tuple] = []
        for pi, plan in enumerate(plans):
            for ci, e in enumerate(plan):
                if e.is_zero:
                    chunks[pi][ci] = b"\x00" * e.length
                else:
                    tagged.append((pi, ci, e, self._pick_replica(e.ptrs)))

        batches = plan_batches(tagged, self.max_gap)
        if len(batches) > 1 and self._max_workers > 1:
            results = list(self._pool_get().map(self._run_batch, batches))
        else:
            results = [self._run_batch(b) for b in batches]

        rounds = physical = 0
        for parts, n_rounds, n_bytes in results:
            rounds += n_rounds
            physical += n_bytes
            for pi, ci, data in parts:
                chunks[pi][ci] = data
        if stats is not None:
            stats.fetch_batches += rounds
            stats.slices_coalesced += len(tagged) - rounds
            stats.data_bytes_read += physical
        return [b"".join(c) for c in chunks]

    def fetch(self, extents: Sequence[Extent], stats=None) -> bytes:
        return self.fetch_many([extents], stats=stats)[0]

    # ----------------------------------------------------------- internals
    def _pick_replica(self, ptrs: Tuple[SlicePointer, ...]) -> SlicePointer:
        """Prefer a replica on a live server so coalescing groups fetches
        onto servers that can actually answer them."""
        for p in ptrs:
            srv = self.cluster.servers.get(p.server_id)
            if srv is not None and srv.alive:
                return p
        return ptrs[0]

    def _run_batch(self, batch: _FetchBatch) -> tuple:
        """Issue one batch; returns (parts, rounds, physical_bytes)."""
        if len(batch.parts) == 1:
            pi, ci, e, ptr = batch.parts[0]
            return ([(pi, ci, self.cluster.fetch_slice(e.ptrs))], 1, e.length)
        try:
            blob = self.cluster.fetch_slice((batch.covering,))
        except StorageError:
            # Degrade to per-extent fetches with full replica failover
            # (§2.9): the chosen replica's server died between planning and
            # execution, or the covering range spans a GC'd hole.
            out = [(pi, ci, self.cluster.fetch_slice(e.ptrs))
                   for pi, ci, e, _ in batch.parts]
            return (out, len(batch.parts),
                    sum(e.length for _, _, e, _ in batch.parts))
        out = []
        for pi, ci, e, ptr in batch.parts:
            lo = ptr.offset - batch.start
            out.append((pi, ci, blob[lo:lo + ptr.length]))
        return (out, 1, len(blob))
