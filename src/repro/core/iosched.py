"""Batched slice-fetch strategy — the read side of the unified I/O runtime.

The scalar client dereferenced slice pointers one at a time: every extent in
a read plan became its own storage-server round.  The paper's whole pitch is
that slice pointers make *metadata* cheap; this module makes *dereferencing*
them cheap too, which is where the batching wins of the sort benchmark (§4)
actually come from:

  1. **Coalescing.**  Planned fetches are sorted by (server, backing file,
     disk offset) and runs that are adjacent — or separated by less than
     the gap threshold — collapse into a single covering retrieval.  Thanks
     to locality-aware placement (§2.7), sequential file writes land
     sequentially in one backing file, so a vectored read over N ranges
     typically needs one round per (server, backing-file) run rather than N.
     The threshold is sized by the runtime's adaptive cost model (the bytes
     one round-trip is worth) unless ``Cluster(fetch_gap_bytes=…)`` pins it.
  2. **Scatter-gather.**  Coalesced batches that share a (server, backing
     file) but sit beyond the gap threshold travel together as ONE
     ``StorageServer.retrieve_slices`` round (zero-copy ``memoryview``s,
     no gap bytes read) — the read-side mirror of the write scheduler's
     one-``create_slices``-per-group rule.  ``Cluster(scatter_gather=
     False)`` reverts to one round per coalesced run.
  3. **Fan-out.**  Batches destined for different servers are issued as
     ``IoTask``s on the shared ``IoRuntime`` pool, so a read striped over
     the cluster completes in one server's latency, not the sum.

This module only *plans* (sort + coalesce); execution, timing and the
failover walk live in ``iort``/``Cluster.fetch_slice``.  If a covering
retrieval fails mid-flight, the strategy degrades to per-extent fetches
through the full §2.9 replica-failover path, so batching never reduces
availability.

Accounting: each covering retrieval counts once in ``StorageStats``
(``slices_read``/``bytes_read``), and the caller's ``ClientStats`` records
``fetch_batches`` (rounds issued) and ``slices_coalesced`` (pointer
dereferences saved) — the measurable effectiveness of the scheduler.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

from .errors import DegradedRead, ReplicaExhausted, StorageError
from .iort import IoTask, run_with_failover
from .slicing import Extent, SlicePointer

# Historical fixed gap threshold, kept as the adaptive model's seed and as
# the value benchmarks pin for comparable paper-reproduction accounting.
# Gap bytes are fetched and discarded: a small bounded over-read is far
# cheaper than an extra round trip, exactly like a disk elevator's seek
# threshold.
DEFAULT_MAX_GAP = 32 << 10


class _FetchBatch:
    """One coalesced storage-server round: a covering range in one backing
    file plus the parts (plan slot, chosen replica pointer, source extent)
    it satisfies."""

    __slots__ = ("server_id", "backing_file", "start", "end", "parts")

    def __init__(self, server_id: int, backing_file: str, start: int,
                 end: int, parts: List[tuple]):
        self.server_id = server_id
        self.backing_file = backing_file
        self.start = start
        self.end = end
        self.parts = parts               # [(plan_idx, chunk_idx, extent, ptr)]

    @property
    def covering(self) -> SlicePointer:
        return SlicePointer(self.server_id, self.backing_file, self.start,
                            self.end - self.start)


class _SGGroup:
    """One scatter-gather round: several coalesced batches that share a
    (server, backing file) but sit too far apart to gap-coalesce.  The
    whole group is served by ONE ``retrieve_slices`` round carrying each
    batch's covering pointer — no gap bytes between batches are read."""

    __slots__ = ("server_id", "backing_file", "batches")

    def __init__(self, server_id: int, backing_file: str,
                 batches: List[_FetchBatch]):
        self.server_id = server_id
        self.backing_file = backing_file
        self.batches = batches

    @property
    def nbytes(self) -> int:
        return sum(b.end - b.start for b in self.batches)


def plan_batches(tagged: Sequence[tuple],
                 max_gap: int = DEFAULT_MAX_GAP) -> List[_FetchBatch]:
    """Group tagged fetches ``(plan_idx, chunk_idx, extent, ptr)`` into
    coalesced per-(server, backing-file) batches."""
    ordered = sorted(
        tagged, key=lambda t: (t[3].server_id, t[3].backing_file,
                               t[3].offset))
    batches: List[_FetchBatch] = []
    for item in ordered:
        ptr = item[3]
        cur = batches[-1] if batches else None
        if (cur is not None
                and cur.server_id == ptr.server_id
                and cur.backing_file == ptr.backing_file
                and ptr.offset <= cur.end + max_gap):
            cur.end = max(cur.end, ptr.offset + ptr.length)
            cur.parts.append(item)
        else:
            batches.append(_FetchBatch(ptr.server_id, ptr.backing_file,
                                       ptr.offset, ptr.offset + ptr.length,
                                       [item]))
    return batches


class SliceScheduler:
    """Read-side strategy layer over the cluster's ``IoRuntime``.

    One scheduler per cluster, shared by all clients (it is stateless).
    ``fetch_many`` is the entry point; ``WtfClient._fetch``/``_fetch_many``
    route every data-plane read through it, so scalar reads and vectored
    reads share one code path and one accounting scheme.  It owns no pool
    and no failover loop: batches execute as ``IoTask``s on the runtime,
    and degraded fetches walk replicas via ``Cluster.fetch_slice`` (the
    unified ``iort.run_with_failover`` path).
    """

    def __init__(self, cluster, runtime,
                 max_gap: Optional[int] = None):
        self.cluster = cluster
        self.runtime = runtime
        self._max_gap = max_gap          # None → adaptive via the runtime

    @property
    def max_gap(self) -> int:
        """Current coalescing threshold (pinned or adaptive)."""
        if self._max_gap is not None:
            return self._max_gap
        return self.runtime.gap_bytes()

    def close(self) -> None:
        """Back-compat: drain the shared runtime."""
        self.runtime.close()

    # -------------------------------------------------------------- fetch
    def fetch_many(self, plans: Sequence[Sequence[Extent]],
                   stats=None, block_cache=None,
                   inode_id=None) -> List[bytes]:
        """Fetch one buffer result per extent plan (``bytes`` or a
        zero-copy ``memoryview`` — callers that need ``bytes`` semantics,
        e.g. the scalar read path, materialize at their boundary).

        Each plan is an ordered extent list (as produced by
        ``_plan_range``); zero extents are materialized locally and pointer
        extents are coalesced and fetched across all plans at once.  With
        ``block_cache`` (and the owning ``inode_id``) supplied, cached
        extents are filled before batching — a fully cached read issues
        zero storage rounds — and fetched extents are inserted after.
        """
        from .blockcache import block_key

        use_cache = block_cache is not None and inode_id is not None
        chunks: List[List[Optional[bytes]]] = [
            [None] * len(plan) for plan in plans]
        tagged: List[tuple] = []
        miss_keys = {} if use_cache else None
        hits = 0
        for pi, plan in enumerate(plans):
            for ci, e in enumerate(plan):
                if e.is_zero:
                    chunks[pi][ci] = b"\x00" * e.length
                    continue
                if use_cache:
                    key = block_key(e.ptrs[0])
                    cached = block_cache.get(key)
                    if cached is not None:
                        chunks[pi][ci] = cached
                        hits += 1
                        continue
                    miss_keys[(pi, ci)] = key
                tagged.append((pi, ci, e,
                               self._pick_replica(e.ptrs, inode_id)))

        units = self._plan_units(plan_batches(tagged, self.max_gap))
        tasks = [IoTask("fetch", u.server_id, u.nbytes
                        if isinstance(u, _SGGroup) else u.end - u.start, u)
                 for u in units]
        results = self.runtime.run_tasks(tasks, self._run_unit)

        rounds = physical = 0
        for parts, n_rounds, n_bytes in results:
            rounds += n_rounds
            physical += n_bytes
            for pi, ci, data in parts:
                chunks[pi][ci] = data
                if use_cache:
                    block_cache.put(miss_keys[(pi, ci)], data, inode_id)
        if stats is not None:
            stats.add(fetch_batches=rounds,
                      slices_coalesced=len(tagged) - rounds,
                      data_bytes_read=physical)
            if use_cache:
                stats.add(block_cache_hits=hits,
                          block_cache_misses=len(tagged))
        # Single-extent plans (the common sequential case) pass the
        # buffer through unjoined — no per-plan copy.
        return [c[0] if len(c) == 1 else b"".join(c) for c in chunks]

    def fetch(self, extents: Sequence[Extent], stats=None,
              block_cache=None, inode_id=None) -> bytes:
        return self.fetch_many([extents], stats=stats,
                               block_cache=block_cache,
                               inode_id=inode_id)[0]

    # ----------------------------------------------------------- internals
    def _plan_units(self, batches: List[_FetchBatch]) -> List[Any]:
        """Fold coalesced batches into scatter-gather rounds.

        Gap coalescing (``plan_batches``) merges runs closer than the gap
        threshold; batches beyond it on the SAME (server, backing file)
        used to each cost their own round.  With ``Cluster(scatter_gather)``
        on (the default), those batches travel together as one
        ``retrieve_slices`` round instead — the read-side mirror of the
        write scheduler's one-``create_slices``-per-(group, replica) rule.
        ``plan_batches`` sorts by (server, file, offset), so same-location
        batches are adjacent here.
        """
        if not getattr(self.cluster, "scatter_gather", True) \
                or len(batches) < 2:
            return list(batches)
        units: List[Any] = []
        run: List[_FetchBatch] = []

        def flush() -> None:
            if len(run) == 1:
                units.append(run[0])
            elif run:
                units.append(_SGGroup(run[0].server_id,
                                      run[0].backing_file, list(run)))
            run.clear()

        for b in batches:
            if run and (run[0].server_id, run[0].backing_file) != \
                    (b.server_id, b.backing_file):
                flush()
            run.append(b)
        flush()
        return units

    def _run_unit(self, task: IoTask) -> tuple:
        unit = task.payload
        if isinstance(unit, _SGGroup):
            return self._run_sg(unit)
        return self._run_batch_payload(unit)

    def _run_sg(self, group: _SGGroup) -> tuple:
        """Issue one scatter-gather round; degrade to per-batch (and then
        per-extent, §2.9) retrieval when the server refuses it."""
        ptrs = [b.covering for b in group.batches]
        try:
            blobs = run_with_failover(
                self.cluster, [(group.server_id, ptrs)],
                lambda srv, ps: srv.retrieve_slices(ps))
        except StorageError:
            # The chosen server died (or cannot serve the round) between
            # planning and execution: every batch walks the normal
            # covering/per-extent failover path instead.
            parts: List[tuple] = []
            rounds = physical = 0
            for b in group.batches:
                p, r, nb = self._run_batch_payload(b)
                parts.extend(p)
                rounds += r
                physical += nb
            return (parts, rounds, physical)
        out: List[tuple] = []
        total = 0
        for b, blob in zip(group.batches, blobs):
            total += len(blob)
            for pi, ci, e, ptr in b.parts:
                lo = ptr.offset - b.start
                out.append((pi, ci, blob[lo:lo + ptr.length]))
        return (out, 1, total)

    def _pick_replica(self, ptrs: Tuple[SlicePointer, ...],
                      inode_id=None) -> SlicePointer:
        """Prefer a replica on a live server so coalescing groups fetches
        onto servers that can actually answer them — and enforce the
        read-side failure policy (§2.9 + repair plane):

        * zero live replicas → typed ``ReplicaExhausted`` now, instead of
          a doomed round followed by a generic ``StorageError``;
        * fewer live replicas than ``Cluster(min_read_replicas)`` → typed
          ``DegradedRead`` (a policy refusal: the bytes are readable, the
          redundancy floor is not met);
        * any dead replica on a replicated extent files a failed-retrieve
          repair ticket for the owning inode, so reads — not just writes —
          feed the repair plane.
        """
        cluster = self.cluster
        live = [p for p in ptrs
                if (srv := cluster.servers.get(p.server_id)) is not None
                and srv.alive]
        if len(live) < len(ptrs) and inode_id is not None and len(ptrs) > 1:
            cluster.note_failed_retrieve(inode_id)
        if not live:
            raise ReplicaExhausted(
                f"no live replica among {len(ptrs)} for this extent")
        floor = getattr(cluster, "min_read_replicas", 1)
        if len(live) < floor:
            raise DegradedRead(
                f"{len(live)} live replica(s) < min_read_replicas={floor}")
        return live[0]

    def _run_batch_payload(self, batch: _FetchBatch) -> tuple:
        """Issue one batch; returns (parts, rounds, physical_bytes)."""
        if len(batch.parts) == 1:
            pi, ci, e, ptr = batch.parts[0]
            return ([(pi, ci, self.cluster.fetch_slice(e.ptrs))], 1, e.length)
        try:
            # memoryview so the per-part carving below aliases the blob
            # instead of copying it (the covering-retrieval inversion that
            # made vectored reads slower than scalar).
            blob = memoryview(self.cluster.fetch_slice((batch.covering,)))
        except StorageError:
            # Degrade to per-extent fetches with full replica failover
            # (§2.9): the chosen replica's server died between planning and
            # execution, or the covering range spans a GC'd hole.
            out = [(pi, ci, self.cluster.fetch_slice(e.ptrs))
                   for pi, ci, e, _ in batch.parts]
            return (out, len(batch.parts),
                    sum(e.length for _, _, e, _ in batch.parts))
        out = []
        for pi, ci, e, ptr in batch.parts:
            lo = ptr.offset - batch.start
            out.append((pi, ci, blob[lo:lo + ptr.length]))
        return (out, 1, len(blob))
