"""Locality-aware slice placement via consistent hashing (paper §2.7).

Writes for the same metadata region always map to the same storage server,
and — via a *differently salted* hash at the server level — to the same
backing file on that server.  A sequential writer therefore lays its bytes
down sequentially on one disk, which compaction later collapses into single
slice pointers spanning the contiguous range.

Hashes are content-stable (blake2b) rather than Python's randomized
``hash()`` so placement is deterministic across processes and restarts —
a requirement for pointers that outlive any single process.
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Any, Hashable, List, Sequence


def stable_hash(*parts: Any, salt: str = "") -> int:
    h = hashlib.blake2b(digest_size=8, person=salt.encode()[:16] or b"wtf")
    for p in parts:
        h.update(repr(p).encode())
        h.update(b"\x00")
    return int.from_bytes(h.digest(), "big")


class HashRing:
    """Consistent-hashing ring [Karger et al. 97] with virtual nodes."""

    VNODES = 64

    def __init__(self, server_ids: Sequence[int] = ()):
        self._points: List[int] = []
        self._owners: List[int] = []
        self._servers: set[int] = set()
        for sid in server_ids:
            self.add_server(sid)

    def add_server(self, server_id: int) -> None:
        if server_id in self._servers:
            return
        self._servers.add(server_id)
        for v in range(self.VNODES):
            point = stable_hash(server_id, v, salt="ring")
            idx = bisect.bisect(self._points, point)
            self._points.insert(idx, point)
            self._owners.insert(idx, server_id)

    def remove_server(self, server_id: int) -> None:
        if server_id not in self._servers:
            return
        self._servers.discard(server_id)
        keep = [(p, o) for p, o in zip(self._points, self._owners)
                if o != server_id]
        self._points = [p for p, _ in keep]
        self._owners = [o for _, o in keep]

    @property
    def servers(self) -> frozenset:
        return frozenset(self._servers)

    def owner(self, key: Hashable) -> int:
        """The server responsible for ``key`` (first vnode clockwise)."""
        if not self._points:
            raise RuntimeError("hash ring has no servers")
        point = stable_hash(key, salt="key")
        idx = bisect.bisect(self._points, point) % len(self._points)
        return self._owners[idx]

    def owners(self, key: Hashable, n: int) -> List[int]:
        """``n`` distinct servers for ``key`` — the replica set (§2.9)."""
        if not self._points:
            raise RuntimeError("hash ring has no servers")
        n = min(n, len(self._servers))
        point = stable_hash(key, salt="key")
        idx = bisect.bisect(self._points, point)
        out: List[int] = []
        seen: set[int] = set()
        for i in range(len(self._points)):
            owner = self._owners[(idx + i) % len(self._points)]
            if owner not in seen:
                seen.add(owner)
                out.append(owner)
                if len(out) == n:
                    break
        return out


def region_placement_key(inode_id: int, region_idx: int) -> tuple:
    """The identity of a metadata region — what the writer hands the ring so
    that all writes to one region land on one server (§2.7)."""
    return ("region", inode_id, region_idx)
