"""Client transaction/replay runtime (paper §2.6) — the bottom layer of the
split client.

The client library is assembled from three layers (see ``client.py``):

  * ``client_runtime`` (this module): fd table, per-client stats, op logging,
    the auto-commit retry loop, and ``WtfTransaction`` — the fully general
    multi-file transaction with transparent KV-abort replay;
  * ``slice_ops``: the data plane (slice planning, batched fetch, write/paste
    engines) and the file-slicing API surface;
  * ``posix_ops``: the POSIX-style surface (open/read/write/...) and the
    directory machinery.

Every application call is logged as an ``_Op`` with its arguments and its
application-visible outcome digest.  On a HyperDex-level abort (KVConflict /
PreconditionFailed) the filesystem is unchanged, so the whole op log is
replayed with the original arguments; if any replayed call's outcome differs
from what the application already observed, the transaction aborts to the
application — otherwise the replay commits invisibly.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from .errors import (BadFileDescriptor, KVConflict, NotOpenForWriting,
                     PreconditionFailed, TransactionAborted, WtfError)
from .iort import AtomicStatsMixin
from .metadata import Transaction
from .slicing import Extent, SlicePointer

SEEK_SET, SEEK_CUR, SEEK_END = 0, 1, 2


@dataclass
class _Fd:
    fd: int
    inode_id: int
    path: str
    offset: int = 0
    writable: bool = True
    # O_APPEND semantics: every write() lands at the file's CURRENT end,
    # not at the offset this fd cached when it was opened.  Routed through
    # the §2.5 relative append so concurrent appenders commute.
    append: bool = False

    def snap(self) -> tuple:
        return (self.fd, self.inode_id, self.path, self.offset,
                self.writable, self.append)

    @staticmethod
    def restore(t: tuple) -> "_Fd":
        return _Fd(*t)


@dataclass(slots=True)
class ClientStats(AtomicStatsMixin):
    """Logical I/O accounting as seen by this client (drives Table 2).

    ``fetch_batches`` / ``slices_coalesced`` measure the batched slice-fetch
    scheduler (``iosched``): each batch is one storage-server round, and each
    coalesced slice is a pointer dereference the scheduler folded into an
    adjacent one instead of issuing separately.  ``store_batches`` /
    ``slices_store_coalesced`` are the write-side mirror (``wsched``): store
    rounds issued vs. slice creations folded into a shared round.
    ``degraded_stores`` counts stores that achieved fewer than
    ``replication`` replicas (available but under-replicated, §2.9).
    ``writeback_flushes`` counts write-behind buffer flushes (one per
    commit scope that had deferred stores), and
    ``slices_cross_op_coalesced`` counts slice creations that coalesced
    into a covering store together with slices planned by a *different*
    logged op — the cross-op batching only the write-behind buffer enables.

    The async I/O runtime adds: ``async_ops`` (ops submitted through the
    futures surface), ``blocked_waits`` (data-plane waits the application
    actually blocked on — every synchronous fetch counts one; an async
    ``result()`` counts one only when the future was not yet done), and
    ``plan_cache_hits``/``plan_cache_misses`` (read plans served from /
    installed into the version-validated plan cache).

    The metadata-plane fast path adds ``resolved_index_hits`` /
    ``resolved_index_misses``: region overlay resolutions served by the
    delta-maintained resolved index (an O(delta) extension of a cached
    resolved form) vs. full ``overlay`` re-resolutions installed into it.

    Counters may be bumped from runtime pool threads concurrently with the
    application thread; all mutation goes through ``add`` (atomic, from
    ``iort.AtomicStatsMixin``) — a bare ``+=`` would drop updates.
    """

    data_bytes_written: int = 0      # bytes physically sent to storage servers
    data_bytes_read: int = 0         # bytes physically fetched (incl. gaps)
    logical_bytes_written: int = 0   # bytes the app asked to write/paste
    logical_bytes_read: int = 0      # bytes the app asked to read/yank
    txn_retries: int = 0
    txn_aborts: int = 0
    fetch_batches: int = 0           # storage-server rounds issued (reads)
    slices_coalesced: int = 0        # pointer fetches saved by coalescing
    store_batches: int = 0           # storage-server rounds issued (writes)
    slices_store_coalesced: int = 0  # slice creations saved by coalescing
    degraded_stores: int = 0         # stores with fewer replicas than asked
    vectored_ops: int = 0            # readv/writev/yankv/pastev batches run
    writeback_flushes: int = 0       # write-behind buffer flushes run
    slices_cross_op_coalesced: int = 0  # creations coalesced across ops
    async_ops: int = 0               # ops submitted via the async surface
    blocked_waits: int = 0           # data-plane waits the app blocked on
    plan_cache_hits: int = 0         # read plans served from the plan cache
    plan_cache_misses: int = 0       # read plans installed into the cache
    block_cache_hits: int = 0        # extents served from the block cache
    block_cache_misses: int = 0      # extents fetched then installed
    resolved_index_hits: int = 0     # overlays served by delta extension
    resolved_index_misses: int = 0   # overlays fully re-resolved + cached
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class _Ctx:
    """Execution context: one WarpKV transaction + replay bookkeeping."""

    __slots__ = ("txn", "first")

    def __init__(self, txn: Transaction, first: bool):
        self.txn = txn
        self.first = first               # first execution vs. replay


class _Op:
    __slots__ = ("name", "args", "kwargs", "digest", "artifacts")

    def __init__(self, name: str, args: tuple, kwargs: dict):
        self.name = name
        self.args = args
        self.kwargs = kwargs
        self.digest: Any = None
        self.artifacts: dict = {}        # slices created, ids allocated, ...


def _iter_slice_pointers(obj: Any):
    """Every ``SlicePointer`` reachable from an op-artifact value: bare
    pointers, replica tuples inside ``Extent``s, and arbitrary nesting in
    tuples/lists/dicts.  Unresolved write-behind placeholders simply have
    no pointers yet and yield nothing."""
    if isinstance(obj, SlicePointer):
        yield obj
    elif isinstance(obj, Extent):
        yield from _iter_slice_pointers(obj.ptrs)
    elif isinstance(obj, (tuple, list)):
        for v in obj:
            yield from _iter_slice_pointers(v)
    elif isinstance(obj, dict):
        for v in obj.values():
            yield from _iter_slice_pointers(v)


def _digest(value: Any) -> Any:
    """Stable comparison token for an op's application-visible outcome."""
    if isinstance(value, (bytes, bytearray, memoryview)):
        return ("bytes", hashlib.blake2b(bytes(value), digest_size=16).digest())
    if isinstance(value, tuple):
        return tuple(_digest(v) for v in value)
    if isinstance(value, list):
        return ("list",) + tuple(_digest(v) for v in value)
    if isinstance(value, dict):
        return ("dict",) + tuple(sorted((k, _digest(v))
                                        for k, v in value.items()))
    return value


def normalize_path(path: str) -> str:
    if not path.startswith("/"):
        raise WtfError(f"paths must be absolute: {path!r}")
    parts = [p for p in path.split("/") if p and p != "."]
    out: list[str] = []
    for p in parts:
        if p == "..":
            if out:
                out.pop()
        else:
            out.append(p)
    return "/" + "/".join(out)


def parent_of(path: str) -> str:
    norm = normalize_path(path)
    if norm == "/":
        return "/"
    return norm.rsplit("/", 1)[0] or "/"


def basename_of(path: str) -> str:
    norm = normalize_path(path)
    return norm.rsplit("/", 1)[1] if norm != "/" else "/"


class ClientRuntime:
    """Mixin providing fd bookkeeping and transactional op dispatch.

    ``WtfClient`` composes this with ``SliceOps`` and ``PosixOps``; the
    attributes referenced here (``kv``, ``stats``, ``_fds``, ...) are set up
    by ``WtfClient.__init__``.
    """

    MAX_RETRIES = 16

    # ------------------------------------------------------------ plumbing
    def _begin_txn(self):
        """Begin a KV transaction wired to this client's lease table (when
        the cluster runs leases) — every op/transaction/replay path MUST
        come through here so lease-served reads and the read-only commit
        skip apply uniformly, including op bodies on runtime pool threads
        (the lease table is thread-safe)."""
        txn = self.kv.begin()
        if self._lease_table is not None:
            txn.attach_leases(self._lease_table)
        return txn

    def _alloc_inode_id(self) -> int:
        # Unique without coordination (no read dependency on a counter →
        # creates never conflict with each other).
        return (self._client_id << 40) | next(self._id_counter)

    def _alloc_inode_id_for(self, path: str) -> int:
        """Allocate an inode id placed on the same metadata shard as
        ``path``, so the hot single-file transactions (open/read/write)
        stay single-shard by construction.  Identity on a 1-shard plane."""
        return self.kv.colocated_inode_id(path, self._alloc_inode_id())

    def _fd_state(self) -> dict:
        return {fd: f.snap() for fd, f in self._fds.items()}

    def _restore_fd_state(self, snap: dict) -> None:
        self._fds = {fd: _Fd.restore(t) for fd, t in snap.items()}

    def _get_fd(self, fd: int) -> _Fd:
        f = self._fds.get(fd)
        if f is None:
            raise BadFileDescriptor(f"fd {fd}")
        return f

    def _get_wfd(self, fd: int) -> _Fd:
        """Like ``_get_fd`` but the fd must be open for writing: write-side
        ops on an ``"r"`` fd raise instead of silently mutating the file."""
        f = self._get_fd(fd)
        if not f.writable:
            raise NotOpenForWriting(
                f"fd {fd} ({f.path!r}) is not open for writing")
        return f

    # ---------------------------------------------------- write-behind hooks
    def _write_behind_active(self) -> bool:
        """Whether slice creations of the op being executed should defer
        into the write-behind buffer (client knob or buffered handle)."""
        return self.write_behind or self._op_buffered

    def _flush_writeback(self, ctx: "_Ctx", ops=()) -> None:
        """Commit-boundary flush: store every deferred payload through the
        write scheduler in one pass, then resolve the recorded pending
        pointers everywhere they were captured — queued region commutes,
        op artifacts (so §2.6 replays reuse the batch pointers verbatim)
        and op digests.  Runs BEFORE the KV commit, preserving the
        slices-before-metadata invariant (§2.1) for the whole batch."""
        if not self._wb.pending:
            return
        from .inode import AppendExtents
        from .wbuf import resolve_value
        self._wb.flush(self.cluster, self.stats)

        def fix(cop):
            if isinstance(cop, AppendExtents):
                new = tuple(resolve_value(e) for e in cop.extents)
                if any(n is not o for n, o in zip(new, cop.extents)):
                    return AppendExtents(new, relative=cop.relative,
                                         bound=cop.bound)
            return cop

        ctx.txn.map_commutes(fix)
        for op in ops:
            op.artifacts = resolve_value(op.artifacts)
            op.digest = resolve_value(op.digest)

    # -------------------------------------------------------- txn dispatch
    def _release_handoffs(self, ops) -> None:
        """End-of-transaction ACK to the storage servers: every slice these
        ops created (recorded in their artifacts for §2.6 replay) has
        either been published by the commit or become plain garbage via
        the final abort — the tier-3 GC no longer needs to protect its
        create→commit handoff window.  Idempotent and exception-free."""
        ptrs = [p for op in ops
                for p in _iter_slice_pointers(op.artifacts)]
        if ptrs:
            self.cluster.release_slices(ptrs)

    def transaction(self) -> "WtfTransaction":
        """Begin a fully general multi-file transaction (§2.6)."""
        if self._txn is not None:
            raise WtfError("nested transactions are not supported")
        return WtfTransaction(self)

    def _run(self, name: str, *args, **kwargs) -> Any:
        if self._txn is not None:
            return self._txn._run(name, args, kwargs)
        # Auto-commit: single-op transaction with internal retry.  Nothing
        # is application-visible until we return, so retry is always safe.
        # A vectored op (readv/writev/yankv/pastev) is one op here, which is
        # what makes a whole batch atomic: either the entire batch commits
        # or the fd state and file contents are exactly as before.
        op = _Op(name, args, kwargs)
        fd_snap = self._fd_state()
        last: Optional[Exception] = None
        try:
            for attempt in range(self.MAX_RETRIES):
                if attempt:
                    self.stats.add(txn_retries=1)
                    self._restore_fd_state(fd_snap)
                ctx = _Ctx(self._begin_txn(), first=(attempt == 0))
                try:
                    result = self._exec(op, ctx)
                    # Write-behind (auto-commit scope): stores the op
                    # deferred flush here, in one scheduler pass, before
                    # the metadata commits.  Retries hit the op's resolved
                    # artifacts and leave the buffer empty.
                    self._flush_writeback(ctx, (op,))
                    ctx.txn.commit()
                    return result
                except (KVConflict, PreconditionFailed) as e:
                    last = e
                    continue
                except BaseException:
                    # Op body or flush failed outright: deferred payloads
                    # from the dead op must not leak into a later commit
                    # scope, and fd state the op advanced before failing
                    # rolls back.
                    self._wb.clear()
                    self._restore_fd_state(fd_snap)
                    raise
        finally:
            # Commit or final abort, the create→commit handoff is over:
            # un-shield this op's slices from the tier-3 GC.  Must run
            # after the LAST attempt, never between retries — replays
            # reuse the recorded pointers (§2.6).
            self._release_handoffs((op,))
        self.stats.add(txn_aborts=1)
        # the aborted op leaves no trace — including fd offsets the op
        # body advanced before its commit failed, and any deferred stores
        # a never-flushed attempt left in the write-behind buffer
        self._wb.clear()
        self._restore_fd_state(fd_snap)
        raise TransactionAborted(
            f"auto-commit op {name} failed after {self.MAX_RETRIES} "
            f"attempts: {last}")

    def _exec(self, op: _Op, ctx: _Ctx) -> Any:
        fn = getattr(self, f"_op_{op.name}")
        return fn(ctx, op, *op.args, **op.kwargs)


class WtfTransaction:
    """Fully general multi-file transaction with the §2.6 retry layer.

    Every application call is logged with its arguments and app-visible
    outcome digest.  On a HyperDex-level abort (KVConflict /
    PreconditionFailed) the filesystem is unchanged, so the whole op log is
    replayed with the original arguments; if any replayed call's outcome
    differs from what the application already observed, the transaction
    aborts to the application — otherwise the replay commits invisibly.
    """

    MAX_RETRIES = 16

    def __init__(self, client):
        self.client = client
        self._ops: list[_Op] = []
        self._ctx: Optional[_Ctx] = None
        self._fd_snap: Optional[dict] = None
        self._done = False

    # -- context manager ------------------------------------------------
    def __enter__(self) -> "WtfTransaction":
        if self.client._txn is not None:
            raise WtfError("client already has an open transaction")
        self.client._txn = self
        self._fd_snap = self.client._fd_state()
        self._ctx = _Ctx(self.client._begin_txn(), first=True)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        try:
            if exc_type is None and not self._done:
                self.commit()
            elif not self._done:
                self.abort()
        finally:
            self.client._txn = None
        return False

    # -- op dispatch -------------------------------------------------------
    def _run(self, name: str, args: tuple, kwargs: dict) -> Any:
        if self._done:
            raise WtfError("transaction already finished")
        op = _Op(name, args, kwargs)
        result = self.client._exec(op, self._ctx)
        op.digest = _digest(result)
        self._ops.append(op)
        return result

    # -- commit / abort -----------------------------------------------------
    def commit(self) -> None:
        if self._done:
            raise WtfError("transaction already finished")
        # Write-behind: every op's deferred stores flush as ONE scheduler
        # planning pass (cross-op coalescing + per-region fan-out); the
        # metadata commit only proceeds once every slice is durable
        # (§2.1).  Replays reuse the resolved artifacts, so retries never
        # re-store data.
        self._flush_or_abort()
        last: Optional[Exception] = None
        try:
            for attempt in range(self.MAX_RETRIES):
                if attempt:
                    self.client.stats.add(txn_retries=1)
                    try:
                        self._replay()
                    except (KVConflict, PreconditionFailed) as e:
                        last = e
                        continue
                    # Normally a no-op: replays hit the resolved artifact
                    # cache.  If a replayed op took a branch that planned a
                    # NEW store, it must flush before the commit too.
                    self._flush_or_abort()
                try:
                    self._ctx.txn.commit()
                    self._done = True
                    return
                except (KVConflict, PreconditionFailed) as e:
                    last = e
        finally:
            # The transaction is over either way (commit, divergent
            # replay, or give-up below): release the GC handoff shield on
            # every slice the op log created.
            self.client._release_handoffs(self._ops)
        self._done = True
        self.client.stats.add(txn_aborts=1)
        self.client._wb.clear()
        self.client._restore_fd_state(self._fd_snap)
        raise TransactionAborted(
            f"gave up after {self.MAX_RETRIES} replays: {last}")

    def _flush_or_abort(self) -> None:
        """Run the write-behind flush; on ANY failure (e.g. StorageError
        when every replica candidate refused) abort the transaction
        wholesale: the KV transaction never commits, so nothing becomes
        visible and partially created slices are unreferenced garbage for
        the tier-3 GC."""
        try:
            self.client._flush_writeback(self._ctx, self._ops)
        except BaseException:
            self._done = True
            self.client._wb.clear()
            self.client.stats.add(txn_aborts=1)
            try:
                self._ctx.txn.abort()
            finally:
                self.client._restore_fd_state(self._fd_snap)
                self.client._release_handoffs(self._ops)
            raise

    def _replay(self) -> None:
        """Re-execute the op log against a fresh KV transaction (§2.6)."""
        self.client._restore_fd_state(self._fd_snap)
        self._ctx = _Ctx(self.client._begin_txn(), first=False)
        for op in self._ops:
            try:
                result = self.client._exec(op, self._ctx)
            except (KVConflict, PreconditionFailed):
                raise
            except WtfError as e:
                # The op succeeded on first execution but errors on replay
                # (e.g. a validity check now fails against changed state):
                # that is a divergent application-visible outcome (§2.6).
                result = e
            if _digest(result) != op.digest:
                self._done = True
                self.client.stats.add(txn_aborts=1)
                # the transaction leaves no trace — including fd offsets
                # and deferred stores replayed ops queued before diverging
                self.client._wb.clear()
                self.client._restore_fd_state(self._fd_snap)
                raise TransactionAborted(
                    f"replayed {op.name} produced a different "
                    f"application-visible outcome")

    def abort(self) -> None:
        self._ctx.txn.abort()
        # Deferred stores were never dispatched: aborting a write-behind
        # transaction leaves zero storage-server garbage.
        self.client._wb.clear()
        self.client._restore_fd_state(self._fd_snap)
        # Eagerly-stored slices ARE garbage now — hand them to the GC.
        self.client._release_handoffs(self._ops)
        self._done = True
