"""Write-path store strategy — the write side of the unified I/O runtime.

PR 1 made the read path batched and scheduled (``iosched``); this module is
its write-side mirror.  The scalar client pays one synchronous
``Cluster.store_slice`` round per slice, serially per replica.  Here a
vectored op *plans* all its stores first (``StoreRequest``), and the
strategy then:

  1. **Groups by target.**  Requests are grouped by (replica-candidate
     servers, backing-file hint) — the placement ring (§2.7) maps a
     metadata region to one server and one backing file, so all writes for
     a region share a group and land sequentially on one disk.
  2. **Coalesces.**  Within a group, runs of small requests (each at most
     the pack threshold — sized by the runtime's adaptive cost model, or
     pinned by ``Cluster(store_coalesce_bytes=…)``) are packed into a
     single covering store; per-request pointers are carved back out with
     ``SlicePointer.sub`` arithmetic.  The remaining units still travel in
     ONE ``create_slices`` round per server — parts are appended
     contiguously under one backing-file lock.
  3. **Fans out.**  Replica creations for *distinct* servers (and groups
     targeting different servers) are issued as ``IoTask``s on the shared
     ``IoRuntime`` pool, so a multi-region write completes in one server's
     latency, not the sum, and replication costs max — not sum — of the
     replica round trips.

Failure handling (§2.9): each (group, replica) task walks the ring owners
through the unified ``iort.run_with_failover`` loop; a ``StorageError``
marks the server failed and falls back to the next owner, never reusing a
server another replica of the same data already landed on.  A store that
achieves at least one but fewer than ``replication`` replicas is recorded
as *degraded* (never silent); zero replicas raises ``StorageError``.

Accounting: ``ClientStats.store_batches`` counts server store rounds
actually issued and ``slices_store_coalesced`` counts the logical stores
folded into those rounds — the measurable effectiveness of the scheduler.
Server-side, each round bumps ``StorageStats.slices_created`` once and
``slices_written`` per logical slice carried.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .errors import StorageError
from .iort import IoTask, run_with_failover
from .slicing import SlicePointer

# Historical fixed pack threshold, kept as the adaptive model's seed and as
# the value benchmarks pin for comparable accounting.  A covering store of
# small writes costs nothing extra, while large writes keep their own
# pointers so GC and compaction see them individually.
DEFAULT_MAX_COALESCE = 32 << 10


class StoreRequest:
    """One planned slice creation: ``data`` placed for ``placement_key``
    (ring lookup) with ``hint`` (server-local backing-file lookup).  ``key``
    identifies the request in the result map.  ``op_tag``, when set, names
    the logged op that planned the request — the write-behind buffer tags
    each pending store so cross-op coalescing is measurable
    (``ClientStats.slices_cross_op_coalesced``)."""

    __slots__ = ("key", "data", "placement_key", "hint", "op_tag")

    def __init__(self, key: Any, data: bytes, placement_key: Any, hint: int,
                 op_tag: Any = None):
        self.key = key
        self.data = data
        self.placement_key = placement_key
        self.hint = hint
        self.op_tag = op_tag


class _Unit:
    """One part of a ``create_slices`` round: either a single large request
    or a covering pack of small adjacent ones.  ``spans`` maps each packed
    request to its byte range within the unit."""

    __slots__ = ("data", "spans")

    def __init__(self, data: bytes, spans: List[Tuple[StoreRequest, int, int]]):
        self.data = data
        self.spans = spans


class _StoreGroup:
    """All requests bound for one (replica candidate list, backing file).

    Owns the replica-placement state shared by this group's per-replica
    tasks: ``used`` servers (replicas must stay distinct, §2.9) guarded by
    ``lock`` because the tasks run concurrently on the pool.
    """

    __slots__ = ("candidates", "hint", "requests", "units", "used", "lock")

    def __init__(self, candidates: Tuple[int, ...], hint: int):
        self.candidates = candidates
        self.hint = hint
        self.requests: List[StoreRequest] = []
        self.units: List[_Unit] = []
        self.used: set[int] = set()
        self.lock = threading.Lock()

    def pack(self, max_coalesce: int) -> None:
        """Pack runs of small requests into covering units (plan order is
        preserved, so carved pointers stay disk-adjacent in file order)."""
        run: List[StoreRequest] = []

        def flush() -> None:
            if not run:
                return
            off, spans = 0, []
            for r in run:
                spans.append((r, off, len(r.data)))
                off += len(r.data)
            self.units.append(_Unit(b"".join(r.data for r in run),
                                    list(spans)))
            run.clear()

        for r in self.requests:
            if len(r.data) > max_coalesce:
                flush()
                self.units.append(_Unit(r.data, [(r, 0, len(r.data))]))
            else:
                run.append(r)
        flush()


def plan_store_groups(requests: Sequence[StoreRequest], ring, n_servers: int,
                      max_coalesce: int = DEFAULT_MAX_COALESCE
                      ) -> List[_StoreGroup]:
    """Group planned stores by (replica candidates, hint) and pack each
    group's small runs into covering units."""
    groups: Dict[Tuple[Tuple[int, ...], int], _StoreGroup] = {}
    for r in requests:
        cands = tuple(ring.owners(r.placement_key, n_servers))
        g = groups.get((cands, r.hint))
        if g is None:
            g = groups[(cands, r.hint)] = _StoreGroup(cands, r.hint)
        g.requests.append(r)
    out = list(groups.values())
    for g in out:
        g.pack(max_coalesce)
    return out


class WriteScheduler:
    """Write-side strategy layer over the cluster's ``IoRuntime``.

    One scheduler per cluster, shared by all clients; it owns no pool and
    no failover loop of its own.  ``store_many`` is the entry point; the
    client's ``_data_slices`` routes every vectored write through it so
    batched and scalar stores share one accounting scheme.
    """

    def __init__(self, cluster, runtime,
                 max_coalesce: Optional[int] = None):
        self.cluster = cluster
        self.runtime = runtime
        self._max_coalesce = max_coalesce    # None → adaptive via runtime

    @property
    def max_coalesce(self) -> int:
        """Current packing threshold (pinned or adaptive)."""
        if self._max_coalesce is not None:
            return self._max_coalesce
        return self.runtime.coalesce_bytes()

    # -------------------------------------------------------------- store
    def store_many(self, requests: Sequence[StoreRequest],
                   stats=None) -> Dict[Any, Tuple[SlicePointer, ...]]:
        """Store every request with ``cluster.replication`` replicas.

        Returns ``{request.key: (ptr per replica, ...)}``.  All data is
        durable on every returned pointer's server before this returns —
        metadata queued afterwards preserves the §2.1 invariant for the
        whole batch.
        """
        if not requests:
            return {}
        cluster = self.cluster
        want = max(1, cluster.replication)
        groups = plan_store_groups(requests, cluster._ring,
                                   len(cluster.servers), self.max_coalesce)
        # Cross-op coalescing: requests packed into one covering unit whose
        # op tag differs from the unit's first request came from *another*
        # logged op — the win the write-behind buffer exists for.  Counted
        # once per unit at plan time (replica rounds reuse the same packing).
        cross_op = 0
        for g in groups:
            for unit in g.units:
                if len(unit.spans) > 1:
                    first = unit.spans[0][0].op_tag
                    cross_op += sum(
                        1 for r, _, _ in unit.spans[1:]
                        if r.op_tag is not None and r.op_tag != first)
        tasks = [IoTask("store", g.candidates[rank % len(g.candidates)],
                        sum(len(u.data) for u in g.units), (g, rank))
                 for g in groups for rank in range(want)]
        results = self.runtime.run_tasks(tasks, self._run_replica)

        # Collate per-replica pointer lists back into per-request tuples.
        by_group: Dict[int, List[Optional[List[SlicePointer]]]] = {}
        rounds = physical = coalesced = 0
        for task, res in zip(tasks, results):
            g, rank = task.payload
            by_group.setdefault(id(g), []).append(res)
            if res is not None:
                rounds += 1
                physical += sum(len(r.data) for r in g.requests)
                coalesced += len(g.requests) - 1
        out: Dict[Any, Tuple[SlicePointer, ...]] = {}
        degraded = 0
        for g in groups:
            replicas = [r for r in by_group[id(g)] if r is not None]
            if not replicas:
                raise StorageError(
                    "no storage server could accept the slice batch")
            short = len(replicas) < want
            if short:
                # per-request unit, matching the scalar pipeline: every
                # slice in the short group is under-replicated
                degraded += len(g.requests)
            for i, req in enumerate(g.requests):
                out[req.key] = tuple(rep[i] for rep in replicas)
                if short:
                    # File a repair ticket per short request: the placement
                    # key names the (inode, region), which is everything
                    # the repair plane needs to re-replicate it later.
                    cluster.enqueue_repair(req.placement_key,
                                           ptrs=out[req.key])
        if degraded:
            cluster.note_degraded_stores(degraded)
            if getattr(cluster, "strict_replication", False):
                raise StorageError(
                    f"strict_replication: {degraded} slice(s) achieved "
                    f"fewer than {want} replicas")
        if stats is not None:
            stats.add(store_batches=rounds,
                      slices_store_coalesced=coalesced,
                      slices_cross_op_coalesced=cross_op,
                      data_bytes_written=physical,
                      degraded_stores=degraded)
        return out

    # ----------------------------------------------------------- internals
    def _run_replica(self, task: IoTask) -> Optional[List[SlicePointer]]:
        """One (group, replica) store round via the unified failover walk.

        Candidates are the group's ring owners rotated to this replica's
        preferred rank; a server already holding a replica of this group is
        never reused (claimed under the group lock), a ``StorageError``
        releases the claim, marks the server failed (§2.9) and falls back
        to the next owner.  Returns per-request pointers, or ``None`` if
        every candidate refused (the caller decides degraded vs. fatal).
        """
        g, rank = task.payload
        n = len(g.candidates)

        def candidates():
            for i in range(n):
                sid = g.candidates[(rank + i) % n]
                with g.lock:
                    if sid in g.used:
                        continue
                    srv = self.cluster.servers.get(sid)
                    if srv is None or not srv.alive:
                        continue
                    g.used.add(sid)
                yield sid, sid

        def attempt(srv, sid):
            task.server_id = sid        # actual target, for the cost model
            ptrs = srv.create_slices([u.data for u in g.units], g.hint)
            out: List[SlicePointer] = []
            for unit, uptr in zip(g.units, ptrs):
                for req, start, length in unit.spans:
                    out.append(uptr.sub(start, length))
            return out

        def release(sid):
            with g.lock:
                g.used.discard(sid)

        return run_with_failover(self.cluster, candidates(), attempt,
                                 release=release,
                                 exhausted=lambda _last: None)
