"""WarpKV — the transactional metadata store (HyperDex/Warp stand-in).

The paper stores all filesystem metadata in HyperDex and relies on three
properties of its transactions [15]:

  1. linearizable multi-key transactions across independent schemas ("spaces"),
  2. optimistic concurrency: a transaction aborts iff a value it *read*
     changed before commit,
  3. atomic list append that does not create a read dependency — this is what
     lets concurrent writers append slice pointers to the same region without
     conflicting (§2.1, §2.5).

WarpKV reproduces exactly that contract in-process:

  * every key is versioned; ``get`` inside a transaction records the version,
  * ``put``/``delete`` are buffered and applied atomically at commit,
  * *commutative operations* (``CommutingOp``) are evaluated at commit time
    under the commit locks, with a precondition check instead of a read
    dependency.  They model HyperDex's atomic append and the paper's bounded
    relative append (§2.5).  A commutative op that leaves the value unchanged
    does not bump the version, so e.g. parallel appends into the same region
    do not invalidate each other's inode reads.

Commit protocol: stripe locks are acquired in canonical order (no deadlock),
read versions validated, preconditions checked, writes applied, versions
bumped.  This yields strict serializability for the in-process setting.

**Group commit.**  Under concurrent auto-commit traffic the stripe-lock
acquisition pass itself becomes the convoy: every committer sorts and takes
its stripe locks one at a time while the rest pile up behind them.  With
``group_commit`` (default on), committers enqueue and the first one through
the commit mutex drains the queue as the *leader*: one sorted acquisition
pass over the union of the batch's stripes, then each transaction's
validate/stage/apply runs sequentially under those locks.  Sequential
application preserves the exact single-commit semantics (a batch-mate that
invalidates your read set aborts you precisely as a prior commit would
have), and failures are isolated per transaction.  ``KVStats`` records
``commit_lock_passes`` (sorted acquisition passes actually made) and
``grouped_commits`` (transactions that rode a leader's pass) — the
measurable win.

**Version-preserving commutes.**  A commutative op may declare
``version_preserving = True`` (see ``inode.CompactRegion``): when its
commit-time application changes the stored value while provably preserving
the bytes any reader can observe, WarpKV keeps the key's version unchanged.
Readers' recorded versions — and the plan cache validated against them —
stay valid; a metadata-shape-only rewrite never aborts anyone.

A bounded write-ahead log of committed mutations supports replication
veneers: a compacted latest-value-per-key snapshot plus a tail ring of the
most recent ``WAL_TAIL_MAX`` mutations, so a long-running cluster's WAL
memory is O(keyspace + tail), not O(history).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, List, Optional

from .errors import KVConflict, PreconditionFailed
from .iort import AtomicStatsMixin
from .testing import witness_lock

_TOMBSTONE = object()


@dataclass(slots=True)
class _Versioned:
    version: int
    value: Any


class CommutingOp:
    """A read-free, commit-time read-modify-write (HyperDex atomic append).

    ``apply(value)`` returns ``(new_value, result)``; it runs under the commit
    locks against the *latest* committed value.  ``precondition(value)`` may
    veto at commit time (→ ``PreconditionFailed``, the transaction as a whole
    aborts and the WTF retry layer takes over).  Ops must be pure so commit
    retries/replays are safe.

    ``version_preserving = True`` declares that this op's value changes
    preserve every byte a reader can observe (e.g. region compaction):
    WarpKV then applies the change WITHOUT bumping the key's version, so
    recorded read dependencies and version-validated plan caches survive.
    Only set it when that property genuinely holds — a version-preserving
    op that changes observable content would break serializability.

    ``preserves_version(old, new)`` refines the class-level flag per
    application: an op whose effect is *sometimes* invisible to
    serializability (e.g. ``BumpInode`` advancing only ``mtime``) can
    keep the version for exactly those applications.
    """

    version_preserving = False
    __slots__ = ()

    def precondition(self, value: Any) -> bool:  # pragma: no cover - default
        return True

    def apply(self, value: Any):  # -> tuple[Any, Any]
        raise NotImplementedError

    def preserves_version(self, old: Any, new: Any) -> bool:
        """Whether replacing ``old`` with ``new`` may keep the version.
        Called under the commit locks, after ``apply``; default is the
        class-level declaration."""
        return self.version_preserving


class ListAppend(CommutingOp):
    """Generic atomic list append (the HyperDex primitive WTF relies on)."""

    __slots__ = ("items",)

    def __init__(self, items: Iterable[Any]):
        self.items = list(items)

    def apply(self, value):
        cur = list(value) if value is not None else []
        cur.extend(self.items)
        return cur, len(cur)


class Transaction:
    """One optimistic multi-key transaction.

    When a lease table is attached (``attach_leases``), reads are served
    from valid client-side leases without touching the KV, and a read-only
    transaction whose every read dependency is covered by a still-valid
    lease commits without any KV round trip at all (``commit`` revalidates
    the leases and skips ``_commit`` entirely).  A revoked or expired lease
    simply falls back to the normal path: the recorded read versions are
    validated by the KV at commit, so a stale lease can never produce a
    stale commit — it produces a ``KVConflict`` and a §2.6 replay.
    """

    __slots__ = ("_kv", "_reads", "_writes", "_commutes",
                 "_commutes_by_key", "committed",
                 "_lease_tab", "_lease_used", "_phase_hook")

    def __init__(self, kv: "WarpKV"):
        self._kv = kv
        self._reads: dict[tuple[str, Any], int] = {}
        self._writes: dict[tuple[str, Any], Any] = {}
        self._commutes: list[tuple[str, Any, CommutingOp, list]] = []
        # per-key index so read-your-writes views don't scan the whole
        # queue (bulk paste/concat transactions queue thousands of ops)
        self._commutes_by_key: dict[tuple[str, Any], list] = {}
        self.committed = False
        self._lease_tab = None            # lease.LeaseTable, duck-typed
        self._lease_used: dict[tuple[str, Any], int] = {}
        self._phase_hook = None           # 2PC fault injection (testing)

    def attach_leases(self, table) -> None:
        """Serve this transaction's reads through a client lease table."""
        self._lease_tab = table

    def _read_dep(self, space: str, key: Any) -> tuple[int, Any]:
        """Committed (version, value) for a read dependency: from a valid
        lease when one is held (zero KV round trips), else from the KV —
        granting a lease on the way out so the *next* transaction hits."""
        sk = (space, key)
        tab = self._lease_tab
        if tab is not None:
            hit = tab.lookup(sk)
            if hit is not None:
                self._lease_used[sk] = hit[0]
                return hit
            tok = tab.begin_grant(sk)
            ver, val = self._kv._read_versioned(space, key)
            if tab.commit_grant(sk, tok, ver, val):
                self._lease_used[sk] = ver
            return ver, val
        return self._kv._read_versioned(space, key)

    # -- read set -----------------------------------------------------------
    def get(self, space: str, key: Any, default: Any = None) -> Any:
        sk = (space, key)
        if sk in self._writes:
            v = self._writes[sk]
            return default if v is _TOMBSTONE else v
        ver, val = self._read_dep(space, key)
        # Record the *first* observed version; seeing a different version on
        # a later read of the same key inside one txn is itself a conflict.
        prev = self._reads.setdefault(sk, ver)
        if prev != ver:
            raise KVConflict(f"non-repeatable read of {space}:{key!r}")
        return default if val is None else val

    def get_version(self, space: str, key: Any) -> Optional[int]:
        """Observed version of ``space:key``, with the read dependency
        recorded exactly like ``get`` — the plan cache's validation
        primitive: a cached plan whose regions still carry their recorded
        versions is as serializable as a fresh plan, because this call
        pins the same versions a re-plan would read.  Returns ``None`` for
        a key this transaction has buffered writes for (no stable
        committed version exists)."""
        sk = (space, key)
        if sk in self._writes:
            return None
        ver, _ = self._read_dep(space, key)
        prev = self._reads.setdefault(sk, ver)
        if prev != ver:
            raise KVConflict(f"non-repeatable read of {space}:{key!r}")
        return ver

    # -- write set ----------------------------------------------------------
    def put(self, space: str, key: Any, value: Any) -> None:
        self._writes[(space, key)] = value

    def delete(self, space: str, key: Any) -> None:
        self._writes[(space, key)] = _TOMBSTONE

    # -- commutative ops ----------------------------------------------------
    def commute(self, space: str, key: Any, op: CommutingOp) -> "_Deferred":
        """Queue a commit-time op; returns a cell filled in at commit."""
        sk = (space, key)
        per_key = self._commutes_by_key.setdefault(sk, [])
        # coalesce with the previous queued op on the same key when the op
        # type supports it (append-of-append, bump-of-bump): a bulk paste
        # queues thousands of ops on a handful of keys, and both the
        # read-your-writes view and commit apply then stay O(keys)
        if per_key and type(per_key[-1][2]) is type(op) \
                and hasattr(op, "coalesce"):
            entry = per_key[-1]
            merged = entry[2].coalesce(op)
            if merged is not None:
                entry[2] = merged
                return _Deferred(entry[3])
        entry = [space, key, op, []]
        self._commutes.append(entry)
        per_key.append(entry)
        return _Deferred(entry[3])

    def map_commutes(self, fn: Callable[[CommutingOp],
                                        Optional[CommutingOp]]) -> None:
        """Rewrite queued commutative ops in place: ``fn(op)`` returns a
        replacement op (or None / the same op to keep it).  Deferred result
        cells and queue order are preserved.  Used by the write-behind
        buffer to swap pending slice pointers for real ones after its
        commit-time flush, before this transaction commits."""
        for entry in self._commutes:
            new = fn(entry[2])
            if new is not None and new is not entry[2]:
                entry[2] = new

    def get_view(self, space: str, key: Any, default: Any = None) -> Any:
        """Read-your-writes view: the committed value (read dependency is
        recorded) with this transaction's queued commutative ops applied.

        If a concurrent transaction changes the key between this read and
        our commit, the read-version validation aborts us and the WTF retry
        layer replays — so the view the application saw is always consistent
        with what commits.
        """
        val = self.get(space, key, None)
        return self._apply_queued(space, key, val, default)

    def peek(self, space: str, key: Any, default: Any = None) -> Any:
        """Unvalidated snapshot read: like ``get_view`` but records NO read
        dependency.  Used where staleness is guarded by a commit-time
        precondition instead — e.g. the bounded relative append's fit check
        (§2.5), which must not make concurrent appends conflict."""
        sk = (space, key)
        if sk in self._writes:
            v = self._writes[sk]
            val = None if v is _TOMBSTONE else v
        else:
            tab = self._lease_tab
            hit = tab.lookup(sk) if tab is not None else None
            if hit is not None:
                val = hit[1]       # lease-served snapshot; no dep recorded
            else:
                _, val = self._kv._read_versioned(space, key)
        return self._apply_queued(space, key, val, default)

    def _apply_queued(self, space: str, key: Any, val: Any,
                      default: Any) -> Any:
        for entry in self._commutes_by_key.get((space, key), ()):
            val, _ = entry[2].apply(val)
        return default if val is None else val

    # -- commit -------------------------------------------------------------
    def commit(self) -> None:
        if self._lease_commit_skip():
            self.committed = True
            return
        self._kv._commit(self)
        self.committed = True

    def _lease_commit_skip(self) -> bool:
        """True iff this txn is read-only, every read dependency was served
        or covered by a lease, and all those leases revalidate atomically at
        their recorded versions right now — in which case committing at the
        KV would be a pure no-op validation pass, so we skip it entirely.
        Revalidation failing is NOT an abort: we fall through to the normal
        KV commit, which re-validates against real versions (and conflicts
        only if the data truly moved, not merely because a lease expired)."""
        tab = self._lease_tab
        if tab is None or self._writes or self._commutes or not self._reads:
            return False
        if len(self._lease_used) != len(self._reads):
            return False              # some read dep isn't lease-covered
        return tab.revalidate(self._lease_used)

    def abort(self) -> None:
        self._reads.clear()
        self._writes.clear()
        self._commutes.clear()
        self._commutes_by_key.clear()
        self._lease_used.clear()


class _Deferred:
    """Result of a commutative op, available after commit."""

    __slots__ = ("_cell",)

    def __init__(self, cell: list):
        self._cell = cell

    @property
    def value(self) -> Any:
        if not self._cell:
            raise RuntimeError("deferred result read before commit")
        return self._cell[0]


@dataclass(slots=True)
class KVStats(AtomicStatsMixin):
    """Counters bumped from the app thread AND runtime pool workers (async
    op bodies run their own KV transactions); mutation goes through the
    atomic ``add`` like the client/storage stats.

    ``commit_lock_passes`` counts sorted stripe-lock acquisition passes
    actually made; with group commit, concurrently-arriving transactions
    share a pass, so ``commits - commit_lock_passes`` (≈ ``grouped_commits``)
    is the number of acquisition passes the batching saved.
    ``compactions`` counts version-preserving commutes that actually
    rewrote a value (commit-time region compactions applied).

    ``conflicts`` counts true optimistic-concurrency losses — a commit
    aborted because a *read version* moved underneath it.  It is a strict
    subset of ``aborts``: precondition failures (e.g. a bounded append
    hitting a region boundary) and injected aborts are part of their
    protocols, not contention, and only bump ``aborts``.  §2.5's claim is
    exactly "parallel appends never conflict", i.e. ``conflicts == 0``.

    ``commit_wait_s`` / ``commit_hold_s`` / ``leader_drains`` expose the
    group-commit admission queue: wall-seconds committers spent waiting
    for a batch outcome, wall-seconds leaders spent draining batches, and
    the number of batches drained.  If commits serialize, waits grow with
    committer count while holds stay flat — that asymmetry is how the
    append serialization point was localized.
    """

    commits: int = 0
    aborts: int = 0
    conflicts: int = 0               # read-version validation failures
    gets: int = 0
    puts: int = 0
    commutes: int = 0
    compactions: int = 0             # version-preserving rewrites applied
    commit_lock_passes: int = 0      # stripe-lock acquisition passes made
    grouped_commits: int = 0         # txns that shared a leader's pass
    leader_drains: int = 0           # group-commit batches drained
    commit_wait_s: float = 0.0       # committer time queued for an outcome
    commit_hold_s: float = 0.0       # leader time spent draining batches
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class _CommitReq:
    """One queued commit: its transaction, outcome slot, and wakeup event.

    ``done``/``exc`` are written by the batch leader before it sets
    ``evt``; the owner reads them after ``evt.wait()`` returns — the
    event is the memory barrier.  ``lead`` is written only under the
    commit-queue lock (at enqueue, or by the previous leader handing
    off) and read by the owner after the same lock or event."""

    __slots__ = ("txn", "exc", "done", "evt", "lead")

    def __init__(self, txn: Transaction):
        self.txn = txn
        self.exc: Optional[BaseException] = None
        self.done = False
        self.evt = threading.Event()
        self.lead = False


class WarpKV:
    """Striped, versioned, optimistically-concurrent in-process KV store."""

    N_STRIPES = 64
    # WAL tail ring capacity: older mutations fold into the compacted
    # latest-value-per-key snapshot (see the module docstring).
    WAL_TAIL_MAX = 4096

    def __init__(self, group_commit: bool = True,
                 service_time_s: float = 0.0,
                 shard_index: int = 0):
        # ``shard_index`` places this store in the global (shard, stripe)
        # acquisition order; ``mdshard.ShardedKV`` passes each shard's
        # position.  Locks are wrapped by the runtime lock-order witness
        # when WTF_LOCK_WITNESS is set (no-op passthrough otherwise).
        self.shard_index = shard_index
        self._spaces: dict[str, dict[Any, _Versioned]] = {}
        self._space_lock = witness_lock(threading.Lock(), "kv.space")
        self._stripes = [
            witness_lock(threading.RLock(), "kv.stripe",
                         key=(shard_index, i))
            for i in range(self.N_STRIPES)]
        self.stats = KVStats()
        self.group_commit = group_commit
        # Modeled per-request service time of ONE metadata server: each
        # read and each commit pass serializes on a single service lock
        # while sleeping (GIL released), so a store has bounded capacity
        # and shard counts / lease hit rates become physically measurable.
        # 0.0 (the default) adds zero overhead on every path.
        self._service_time = float(service_time_s)
        self._service_lock = witness_lock(threading.Lock(), "kv.service")
        # Pre-apply lease barrier: called with the keys a commit is about
        # to mutate, under the stripe locks, BEFORE the first store — so a
        # lease holder that revalidates successfully is guaranteed not to
        # have observed any part of an in-flight commit (see core/lease.py).
        self._inval_listeners: list[Callable[[list], None]] = []
        self._commit_queue: List[_CommitReq] = []
        self._commit_queue_lock = witness_lock(threading.Lock(),
                                               "kv.commit_queue")
        # True while some committer owns batch leadership.  Leadership is
        # granted at enqueue (queue empty, no leader) or handed off by the
        # retiring leader to the head of the queue — always under
        # ``_commit_queue_lock``, so there is at most one leader and the
        # flag can never be left set without a live owner.
        self._leader_active = False
        self._leader_thread: Optional[int] = None
        # Bounded write-ahead log of committed mutations for chain
        # replication: compacted snapshot + recent-mutation tail ring.
        self._wal_tail: "deque[tuple[str, Any, Any, int]]" = deque()
        self._wal_snapshot: dict[tuple[str, Any], tuple[Any, int]] = {}
        # RLock: listeners run under this lock, and a listener that
        # commits re-enters ``_log`` on the same thread (the reentrant
        # commit path the ``_leader_thread`` guard permits).
        self._wal_lock = witness_lock(threading.RLock(), "kv.wal",
                                      key=shard_index)
        self._wal_listeners: list[Callable[[str, Any, Any, int], None]] = []
        self._fail_next_commits = 0   # test hook: forced HyperDex-level abort

    # -- plumbing -----------------------------------------------------------
    def _space(self, name: str) -> dict[Any, _Versioned]:
        sp = self._spaces.get(name)
        if sp is None:
            with self._space_lock:
                sp = self._spaces.setdefault(name, {})
        return sp

    def _stripe_of(self, space: str, key: Any) -> int:
        return hash((space, key)) % self.N_STRIPES

    def _service_delay(self) -> None:
        """One modeled server round trip (no-op when service time is 0)."""
        if self._service_time:
            with self._service_lock:
                # wtf-lint: ignore[WTF002] -- modeled service time: serializing the sleep IS the single-server queueing model
                time.sleep(self._service_time)

    def _read_versioned(self, space: str, key: Any) -> tuple[int, Any]:
        self._service_delay()
        self.stats.add(gets=1)
        sp = self._space(space)
        with self._stripes[self._stripe_of(space, key)]:
            ent = sp.get(key)
            if ent is None:
                return 0, None
            return ent.version, ent.value

    # -- non-transactional convenience (single-key linearizable ops) --------
    def get(self, space: str, key: Any, default: Any = None) -> Any:
        _, val = self._read_versioned(space, key)
        return default if val is None else val

    def put(self, space: str, key: Any, value: Any) -> None:
        txn = self.begin()
        txn.put(space, key, value)
        txn.commit()

    def keys(self, space: str) -> list:
        sp = self._space(space)
        # Snapshot under all stripe locks is unnecessary for iteration used
        # by the GC scanner; dict views are safe to copy in CPython.
        return [k for k, v in list(sp.items()) if v.value is not None]

    # -- transactions -------------------------------------------------------
    def begin(self) -> Transaction:
        return Transaction(self)

    def _commit(self, txn: Transaction) -> None:
        req = _CommitReq(txn)
        if not self.group_commit \
                or self._leader_thread == threading.get_ident():
            # Group commit off — or a re-entrant commit from inside a
            # batch (a WAL listener committing): parking on the admission
            # queue would deadlock against ourselves (we ARE the leader),
            # and the stripe RLocks are reentrant, so commit directly.
            self._commit_batch([req])
            if req.exc is not None:
                raise req.exc
            return
        # Group commit with leader *handoff*: enqueue; if nobody is
        # leading, lead immediately, otherwise park on our own event.
        # A leader drains exactly one batch under ONE sorted stripe-lock
        # acquisition pass, then passes leadership to the head of the
        # queue (a committer that arrived while it worked) and wakes its
        # own followers.  Unlike the old global commit mutex, retired
        # followers never re-acquire anything — they wake and return —
        # and the next batch's leader starts without waiting for this
        # batch's followers to drain through a mutex convoy.
        t0 = time.perf_counter()
        with self._commit_queue_lock:
            self._commit_queue.append(req)
            if not self._leader_active:
                self._leader_active = True
                req.lead = True
        if not req.lead:
            req.evt.wait()
        if req.lead:
            with self._commit_queue_lock:
                batch = self._commit_queue
                self._commit_queue = []
            self.stats.add(leader_drains=1,
                           commit_wait_s=time.perf_counter() - t0)
            t1 = time.perf_counter()
            self._leader_thread = threading.get_ident()
            try:
                self._commit_batch(batch)
            finally:
                self._leader_thread = None
                self.stats.add(commit_hold_s=time.perf_counter() - t1)
                with self._commit_queue_lock:
                    if self._commit_queue:
                        nxt = self._commit_queue[0]
                        nxt.lead = True
                        nxt.evt.set()
                    else:
                        self._leader_active = False
                for r in batch:
                    if r is not req:
                        r.evt.set()
        else:
            self.stats.add(commit_wait_s=time.perf_counter() - t0)
        if req.exc is not None:
            raise req.exc

    def _commit_batch(self, reqs: List[_CommitReq]) -> None:
        """Commit a batch under one stripe-lock pass (union of all stripes).

        Transactions are validated and applied *sequentially*, so the
        outcome is identical to committing them back-to-back: a batch-mate
        that invalidates your read set aborts you exactly as a prior
        commit would have.  Failures are isolated per transaction — each
        request carries its own exception back to its waiting committer.
        """
        self._service_delay()        # one modeled round trip per pass
        touched: set[tuple[str, Any]] = set()
        for req in reqs:
            t = req.txn
            touched |= set(t._reads) | set(t._writes)
            touched |= {(s, k) for s, k, _, _ in t._commutes}
        stripe_ids = sorted({self._stripe_of(s, k) for s, k in touched})
        self.stats.add(commit_lock_passes=1,
                       grouped_commits=len(reqs) - 1)
        for sid in stripe_ids:
            self._stripes[sid].acquire()
        try:
            for req in reqs:
                try:
                    self._apply_txn_locked(req.txn)
                except Exception as e:
                    req.exc = e
                finally:
                    req.done = True
        finally:
            for sid in reversed(stripe_ids):
                self._stripes[sid].release()
            for req in reqs:         # a leader crash must strand no one
                if not req.done:
                    req.exc = KVConflict("commit batch aborted")
                    req.done = True

    def _apply_txn_locked(self, txn: Transaction) -> None:
        """Validate and apply one transaction; caller holds its stripes."""
        self._apply_staged(txn, self._validate_and_stage(txn))

    def _validate_and_stage(self, txn) -> list:
        """Prepare phase: validate read versions and commutative
        preconditions, compute commute results against the post-write view
        — WITHOUT mutating anything.  Caller holds this shard's stripes
        for every touched key.  Raises on conflict; on success the returned
        staged list can be applied with ``_apply_staged`` (which cannot
        fail), so validate-everywhere-then-apply-everywhere is exactly the
        2PC contract ``mdshard.ShardedKV`` needs.  ``txn`` is duck-typed:
        anything carrying ``_reads``/``_writes``/``_commutes``."""
        if self._fail_next_commits > 0:
            # wtf-lint: ignore[WTF003] -- test-only crash hook; every caller holds the commit stripe locks
            self._fail_next_commits -= 1
            self.stats.add(aborts=1)
            raise KVConflict("injected abort")
        # 1. validate read versions (optimistic concurrency control)
        for (space, key), seen in txn._reads.items():
            ent = self._space(space).get(key)
            cur = ent.version if ent is not None else 0
            if cur != seen:
                self.stats.add(aborts=1, conflicts=1)
                raise KVConflict(
                    f"version conflict on {space}:{key!r} "
                    f"(saw {seen}, now {cur})")
        # 2. check commutative preconditions + compute results against
        # the post-write view (this txn's buffered writes included, and
        # earlier commutes on the same key chained in queue order)
        view: dict[tuple[str, Any], Any] = {}
        for (space, key), value in txn._writes.items():
            view[(space, key)] = None if value is _TOMBSTONE else value
        staged: list[tuple[str, Any, Any, Any, CommutingOp, list]] = []
        for space, key, op, cell in txn._commutes:
            sk = (space, key)
            if sk in view:
                cur = view[sk]
            else:
                ent = self._space(space).get(key)
                cur = ent.value if ent is not None else None
            if not op.precondition(cur):
                self.stats.add(aborts=1)
                raise PreconditionFailed(
                    f"precondition failed on {space}:{key!r}")
            new, result = op.apply(cur)
            view[sk] = new
            staged.append((space, key, new, result, op, cell))
        return staged

    def _apply_staged(self, txn, staged: list) -> None:
        """Apply phase: make a validated transaction's effects visible.
        Caller holds the stripes; this cannot fail (all validation already
        happened in ``_validate_and_stage``)."""
        # Lease barrier first: revoke leases on every key about to change
        # BEFORE any store, so no lease can outlive the pre-commit value
        # while part of this commit is already visible.
        if self._inval_listeners:
            changing = list(txn._writes)
            for space, key, new, _result, _op, _cell in staged:
                ent = self._space(space).get(key)
                if ent is None or ent.value != new:
                    changing.append((space, key))
            if changing:
                for fn in self._inval_listeners:
                    fn(changing)
        # 3. apply buffered writes.  Deletes keep a versioned tombstone
        # (value None) so a delete+recreate can never satisfy a stale
        # reader's version check (no ABA).
        n_compactions = 0
        for (space, key), value in txn._writes.items():
            sp = self._space(space)
            ent = sp.get(key)
            ver = (ent.version if ent is not None else 0) + 1
            stored = None if value is _TOMBSTONE else value
            sp[key] = _Versioned(ver, stored)
            self._log(space, key, stored, ver)
        # 4. apply commutative results; bump version only on real change,
        # and not at all for a version-preserving rewrite (compaction):
        # the bytes any reader can observe are unchanged, so recorded
        # read dependencies and cached plans must stay valid.
        for space, key, new, result, op, cell in staged:
            sp = self._space(space)
            ent = sp.get(key)
            if ent is not None and ent.value == new:
                pass                      # no-op merge: no invalidation
            elif ent is not None and op.preserves_version(ent.value, new):
                sp[key] = _Versioned(ent.version, new)
                self._log(space, key, new, ent.version)
                if op.version_preserving:
                    n_compactions += 1
            else:
                ver = (ent.version if ent is not None else 0) + 1
                sp[key] = _Versioned(ver, new)
                self._log(space, key, new, ver)
            cell.append(result)
        # One atomic bump for the whole transaction: each ``add`` takes
        # the stats lock, and per-key bumps were a measurable slice of
        # GIL-held commit time under many appenders.
        self.stats.add(commits=1, puts=len(txn._writes),
                       commutes=len(staged), compactions=n_compactions)

    # -- shard hooks (used by mdshard.ShardedKV) ----------------------------
    def lock_keys(self, touched: Iterable[tuple]) -> list[int]:
        """Acquire the stripe locks covering ``touched`` in canonical
        (sorted) order and return the stripe ids for ``unlock_keys``."""
        stripe_ids = sorted({self._stripe_of(s, k) for s, k in touched})
        for sid in stripe_ids:
            self._stripes[sid].acquire()
        return stripe_ids

    def unlock_keys(self, stripe_ids: list[int]) -> None:
        for sid in reversed(stripe_ids):
            self._stripes[sid].release()

    def add_invalidation_listener(self, fn: Callable[[list], None]) -> None:
        """Register a pre-apply lease barrier: ``fn(keys)`` is called under
        the commit's stripe locks with every (space, key) about to change,
        before the first store (see ``_apply_staged``)."""
        self._inval_listeners.append(fn)

    def colocated_inode_id(self, path: str, raw_id: int) -> int:
        """Map a unique raw inode id to the id actually stored.  A single
        shard has no placement constraint, so this is the identity; the
        sharded KV overrides it to colocate an inode with its path."""
        return raw_id

    # -- replication hooks ---------------------------------------------------
    def _log(self, space: str, key: Any, value: Any, version: int) -> None:
        with self._wal_lock:
            self._wal_tail.append((space, key, value, version))
            while len(self._wal_tail) > self.WAL_TAIL_MAX:
                s, k, v, ver = self._wal_tail.popleft()
                self._wal_snapshot[(s, k)] = (v, ver)
            for fn in self._wal_listeners:
                fn(space, key, value, version)

    def subscribe(self, fn: Callable, with_meta: bool = False) -> Callable[[], None]:
        """Replay the WAL into ``fn`` and register it for future commits.

        Replay is the compacted snapshot (latest folded value per key)
        followed by the tail ring, so a late subscriber converges on the
        exact current state in O(keyspace + tail) calls — not O(history).

        Replay and registration happen atomically under ``_wal_lock`` —
        the same lock every committer's ``_log`` takes — so there is no
        window between snapshot replay and live-tail attach: a mutation
        committing concurrently either lands in the replayed tail or is
        delivered live after registration, never both, never neither.

        ``with_meta=True`` delivers ``fn(space, key, value, version,
        shard, seq)`` with ``shard == 0`` and a per-subscriber 1-based
        gap-free ``seq`` — the same contract as ``ShardedKV.subscribe``,
        so stream consumers are agnostic to the shard count.

        Returns a zero-argument cancel callable that detaches the
        subscription (no further deliveries once it returns).
        """
        if with_meta:
            raw, box = fn, [0]

            def fn(space, key, value, version):  # noqa: F811
                box[0] += 1
                raw(space, key, value, version, 0, box[0])

        with self._wal_lock:
            for (space, key), (value, version) in self._wal_snapshot.items():
                fn(space, key, value, version)
            for space, key, value, version in self._wal_tail:
                fn(space, key, value, version)
            self._wal_listeners.append(fn)

        def cancel() -> None:
            with self._wal_lock:
                if fn in self._wal_listeners:
                    self._wal_listeners.remove(fn)

        return cancel

    def wal_entries(self) -> int:
        """Retained WAL size (snapshot keys + tail ring), for tests."""
        with self._wal_lock:
            return len(self._wal_snapshot) + len(self._wal_tail)

    # -- test hooks -----------------------------------------------------------
    def inject_aborts(self, n: int = 1) -> None:
        """Force the next ``n`` commits to abort at the KV level (for
        exercising the §2.6 retry layer)."""
        self._fail_next_commits = n
