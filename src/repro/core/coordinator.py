"""The replicated coordinator (paper Fig. 1, §3).

In the paper the coordinator is a 960-line replicated object hosted by the
Replicant state-machine service, which uses Paxos to sequence function calls
into the object.  We reproduce that structure: a tiny deterministic state
machine (`CoordinatorState`) replicated across N replicas by a sequencer that
assigns a total order to commands (the Paxos stand-in), with quorum reads and
replica crash/recovery.

The coordinator is the rendezvous point: it maintains the list of storage
servers, their liveness, and a monotonically increasing *configuration epoch*
that clients use to refresh their hash ring when membership changes.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .errors import NoQuorum


@dataclass
class ServerInfo:
    server_id: int
    address: str
    status: str = "online"      # online | failed


class CoordinatorState:
    """Deterministic replicated object.  Commands are (name, args) tuples;
    applying the same log to any replica yields the same state."""

    def __init__(self):
        self.epoch = 0
        self.servers: Dict[int, ServerInfo] = {}

    # Every mutation bumps the epoch so clients can cheaply detect staleness.
    def apply(self, command: str, args: tuple) -> Any:
        fn = getattr(self, f"_cmd_{command}")
        return fn(*args)

    def _cmd_register_server(self, server_id: int, address: str):
        self.servers[server_id] = ServerInfo(server_id, address)
        self.epoch += 1
        return self.epoch

    def _cmd_fail_server(self, server_id: int):
        info = self.servers.get(server_id)
        if info is not None and info.status != "failed":
            info.status = "failed"
            self.epoch += 1
        return self.epoch

    def _cmd_recover_server(self, server_id: int):
        info = self.servers.get(server_id)
        if info is not None and info.status != "online":
            info.status = "online"
            self.epoch += 1
        return self.epoch

    def _cmd_deregister_server(self, server_id: int):
        if self.servers.pop(server_id, None) is not None:
            self.epoch += 1
        return self.epoch

    def config(self) -> dict:
        return {
            "epoch": self.epoch,
            "online": sorted(s.server_id for s in self.servers.values()
                             if s.status == "online"),
            "failed": sorted(s.server_id for s in self.servers.values()
                             if s.status == "failed"),
        }


class _Replica:
    def __init__(self, rid: int):
        self.rid = rid
        self.state = CoordinatorState()
        self.applied_upto = 0           # log index
        self.alive = True


class ReplicatedCoordinator:
    """N-replica coordinator with a total-order command log.

    The sequencer (``_log`` + lock) plays the role of Paxos: every command
    gets a slot, replicas apply slots in order.  Commands succeed only while
    a majority of replicas is alive; reads are served by any replica that is
    caught up to the latest slot (linearizable in this in-process setting).
    """

    def __init__(self, n_replicas: int = 3):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self._replicas = [_Replica(i) for i in range(n_replicas)]
        self._log: List[Tuple[str, tuple]] = []
        self._lock = threading.RLock()

    # -- replication machinery ----------------------------------------------
    def _quorum(self) -> int:
        return len(self._replicas) // 2 + 1

    def _alive(self) -> List[_Replica]:
        return [r for r in self._replicas if r.alive]

    def _submit(self, command: str, args: tuple) -> Any:
        with self._lock:
            alive = self._alive()
            if len(alive) < self._quorum():
                raise NoQuorum(
                    f"{len(alive)}/{len(self._replicas)} replicas alive, "
                    f"need {self._quorum()}")
            self._log.append((command, args))
            slot = len(self._log)
            result = None
            for rep in alive:
                result = self._catch_up(rep, slot)
            return result

    def _catch_up(self, rep: _Replica, upto: int) -> Any:
        result = None
        while rep.applied_upto < upto:
            cmd, args = self._log[rep.applied_upto]
            result = rep.state.apply(cmd, args)
            rep.applied_upto += 1
        return result

    # -- coordinator API -----------------------------------------------------
    def register_server(self, server_id: int, address: str) -> int:
        return self._submit("register_server", (server_id, address))

    def fail_server(self, server_id: int) -> int:
        return self._submit("fail_server", (server_id,))

    def recover_server(self, server_id: int) -> int:
        return self._submit("recover_server", (server_id,))

    def deregister_server(self, server_id: int) -> int:
        return self._submit("deregister_server", (server_id,))

    def config(self) -> dict:
        """Quorum read: served by any caught-up live replica."""
        with self._lock:
            alive = self._alive()
            if len(alive) < self._quorum():
                raise NoQuorum("cannot serve linearizable read")
            rep = alive[0]
            self._catch_up(rep, len(self._log))
            return rep.state.config()

    # -- failure injection ----------------------------------------------------
    def crash_replica(self, rid: int) -> None:
        self._replicas[rid].alive = False

    def recover_replica(self, rid: int) -> None:
        with self._lock:
            rep = self._replicas[rid]
            rep.alive = True
            # State transfer: replay the log from the last applied slot.
            self._catch_up(rep, len(self._log))

    @property
    def n_replicas(self) -> int:
        return len(self._replicas)
