"""The WTF client library (paper §2.1–§2.6).

The client is where metadata (WarpKV) and data (storage servers) combine into
a coherent filesystem.  It exposes:

  * the POSIX-style API: open/close/read/write/seek/tell, mkdir/listdir,
    link/unlink/rename/stat — with one-lookup open (§2.4);
  * the file-slicing API: yank/paste/punch/append/concat/copy (Table 1);
  * fully general multi-file transactions with the §2.6 retry layer: every
    call inside a transaction is logged with its arguments and app-visible
    outcome; KV-level aborts are replayed transparently and only surface to
    the application when a re-executed call's outcome differs (an
    unresolvable, application-visible conflict).

Writers create slices on storage servers *before* their metadata commits, so
any transaction that can observe a slice pointer can safely dereference it —
the cornerstone invariant of the design (§2.1).
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .errors import (AlreadyExists, BadFileDescriptor, DirectoryNotEmpty,
                     IsADirectory, KVConflict, NotADirectory, NotFound,
                     PreconditionFailed, StorageError, TransactionAborted,
                     WtfError)
from .inode import (DEFAULT_REGION_SIZE, AppendExtents, BumpInode, Inode,
                    RegionData, region_key)
from .metadata import Transaction, WarpKV
from .placement import region_placement_key, stable_hash
from .slicing import (Extent, SlicePointer, compact, decode_extents,
                      encode_extents, merge_adjacent, shift, slice_range,
                      split_by_regions, visible_length)

import orjson

GC_DIR = "/.wtf-gc"          # reserved directory for GC live lists (§2.8)

SEEK_SET, SEEK_CUR, SEEK_END = 0, 1, 2


@dataclass
class _Fd:
    fd: int
    inode_id: int
    path: str
    offset: int = 0
    writable: bool = True

    def snap(self) -> tuple:
        return (self.fd, self.inode_id, self.path, self.offset, self.writable)

    @staticmethod
    def restore(t: tuple) -> "_Fd":
        return _Fd(*t)


@dataclass
class ClientStats:
    """Logical I/O accounting as seen by this client (drives Table 2)."""

    data_bytes_written: int = 0      # bytes physically sent to storage servers
    data_bytes_read: int = 0         # bytes physically fetched
    logical_bytes_written: int = 0   # bytes the app asked to write/paste
    logical_bytes_read: int = 0      # bytes the app asked to read/yank
    txn_retries: int = 0
    txn_aborts: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class _Ctx:
    """Execution context: one WarpKV transaction + replay bookkeeping."""

    def __init__(self, txn: Transaction, first: bool):
        self.txn = txn
        self.first = first               # first execution vs. replay


class _Op:
    __slots__ = ("name", "args", "kwargs", "digest", "artifacts")

    def __init__(self, name: str, args: tuple, kwargs: dict):
        self.name = name
        self.args = args
        self.kwargs = kwargs
        self.digest: Any = None
        self.artifacts: dict = {}        # slices created, ids allocated, ...


def _digest(value: Any) -> Any:
    """Stable comparison token for an op's application-visible outcome."""
    if isinstance(value, (bytes, bytearray)):
        return ("bytes", hashlib.blake2b(bytes(value), digest_size=16).digest())
    if isinstance(value, tuple):
        return tuple(_digest(v) for v in value)
    if isinstance(value, list):
        return ("list",) + tuple(_digest(v) for v in value)
    if isinstance(value, dict):
        return ("dict",) + tuple(sorted((k, _digest(v))
                                        for k, v in value.items()))
    return value


def normalize_path(path: str) -> str:
    if not path.startswith("/"):
        raise WtfError(f"paths must be absolute: {path!r}")
    parts = [p for p in path.split("/") if p and p != "."]
    out: list[str] = []
    for p in parts:
        if p == "..":
            if out:
                out.pop()
        else:
            out.append(p)
    return "/" + "/".join(out)


def parent_of(path: str) -> str:
    norm = normalize_path(path)
    if norm == "/":
        return "/"
    return norm.rsplit("/", 1)[0] or "/"


def basename_of(path: str) -> str:
    norm = normalize_path(path)
    return norm.rsplit("/", 1)[1] if norm != "/" else "/"


class WtfClient:
    """One application's handle on the filesystem.

    Not thread-safe by design: the paper's workloads use one client per
    thread/process; share the ``Cluster`` instead, which is thread-safe.
    """

    MAX_RETRIES = 16

    def __init__(self, cluster: "Cluster", client_id: Optional[int] = None):
        self.cluster = cluster
        self.kv: WarpKV = cluster.kv
        self.stats = ClientStats()
        self._client_id = (client_id if client_id is not None
                           else cluster._next_client_id())
        self._fd_counter = itertools.count(3)
        self._fds: Dict[int, _Fd] = {}
        self._id_counter = itertools.count(1)
        self._txn: Optional[WtfTransaction] = None
        self.time_fn: Callable[[], int] = lambda: int(time.time())

    # ------------------------------------------------------------ plumbing
    def _alloc_inode_id(self) -> int:
        # Unique without coordination (no read dependency on a counter →
        # creates never conflict with each other).
        return (self._client_id << 40) | next(self._id_counter)

    def _fd_state(self) -> dict:
        return {fd: f.snap() for fd, f in self._fds.items()}

    def _restore_fd_state(self, snap: dict) -> None:
        self._fds = {fd: _Fd.restore(t) for fd, t in snap.items()}

    def _get_fd(self, fd: int) -> _Fd:
        f = self._fds.get(fd)
        if f is None:
            raise BadFileDescriptor(f"fd {fd}")
        return f

    # -------------------------------------------------------- txn dispatch
    def transaction(self) -> "WtfTransaction":
        """Begin a fully general multi-file transaction (§2.6)."""
        if self._txn is not None:
            raise WtfError("nested transactions are not supported")
        return WtfTransaction(self)

    def _run(self, name: str, *args, **kwargs) -> Any:
        if self._txn is not None:
            return self._txn._run(name, args, kwargs)
        # Auto-commit: single-op transaction with internal retry.  Nothing
        # is application-visible until we return, so retry is always safe.
        op = _Op(name, args, kwargs)
        fd_snap = self._fd_state()
        last: Optional[Exception] = None
        for attempt in range(self.MAX_RETRIES):
            if attempt:
                self.stats.txn_retries += 1
                self._restore_fd_state(fd_snap)
            ctx = _Ctx(self.kv.begin(), first=(attempt == 0))
            try:
                result = self._exec(op, ctx)
                ctx.txn.commit()
                return result
            except (KVConflict, PreconditionFailed) as e:
                last = e
                continue
        self.stats.txn_aborts += 1
        raise TransactionAborted(
            f"auto-commit op {name} failed after {self.MAX_RETRIES} "
            f"attempts: {last}")

    def _exec(self, op: _Op, ctx: _Ctx) -> Any:
        fn = getattr(self, f"_op_{op.name}")
        return fn(ctx, op, *op.args, **op.kwargs)

    # ===================================================== public API: POSIX
    def mkfs(self) -> None:
        """Create the root directory and GC directory (idempotent)."""
        txn = self.kv.begin()
        if txn.get("paths", "/") is None:
            root = Inode(self._alloc_inode_id(), "dir",
                         mtime=self.time_fn(),
                         region_size=self.cluster.region_size)
            txn.put("paths", "/", root.inode_id)
            txn.put("inodes", root.inode_id, root)
            txn.commit()
            self.mkdir(GC_DIR)
        else:
            txn.abort()

    def open(self, path: str, mode: str = "r",
             region_size: Optional[int] = None) -> int:
        """One-lookup open (§2.4): pathname → inode in a single KV get."""
        return self._run("open", normalize_path(path), mode, region_size)

    def close(self, fd: int) -> None:
        self._get_fd(fd)
        del self._fds[fd]

    def read(self, fd: int, size: int = -1) -> bytes:
        return self._run("read", fd, size)

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        return self._run("pread", fd, size, offset)

    def write(self, fd: int, data: bytes) -> int:
        return self._run("write", fd, bytes(data))

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        return self._run("pwrite", fd, bytes(data), offset)

    def seek(self, fd: int, offset: int, whence: int = SEEK_SET):
        return self._run("seek", fd, offset, whence)

    def tell(self, fd: int) -> int:
        return self._get_fd(fd).offset

    def truncate(self, fd: int, length: int = 0) -> None:
        return self._run("truncate", fd, length)

    def mkdir(self, path: str) -> None:
        return self._run("mkdir", normalize_path(path))

    def listdir(self, path: str) -> list[str]:
        return self._run("listdir", normalize_path(path))

    def link(self, existing: str, new: str) -> None:
        """Hardlink: atomically add the path→inode mapping, bump the link
        count, and append the dirent — the paper's own example txn (§2.4)."""
        return self._run("link", normalize_path(existing), normalize_path(new))

    def unlink(self, path: str) -> None:
        return self._run("unlink", normalize_path(path))

    def rmdir(self, path: str) -> None:
        return self._run("rmdir", normalize_path(path))

    def rename(self, old: str, new: str) -> None:
        return self._run("rename", normalize_path(old), normalize_path(new))

    def stat(self, path: str) -> dict:
        return self._run("stat", normalize_path(path))

    def exists(self, path: str) -> bool:
        return self.kv.get("paths", normalize_path(path)) is not None

    def file_length(self, path: str) -> int:
        return self.stat(path)["size"]

    # ============================================= public API: file slicing
    def yank(self, fd: int, size: int, want_data: bool = False):
        """Copy ``size`` bytes from fd as slice pointers (Table 1)."""
        return self._run("yank", fd, size, want_data)

    def paste(self, fd: int, extents: Sequence[Extent]) -> int:
        """Write slices to fd at its offset — metadata only, zero data I/O."""
        return self._run("paste", fd, tuple(extents))

    def punch(self, fd: int, amount: int) -> int:
        """Zero ``amount`` bytes at the offset, freeing underlying storage."""
        return self._run("punch", fd, amount)

    def append(self, fd: int, data: bytes) -> int:
        """Append with the §2.5 relative-append fast path (commutative)."""
        return self._run("append", fd, bytes(data))

    def append_slices(self, fd: int, extents: Sequence[Extent]) -> int:
        return self._run("append_slices", fd, tuple(extents))

    def concat(self, sources: Sequence[str], dest: str) -> None:
        """Concatenate files by metadata alone (Table 1)."""
        return self._run("concat",
                         tuple(normalize_path(s) for s in sources),
                         normalize_path(dest))

    def copy(self, source: str, dest: str) -> None:
        return self._run("copy", normalize_path(source), normalize_path(dest))

    # ============================================================ op bodies
    # Each _op_* body executes against a WarpKV transaction and must be
    # replayable: artifacts created on first execution (slices, ids) are
    # recorded on the op and reused verbatim on replay (§2.6: the log keeps
    # slice pointers, never data).

    def _op_open(self, ctx: _Ctx, op: _Op, path: str, mode: str,
                 region_size: Optional[int]) -> int:
        create = "w" in mode or "a" in mode or "x" in mode
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            if not create:
                raise NotFound(path)
            ino_id = self._create_file(ctx, op, path, region_size)
            ino = ctx.txn.get("inodes", ino_id)
        else:
            if "x" in mode:
                raise AlreadyExists(path)
            ino = ctx.txn.get("inodes", ino_id)
            if ino is None:
                raise NotFound(f"dangling path {path}")
            if ino.kind == "dir" and ("w" in mode or "a" in mode):
                raise IsADirectory(path)
            if mode == "w":                       # truncate semantics
                self._truncate_inode(ctx, ino, 0)
        f = _Fd(op.artifacts.setdefault("fd", next(self._fd_counter)),
                ino_id, path, writable=("r" != mode))
        if "a" in mode:
            f.offset = self._file_length(ctx, ino)
        self._fds[f.fd] = f
        return f.fd

    def _create_file(self, ctx: _Ctx, op: _Op, path: str,
                     region_size: Optional[int]) -> int:
        parent = parent_of(path)
        parent_id = ctx.txn.get("paths", parent)
        if parent_id is None:
            raise NotFound(f"parent directory {parent}")
        pino = ctx.txn.get("inodes", parent_id)
        if pino.kind != "dir":
            raise NotADirectory(parent)
        ino_id = op.artifacts.setdefault("ino", self._alloc_inode_id())
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ino = Inode(ino_id, "file", mtime=now,
                    region_size=region_size or self.cluster.region_size)
        ctx.txn.put("paths", path, ino_id)
        ctx.txn.put("inodes", ino_id, ino)
        self._dir_append(ctx, op, pino, {"op": "add",
                                         "name": basename_of(path),
                                         "ino": ino_id})
        return ino_id

    def _op_read(self, ctx: _Ctx, op: _Op, fd: int, size: int) -> bytes:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        if size < 0:
            size = max(0, length - f.offset)
        size = min(size, max(0, length - f.offset))
        data = self._read_range(ctx, ino, f.offset, size)
        f.offset += len(data)
        self.stats.logical_bytes_read += len(data)
        return data

    def _op_pread(self, ctx: _Ctx, op: _Op, fd: int, size: int,
                  offset: int) -> bytes:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        size = min(size, max(0, length - offset))
        data = self._read_range(ctx, ino, offset, size)
        self.stats.logical_bytes_read += len(data)
        return data

    def _op_write(self, ctx: _Ctx, op: _Op, fd: int, data: bytes) -> int:
        f = self._get_fd(fd)
        n = self._write_at(ctx, op, f.inode_id, f.offset, data, key="w")
        f.offset += n
        return n

    def _op_pwrite(self, ctx: _Ctx, op: _Op, fd: int, data: bytes,
                   offset: int) -> int:
        f = self._get_fd(fd)
        return self._write_at(ctx, op, f.inode_id, offset, data, key="w")

    def _op_seek(self, ctx: _Ctx, op: _Op, fd: int, offset: int,
                 whence: int):
        f = self._get_fd(fd)
        if whence == SEEK_SET:
            f.offset = offset
            return f.offset
        if whence == SEEK_CUR:
            f.offset += offset
            return f.offset
        if whence == SEEK_END:
            ino = self._inode(ctx, f.inode_id)
            f.offset = self._file_length(ctx, ino) + offset
            # The application never observes the end-of-file offset through
            # seek — that's precisely what makes seek(END)+write retryable
            # without an application-visible conflict (§2.6).
            return None
        raise WtfError(f"bad whence {whence}")

    def _op_truncate(self, ctx: _Ctx, op: _Op, fd: int, length: int) -> None:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        self._truncate_inode(ctx, ino, length)

    def _op_mkdir(self, ctx: _Ctx, op: _Op, path: str) -> None:
        if ctx.txn.get("paths", path) is not None:
            raise AlreadyExists(path)
        parent = parent_of(path)
        parent_id = ctx.txn.get("paths", parent)
        if parent_id is None:
            raise NotFound(f"parent directory {parent}")
        pino = ctx.txn.get("inodes", parent_id)
        if pino.kind != "dir":
            raise NotADirectory(parent)
        ino_id = op.artifacts.setdefault("ino", self._alloc_inode_id())
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ino = Inode(ino_id, "dir", mtime=now,
                    region_size=self.cluster.region_size)
        ctx.txn.put("paths", path, ino_id)
        ctx.txn.put("inodes", ino_id, ino)
        self._dir_append(ctx, op, pino,
                         {"op": "add", "name": basename_of(path),
                          "ino": ino_id})

    def _op_listdir(self, ctx: _Ctx, op: _Op, path: str) -> list[str]:
        ino = self._inode_at(ctx, path)
        if ino.kind != "dir":
            raise NotADirectory(path)
        return sorted(self._dir_entries(ctx, ino).keys())

    def _op_link(self, ctx: _Ctx, op: _Op, existing: str, new: str) -> None:
        ino_id = ctx.txn.get("paths", existing)
        if ino_id is None:
            raise NotFound(existing)
        if ctx.txn.get("paths", new) is not None:
            raise AlreadyExists(new)
        parent_id = ctx.txn.get("paths", parent_of(new))
        if parent_id is None:
            raise NotFound(parent_of(new))
        pino = ctx.txn.get("inodes", parent_id)
        # Atomically: new mapping + link count + dirent (§2.4).
        ctx.txn.put("paths", new, ino_id)
        ctx.txn.commute("inodes", ino_id, BumpInode(link_delta=1))
        self._dir_append(ctx, op, pino,
                         {"op": "add", "name": basename_of(new),
                          "ino": ino_id})

    def _op_unlink(self, ctx: _Ctx, op: _Op, path: str) -> None:
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        ino = ctx.txn.get("inodes", ino_id)
        if ino.kind == "dir":
            raise IsADirectory(path)
        parent_id = ctx.txn.get("paths", parent_of(path))
        pino = ctx.txn.get("inodes", parent_id)
        ctx.txn.delete("paths", path)
        self._dir_append(ctx, op, pino,
                         {"op": "del", "name": basename_of(path)})
        if ino.links <= 1:
            # Last link: drop the inode and all region metadata; the slices
            # become garbage for the tier-3 collector (§2.8).
            ctx.txn.delete("inodes", ino_id)
            for r in range(ino.max_region + 1):
                ctx.txn.delete("regions", region_key(ino_id, r))
        else:
            ctx.txn.put("inodes", ino_id, ino.replace(links=ino.links - 1))

    def _op_rmdir(self, ctx: _Ctx, op: _Op, path: str) -> None:
        if path == "/":
            raise WtfError("cannot remove the root directory")
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        ino = ctx.txn.get("inodes", ino_id)
        if ino.kind != "dir":
            raise NotADirectory(path)
        if self._dir_entries(ctx, ino):
            raise DirectoryNotEmpty(path)
        parent_id = ctx.txn.get("paths", parent_of(path))
        pino = ctx.txn.get("inodes", parent_id)
        ctx.txn.delete("paths", path)
        ctx.txn.delete("inodes", ino_id)
        ctx.txn.delete("regions", region_key(ino_id, 0))
        self._dir_append(ctx, op, pino,
                         {"op": "del", "name": basename_of(path)})

    def _op_rename(self, ctx: _Ctx, op: _Op, old: str, new: str) -> None:
        ino_id = ctx.txn.get("paths", old)
        if ino_id is None:
            raise NotFound(old)
        if ctx.txn.get("paths", new) is not None:
            raise AlreadyExists(new)
        old_pid = ctx.txn.get("paths", parent_of(old))
        new_pid = ctx.txn.get("paths", parent_of(new))
        if new_pid is None:
            raise NotFound(parent_of(new))
        ctx.txn.delete("paths", old)
        ctx.txn.put("paths", new, ino_id)
        self._dir_append(ctx, op, ctx.txn.get("inodes", old_pid),
                         {"op": "del", "name": basename_of(old)}, key="d1")
        self._dir_append(ctx, op, ctx.txn.get("inodes", new_pid),
                         {"op": "add", "name": basename_of(new),
                          "ino": ino_id}, key="d2")

    def _op_stat(self, ctx: _Ctx, op: _Op, path: str) -> dict:
        ino = self._inode_at(ctx, path)
        return {
            "inode": ino.inode_id,
            "kind": ino.kind,
            "links": ino.links,
            "mtime": ino.mtime,
            "mode": ino.mode,
            "size": self._file_length(ctx, ino),
            "region_size": ino.region_size,
        }

    # ---------------------------------------------------- slicing op bodies
    def _op_yank(self, ctx: _Ctx, op: _Op, fd: int, size: int,
                 want_data: bool):
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        size = min(size, max(0, length - f.offset))
        extents = self._plan_range(ctx, ino, f.offset, size)
        data = None
        if want_data:
            data = self._fetch(extents)
            self.stats.logical_bytes_read += size
        f.offset += size
        extents = tuple(extents)
        return (extents, data) if want_data else extents

    def _op_paste(self, ctx: _Ctx, op: _Op, fd: int,
                  extents: Tuple[Extent, ...]) -> int:
        f = self._get_fd(fd)
        n = self._paste_at(ctx, f.inode_id, f.offset, extents)
        f.offset += n
        self.stats.logical_bytes_written += n
        return n

    def _op_punch(self, ctx: _Ctx, op: _Op, fd: int, amount: int) -> int:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        max_r = -1
        for r, rel, _, ln in split_by_regions(f.offset, amount,
                                              ino.region_size):
            ctx.txn.commute("regions", region_key(ino.inode_id, r),
                            AppendExtents([Extent(rel, ln, ())]))
            max_r = max(max_r, r)
        self._bump(ctx, ino.inode_id, op, max_region=max_r)
        f.offset += amount
        return amount

    def _op_append(self, ctx: _Ctx, op: _Op, fd: int, data: bytes) -> int:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        last = max(ino.max_region, 0)
        # Unvalidated fit check: the commit-time bound precondition is the
        # real guard, so concurrent appends carry no read dependency (§2.5).
        rd = ctx.txn.peek("regions", region_key(ino.inode_id, last),
                          RegionData())
        if rd.end + len(data) <= ino.region_size:
            # Fast path (§2.5): commutative bounded append — resolved against
            # the region's end at commit time, so concurrent appends all
            # commit without conflicting.
            full = self._data_slice(ctx, op, ino, last, data, key="a")
            ctx.txn.commute(
                "regions", region_key(ino.inode_id, last),
                AppendExtents([Extent(0, len(data), full.ptrs)],
                              relative=True, bound=ino.region_size))
            self._bump(ctx, ino.inode_id, op, max_region=last)
        else:
            # Fallback: read end-of-file and write at that offset (§2.5);
            # a replay reuses the already-written slice ("paste the
            # previously written slice at the new end of file").
            eof = self._file_length(ctx, ino)
            self._write_at(ctx, op, ino.inode_id, eof, data, key="a")
        self.stats.logical_bytes_written += len(data)
        return len(data)

    def _op_append_slices(self, ctx: _Ctx, op: _Op, fd: int,
                          extents: Tuple[Extent, ...]) -> int:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        eof = self._file_length(ctx, ino)
        n = self._paste_at(ctx, f.inode_id, eof, extents)
        self.stats.logical_bytes_written += n
        return n

    def _op_concat(self, ctx: _Ctx, op: _Op, sources: Tuple[str, ...],
                   dest: str) -> None:
        cursor = 0
        if ctx.txn.get("paths", dest) is None:
            self._create_file(ctx, op, dest, None)
        dest_ino = self._inode_at(ctx, dest)
        for src in sources:
            ino = self._inode_at(ctx, src)
            length = self._file_length(ctx, ino)
            extents = self._plan_range(ctx, ino, 0, length)
            cursor += self._paste_at(ctx, dest_ino.inode_id, cursor, extents)
        self.stats.logical_bytes_written += cursor

    def _op_copy(self, ctx: _Ctx, op: _Op, source: str, dest: str) -> None:
        return self._op_concat(ctx, op, (source,), dest)

    # ------------------------------------------------------------ internals
    def _inode(self, ctx: _Ctx, inode_id: int) -> Inode:
        # get_view: BumpInode commutes queued earlier in this transaction
        # (e.g. a paste growing max_region) must be visible to later ops.
        ino = ctx.txn.get_view("inodes", inode_id)
        if ino is None:
            raise NotFound(f"inode {inode_id}")
        return ino

    def _inode_at(self, ctx: _Ctx, path: str) -> Inode:
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        return self._inode(ctx, ino_id)

    def _bump(self, ctx: _Ctx, inode_id: int, op: _Op,
              max_region: Optional[int] = None) -> None:
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ctx.txn.commute("inodes", inode_id,
                        BumpInode(max_region=max_region, mtime=now))

    def _file_length(self, ctx: _Ctx, ino: Inode) -> int:
        if ino.max_region < 0:
            return 0
        rd = ctx.txn.get_view("regions",
                              region_key(ino.inode_id, ino.max_region),
                              RegionData())
        return ino.max_region * ino.region_size + rd.end

    def _region_entries(self, ctx: _Ctx, ino: Inode,
                        region_idx: int) -> list[Extent]:
        rd = ctx.txn.get_view("regions",
                              region_key(ino.inode_id, region_idx))
        if rd is None:
            return ()
        if rd.indirect is None:
            # return the stored tuple itself: `overlay_cached` memoizes on
            # it, so repeated reads of an unchanged region plan in O(1)
            return rd.entries
        # Tier-2 GC: the bulk of the list lives in a slice (§2.8).
        base = decode_extents(self._fetch([rd.indirect]))
        return tuple(base) + tuple(rd.entries)

    def _plan_range(self, ctx: _Ctx, ino: Inode, offset: int,
                    length: int) -> list[Extent]:
        """File-absolute extents (incl. zero runs) tiling [offset, +length)."""
        out: list[Extent] = []
        for r, rel, _, ln in split_by_regions(offset, length,
                                              ino.region_size):
            entries = self._region_entries(ctx, ino, r)
            part = slice_range(entries, rel, ln)
            out.extend(shift(part, r * ino.region_size))
        return merge_adjacent(out)

    def _read_range(self, ctx: _Ctx, ino: Inode, offset: int,
                    length: int) -> bytes:
        if length <= 0:
            return b""
        return self._fetch(self._plan_range(ctx, ino, offset, length))

    def _fetch(self, extents: Sequence[Extent]) -> bytes:
        """Dereference pointers, replica-failover aware (§2.9)."""
        chunks: list[bytes] = []
        for e in extents:
            if e.is_zero:
                chunks.append(b"\x00" * e.length)
                continue
            chunks.append(self.cluster.fetch_slice(e.ptrs))
            self.stats.data_bytes_read += e.length
        return b"".join(chunks)

    def _data_slice(self, ctx: _Ctx, op: _Op, ino: Inode, region: int,
                    data: bytes, key: str) -> Extent:
        """Create one (replicated) slice for ``data``, placed for ``region``.

        Created on first execution only; replays reuse the recorded pointers
        verbatim — the §2.6 op log holds slice pointers, never data.  A write
        that crosses a region boundary stays a *single* slice; each region's
        list gets a sub-ranged pointer (Figure 3, write C).
        """
        cached = op.artifacts.get(key)
        if cached is not None:
            return cached
        hint = stable_hash(region_placement_key(ino.inode_id, region))
        ptrs = self.cluster.store_slice(
            data, region_placement_key(ino.inode_id, region), hint)
        self.stats.data_bytes_written += len(data) * len(ptrs)
        ext = Extent(0, len(data), ptrs)
        op.artifacts[key] = ext
        return ext

    def _write_at(self, ctx: _Ctx, op: _Op, inode_id: int, offset: int,
                  data: bytes, key: str) -> int:
        ino = self._inode(ctx, inode_id)
        first_region = offset // ino.region_size
        full = self._data_slice(ctx, op, ino, first_region, data, key)
        max_r = ino.max_region
        for r, rel, po, ln in split_by_regions(offset, len(data),
                                               ino.region_size):
            ctx.txn.commute("regions", region_key(inode_id, r),
                            AppendExtents([full.sub(po, ln).at(rel)]))
            max_r = max(max_r, r)
        self._bump(ctx, inode_id, op, max_region=max_r)
        self.stats.logical_bytes_written += len(data)
        return len(data)

    def _paste_at(self, ctx: _Ctx, inode_id: int, offset: int,
                  extents: Sequence[Extent]) -> int:
        """Overlay existing slices at ``offset`` — pure metadata, no I/O."""
        ino = self._inode(ctx, inode_id)
        cursor = offset
        max_r = ino.max_region
        for e in extents:
            consumed = 0
            while consumed < e.length:
                r = cursor // ino.region_size
                rel = cursor - r * ino.region_size
                take = min(e.length - consumed, ino.region_size - rel)
                piece = e.sub(consumed, take).at(rel)
                ctx.txn.commute("regions", region_key(inode_id, r),
                                AppendExtents([piece]))
                max_r = max(max_r, r)
                cursor += take
                consumed += take
        op = _Op("paste_internal", (), {})
        self._bump(ctx, inode_id, op, max_region=max_r)
        return cursor - offset

    def _truncate_inode(self, ctx: _Ctx, ino: Inode, length: int) -> None:
        if length != 0:
            raise WtfError("only truncate-to-zero is supported")
        for r in range(ino.max_region + 1):
            ctx.txn.delete("regions", region_key(ino.inode_id, r))
        ctx.txn.put("inodes", ino.inode_id,
                    ino.replace(max_region=-1, mtime=self.time_fn()))

    # ----------------------------------------------------------- dir files
    # Directories are special files (§2.4): their content is a record log of
    # add/del entries, maintained with the same append machinery as data.
    def _dir_append(self, ctx: _Ctx, op: _Op, dir_ino: Inode, record: dict,
                    key: str = "d") -> None:
        data = orjson.dumps(record) + b"\n"
        full = self._data_slice(ctx, op, dir_ino, 0, data, key=key)
        ctx.txn.commute(
            "regions", region_key(dir_ino.inode_id, 0),
            AppendExtents([Extent(0, len(data), full.ptrs)],
                          relative=True, bound=dir_ino.region_size))
        self._bump(ctx, dir_ino.inode_id, op, max_region=0)

    def _dir_entries(self, ctx: _Ctx, dir_ino: Inode) -> dict[str, int]:
        length = self._file_length(ctx, dir_ino)
        raw = self._read_range(ctx, dir_ino, 0, length)
        entries: dict[str, int] = {}
        for line in raw.split(b"\n"):
            if not line.strip(b"\x00"):
                continue
            rec = orjson.loads(line)
            if rec["op"] == "add":
                entries[rec["name"]] = rec["ino"]
            else:
                entries.pop(rec["name"], None)
        return entries


class WtfTransaction:
    """Fully general multi-file transaction with the §2.6 retry layer.

    Every application call is logged with its arguments and app-visible
    outcome digest.  On a HyperDex-level abort (KVConflict /
    PreconditionFailed) the filesystem is unchanged, so the whole op log is
    replayed with the original arguments; if any replayed call's outcome
    differs from what the application already observed, the transaction
    aborts to the application — otherwise the replay commits invisibly.
    """

    MAX_RETRIES = 16

    def __init__(self, client: WtfClient):
        self.client = client
        self._ops: list[_Op] = []
        self._ctx: Optional[_Ctx] = None
        self._fd_snap: Optional[dict] = None
        self._done = False

    # -- context manager ------------------------------------------------
    def __enter__(self) -> "WtfTransaction":
        if self.client._txn is not None:
            raise WtfError("client already has an open transaction")
        self.client._txn = self
        self._fd_snap = self.client._fd_state()
        self._ctx = _Ctx(self.client.kv.begin(), first=True)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        try:
            if exc_type is None and not self._done:
                self.commit()
            elif not self._done:
                self.abort()
        finally:
            self.client._txn = None
        return False

    # -- op dispatch -------------------------------------------------------
    def _run(self, name: str, args: tuple, kwargs: dict) -> Any:
        if self._done:
            raise WtfError("transaction already finished")
        op = _Op(name, args, kwargs)
        result = self.client._exec(op, self._ctx)
        op.digest = _digest(result)
        self._ops.append(op)
        return result

    # -- commit / abort -----------------------------------------------------
    def commit(self) -> None:
        if self._done:
            raise WtfError("transaction already finished")
        last: Optional[Exception] = None
        for attempt in range(self.MAX_RETRIES):
            if attempt:
                self.client.stats.txn_retries += 1
                try:
                    self._replay()
                except (KVConflict, PreconditionFailed) as e:
                    last = e
                    continue
            try:
                self._ctx.txn.commit()
                self._done = True
                return
            except (KVConflict, PreconditionFailed) as e:
                last = e
        self._done = True
        self.client.stats.txn_aborts += 1
        self.client._restore_fd_state(self._fd_snap)
        raise TransactionAborted(
            f"gave up after {self.MAX_RETRIES} replays: {last}")

    def _replay(self) -> None:
        """Re-execute the op log against a fresh KV transaction (§2.6)."""
        self.client._restore_fd_state(self._fd_snap)
        self._ctx = _Ctx(self.client.kv.begin(), first=False)
        for op in self._ops:
            result = self.client._exec(op, self._ctx)
            if _digest(result) != op.digest:
                self._done = True
                self.client.stats.txn_aborts += 1
                # the transaction leaves no trace — including fd offsets
                self.client._restore_fd_state(self._fd_snap)
                raise TransactionAborted(
                    f"replayed {op.name} produced a different "
                    f"application-visible outcome")

    def abort(self) -> None:
        self._ctx.txn.abort()
        self.client._restore_fd_state(self._fd_snap)
        self._done = True


class Cluster:
    """Wires together the four components of Figure 1 and owns shared state.

    Thread-safe; create one ``WtfClient`` per worker thread on top of it.
    """

    def __init__(self, n_servers: int = 4, data_dir: str = "/tmp/wtf",
                 replication: int = 1,
                 region_size: int = DEFAULT_REGION_SIZE,
                 coordinator_replicas: int = 3,
                 num_backing_files: int = 8):
        from .coordinator import ReplicatedCoordinator
        from .placement import HashRing
        from .storage import StorageServer
        import os

        self.kv = WarpKV()
        self.region_size = region_size
        self.replication = replication
        self.coordinator = ReplicatedCoordinator(coordinator_replicas)
        self.servers: Dict[int, Any] = {}
        self._ring = HashRing()
        self._ring_epoch = -1
        self._lock = threading.Lock()
        self._client_ids = itertools.count(1)
        for sid in range(n_servers):
            root = os.path.join(data_dir, f"server_{sid:03d}")
            srv = StorageServer(sid, root,
                                num_backing_files=num_backing_files)
            self.servers[sid] = srv
            self.coordinator.register_server(sid, root)
        self._refresh_ring()
        self._root_client = WtfClient(self, client_id=0)
        self._root_client.mkfs()

    # ----------------------------------------------------------- membership
    def _next_client_id(self) -> int:
        return next(self._client_ids)

    def _refresh_ring(self) -> None:
        from .placement import HashRing

        cfg = self.coordinator.config()
        ring = HashRing(cfg["online"])
        with self._lock:
            self._ring = ring
            self._ring_epoch = cfg["epoch"]

    def fail_server(self, server_id: int) -> None:
        self.servers[server_id].crash()
        self.coordinator.fail_server(server_id)
        self._refresh_ring()

    def recover_server(self, server_id: int) -> None:
        self.servers[server_id].recover()
        self.coordinator.recover_server(server_id)
        self._refresh_ring()

    def client(self) -> WtfClient:
        return WtfClient(self)

    # ------------------------------------------------------------- data I/O
    def store_slice(self, data: bytes, placement_key: Any,
                    hint: int) -> Tuple[SlicePointer, ...]:
        """Create ``replication`` replica slices on distinct servers (§2.9).

        On server failure, falls back to the next servers on the ring — the
        write path never blocks on a single faulty node.
        """
        want = self.replication
        candidates = self._ring.owners(placement_key, len(self.servers))
        ptrs: list[SlicePointer] = []
        for sid in candidates:
            if len(ptrs) == want:
                break
            srv = self.servers[sid]
            try:
                ptrs.append(srv.create_slice(data, locality_hint=hint))
            except StorageError:
                self._on_server_error(sid)
        if len(ptrs) < min(want, 1):
            raise StorageError("no storage server could accept the slice")
        return tuple(ptrs)

    def fetch_slice(self, ptrs: Sequence[SlicePointer]) -> bytes:
        """Read any replica; fail over across them (§2.9)."""
        last: Optional[Exception] = None
        for p in ptrs:
            srv = self.servers.get(p.server_id)
            if srv is None or not srv.alive:
                continue
            try:
                return srv.retrieve_slice(p)
            except StorageError as e:
                last = e
                self._on_server_error(p.server_id)
        raise StorageError(f"all replicas unavailable: {last}")

    def _on_server_error(self, server_id: int) -> None:
        try:
            self.coordinator.fail_server(server_id)
        except Exception:
            pass
        self._refresh_ring()

    # ------------------------------------------------------------- stats
    def total_stats(self) -> dict:
        agg = {
            "kv": self.kv.stats.snapshot(),
            "servers": {sid: s.stats.snapshot()
                        for sid, s in self.servers.items()},
        }
        agg["data_bytes_written"] = sum(
            s["bytes_written"] for s in agg["servers"].values())
        agg["data_bytes_read"] = sum(
            s["bytes_read"] for s in agg["servers"].values())
        return agg

    def reset_io_stats(self) -> None:
        from .storage import StorageStats

        for s in self.servers.values():
            s.stats = StorageStats()

    def close(self) -> None:
        for s in self.servers.values():
            s.close()
