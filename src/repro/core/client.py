"""The WTF client library (paper §2.1–§2.6) — assembly of the layered client.

The client is where metadata (WarpKV) and data (storage servers) combine
into a coherent filesystem.  The implementation is split into layers:

  * ``client_runtime`` — fd table, op logging, the auto-commit retry loop,
    and ``WtfTransaction`` (the §2.6 replay layer);
  * ``slice_ops``      — the data plane (slice planning, batched fetching
    through ``iosched``, write/paste engines) and the file-slicing API
    (Table 1) plus vectored ``yankv``/``pastev``;
  * ``posix_ops``      — the POSIX-style surface with one-lookup open
    (§2.4) plus vectored ``readv``/``preadv``/``writev``/``pwritev``;
  * ``handle``         — ``WtfFile``, the first-class handle returned by
    ``open_file`` (preferred over raw fd juggling);
  * ``iosched``        — the batched slice-fetch scheduler: coalesces
    adjacent slice pointers per (server, backing file) and fans fetches
    out across servers.

This module assembles ``WtfClient`` from those layers and defines
``Cluster``, which wires together the four components of Figure 1.

Writers create slices on storage servers *before* their metadata commits, so
any transaction that can observe a slice pointer can safely dereference it —
the cornerstone invariant of the design (§2.1).
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

# Re-exported for compatibility: these names historically lived here.
from .client_runtime import (SEEK_CUR, SEEK_END, SEEK_SET,  # noqa: F401
                             ClientRuntime, ClientStats, WtfTransaction,
                             basename_of, normalize_path, parent_of)
from .errors import StorageError
from .handle import WtfFile  # noqa: F401  (re-export)
from .blockcache import DEFAULT_BLOCK_CACHE_BYTES, BlockCache
from .inode import DEFAULT_REGION_SIZE, REGION_COMPACT_THRESHOLD
from .iort import HealthTracker, IoRuntime, PlanCache, run_with_failover
from .repair import RepairQueue, RepairStats, RepairTicket
from .iosched import DEFAULT_MAX_GAP, SliceScheduler
from .lease import LeaseHub, LeaseTable
from .mdshard import ShardedKV
from .wsched import DEFAULT_MAX_COALESCE, StoreRequest, WriteScheduler
from .metadata import WarpKV
from .posix_ops import PosixOps
from .slice_ops import SliceOps
from .slicing import ResolvedIndexCache, SlicePointer

GC_DIR = "/.wtf-gc"          # reserved directory for GC live lists (§2.8)


class WtfClient(PosixOps, SliceOps, ClientRuntime):
    """One application's handle on the filesystem.

    Not thread-safe by design: the paper's workloads use one client per
    thread/process; share the ``Cluster`` instead, which is thread-safe.

    Surface (see the layer modules for details):

      * POSIX ops with one-lookup open, plus vectored
        ``readv``/``preadv``/``writev``/``pwritev``;
      * file slicing (``yank``/``paste``/``punch``/``append``/``concat``/
        ``copy``) plus vectored ``yankv``/``pastev``;
      * ``open_file`` returning a ``WtfFile`` context-manager handle;
      * fully general multi-file transactions via ``transaction()`` with
        the §2.6 transparent-replay retry layer.

    Every vectored call executes as ONE logged op in ONE transaction, and
    its slice fetches are batched by the cluster's ``SliceScheduler``.
    """

    def __init__(self, cluster: "Cluster", client_id: Optional[int] = None):
        from .wbuf import WriteBehindBuffer

        self.cluster = cluster
        self.kv: WarpKV = cluster.kv
        self.stats = ClientStats()
        # Leased metadata cache (``lease``): on lease-enabled clusters every
        # transaction this client begins serves reads from (and grants)
        # time/version-bounded leases, and read-only transactions whose
        # whole read set is lease-covered commit with zero KV round trips.
        self._lease_table = (LeaseTable(cluster.lease_hub)
                             if cluster.lease_hub is not None else None)
        self._client_id = (client_id if client_id is not None
                           else cluster._next_client_id())
        self._fd_counter = itertools.count(3)
        self._fds: Dict[int, Any] = {}
        self._id_counter = itertools.count(1)
        self._txn: Optional[WtfTransaction] = None
        # Write-behind: slice stores deferred into ``_wb`` flush in one
        # scheduled pass at the commit boundary (``wbuf``).  The client
        # inherits the cluster knob; ``WtfFile(buffered=True)`` raises
        # ``_op_buffered`` per call for handle-level opt-in.
        self.write_behind = cluster.write_behind
        self._op_buffered = False
        self._wb = WriteBehindBuffer()
        # Read-plan cache (``iort.PlanCache``): hot re-reads skip overlay
        # resolution when the touched regions' KV versions are unchanged —
        # the commutes a commit applies bump them, which is the whole
        # invalidation story.  Per-client by default: validation records
        # the same read dependencies a fresh plan would.  Lease-enabled
        # clusters share ONE cache across all clients under the same rule
        # (hits are version-validated per transaction), with the lease hub
        # evicting an inode's plans when its region metadata changes.
        self._plan_cache = (cluster.shared_plan_cache
                            if cluster.shared_plan_cache is not None
                            else PlanCache())
        # Data-block cache (``blockcache.BlockCache``): hot re-reads skip
        # the storage round entirely.  Same sharing and invalidation rule
        # as the plan cache — cluster-shared on lease clusters, evicted
        # jointly with the inode's plans when a commit (or lease
        # revocation) invalidates them; ``Cluster(block_cache_bytes=0)``
        # disables it.
        if cluster.shared_block_cache is not None:
            self._block_cache = cluster.shared_block_cache
        elif cluster.block_cache_bytes > 0:
            from .blockcache import BlockCache
            self._block_cache = BlockCache(cluster.block_cache_bytes)
        else:
            self._block_cache = None
        # Resolved-region index (``slicing.ResolvedIndexCache``): when a
        # hot region's overlay list grows by k extents, its resolved form
        # is extended in O(k log n) instead of re-resolved over the whole
        # write history.  Per-client, identity-validated (a false hit is
        # impossible); disabled via ``Cluster(resolved_index=False)``.
        self._rcache = (ResolvedIndexCache()
                        if cluster.resolved_index else None)
        self.time_fn: Callable[[], int] = lambda: int(time.time())


class Cluster:
    """Wires together the four components of Figure 1 and owns shared state.

    Thread-safe; create one ``WtfClient`` per worker thread on top of it.
    Owns the ``SliceScheduler`` (one per cluster, shared by all clients) so
    batched fetches from every client share one thread pool and one
    coalescing policy (``fetch_gap_bytes``), and its write-side mirror, the
    ``WriteScheduler`` (``wsched``), which shares the same pool.

    The store pipeline: the client plans every slice creation of an op as a
    ``StoreRequest``; ``store_slices`` groups them by (replica candidate
    servers, backing-file hint), packs runs of small requests (at most
    ``store_coalesce_bytes`` each) into covering stores, issues ONE
    ``create_slices`` round per (group, replica) — concurrently across
    distinct servers — and falls back to the next ring owner on
    ``StorageError`` (§2.9).  ``store_batching=False`` degrades to the
    scalar one-round-per-slice path (same results, more rounds).  Effects
    are measured by ``ClientStats.store_batches`` / ``slices_store_coalesced``
    / ``degraded_stores`` and server-side ``StorageStats.slices_written``.
    """

    def __init__(self, n_servers: int = 4, data_dir: str = "/tmp/wtf",
                 replication: int = 1,
                 region_size: int = DEFAULT_REGION_SIZE,
                 coordinator_replicas: int = 3,
                 num_backing_files: int = 8,
                 fetch_gap_bytes: Optional[int] = None,
                 fetch_workers: Optional[int] = None,
                 store_coalesce_bytes: Optional[int] = None,
                 store_batching: bool = True,
                 write_behind: bool = False,
                 scatter_gather: bool = True,
                 resolved_index: bool = True,
                 readahead: bool = True,
                 block_cache_bytes: int = DEFAULT_BLOCK_CACHE_BYTES,
                 region_compact_threshold: Optional[int] =
                 REGION_COMPACT_THRESHOLD,
                 kv_group_commit: bool = True,
                 n_meta_shards: int = 1,
                 lease_ttl: Optional[float] = None,
                 kv_service_time: float = 0.0,
                 storage_service_time: float = 0.0,
                 io_deadline_s: Optional[float] = None,
                 min_read_replicas: int = 1,
                 strict_replication: bool = False,
                 health_seed: int = 0):
        from .coordinator import ReplicatedCoordinator
        from .placement import HashRing
        from .storage import DEFAULT_READAHEAD_POOL_BYTES, StorageServer
        import os

        # Knob validation up front: a bad threshold or an unachievable
        # replica count must fail HERE, not misbehave mid-op (a negative
        # gap silently disables coalescing; replication > n_servers makes
        # every store degraded from the first write on).
        if n_servers < 1:
            raise ValueError(f"n_servers must be >= 1, got {n_servers}")
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        if replication > n_servers:
            raise ValueError(
                f"replication={replication} exceeds n_servers={n_servers}: "
                f"replicas must land on distinct servers (§2.9)")
        if region_size <= 0:
            raise ValueError(f"region_size must be > 0, got {region_size}")
        if fetch_gap_bytes is not None and fetch_gap_bytes <= 0:
            raise ValueError(
                f"fetch_gap_bytes must be > 0 (or None for adaptive), "
                f"got {fetch_gap_bytes}")
        if store_coalesce_bytes is not None and store_coalesce_bytes <= 0:
            raise ValueError(
                f"store_coalesce_bytes must be > 0 (or None for adaptive), "
                f"got {store_coalesce_bytes}")
        if fetch_workers is not None and fetch_workers < 1:
            raise ValueError(
                f"fetch_workers must be >= 1, got {fetch_workers}")
        if region_compact_threshold is not None \
                and region_compact_threshold < 2:
            raise ValueError(
                f"region_compact_threshold must be >= 2 (or None to "
                f"disable), got {region_compact_threshold}")
        if n_meta_shards < 1:
            raise ValueError(
                f"n_meta_shards must be >= 1, got {n_meta_shards}")
        if lease_ttl is not None and lease_ttl <= 0:
            raise ValueError(
                f"lease_ttl must be > 0 (or None to disable leases), "
                f"got {lease_ttl}")
        if kv_service_time < 0:
            raise ValueError(
                f"kv_service_time must be >= 0, got {kv_service_time}")
        if storage_service_time < 0:
            raise ValueError(
                f"storage_service_time must be >= 0, "
                f"got {storage_service_time}")
        if not isinstance(block_cache_bytes, int) or block_cache_bytes < 0:
            raise ValueError(
                f"block_cache_bytes must be an int >= 0 (0 disables the "
                f"client data-block cache), got {block_cache_bytes!r}")
        if io_deadline_s is not None and io_deadline_s <= 0:
            raise ValueError(
                f"io_deadline_s must be > 0 (or None to disable per-round "
                f"deadlines), got {io_deadline_s}")
        if not 1 <= min_read_replicas <= replication:
            raise ValueError(
                f"min_read_replicas must be in [1, replication="
                f"{replication}], got {min_read_replicas}")

        # Metadata plane: ONE WarpKV by default — the exact single-store
        # fast path — or a ``mdshard.ShardedKV`` partitioning the keyspace
        # across ``n_meta_shards`` independent WarpKV shards, with
        # cross-shard transactions (rare by construction: inode ids are
        # colocated with their paths) going through 2PC.
        self.n_meta_shards = n_meta_shards
        if n_meta_shards == 1:
            self.kv = WarpKV(group_commit=kv_group_commit,
                             service_time_s=kv_service_time)
        else:
            self.kv = ShardedKV(n_meta_shards, group_commit=kv_group_commit,
                                service_time_s=kv_service_time)
        # Leases (``lease``): time/version-bounded client metadata caching.
        # The hub wires revocation (writer-side invalidation barrier + the
        # per-shard WAL subscribe stream) and owns the cluster-shared
        # version-validated plan cache.
        self.lease_ttl = lease_ttl
        # Data-plane read caching knobs: server-side readahead pools and
        # the client block cache share the plan cache's invalidation rule
        # (see ``blockcache``); each has an off position so benchmarks can
        # isolate its contribution.
        self.readahead = readahead
        self.block_cache_bytes = block_cache_bytes
        if lease_ttl is not None:
            self.shared_plan_cache = PlanCache()
            self.shared_block_cache = (BlockCache(block_cache_bytes)
                                       if block_cache_bytes > 0 else None)
            self.lease_hub = LeaseHub(self.kv, ttl=lease_ttl,
                                      plan_cache=self.shared_plan_cache,
                                      block_cache=self.shared_block_cache)
        else:
            self.shared_plan_cache = None
            self.shared_block_cache = None
            self.lease_hub = None
        # Metadata-plane fast-path knobs (all default on; each has an off
        # position so benchmarks/tests can compare like for like):
        #   scatter_gather — one retrieve_slices round per (server,
        #     backing file) fetch group instead of one per coalesced run;
        #   resolved_index — per-client delta-maintained region overlays;
        #   region_compact_threshold — commit-time CompactRegion trigger.
        self.scatter_gather = scatter_gather
        self.resolved_index = resolved_index
        self.region_compact_threshold = region_compact_threshold
        self.region_size = region_size
        self.replication = replication
        self.coordinator = ReplicatedCoordinator(coordinator_replicas)
        self.servers: Dict[int, Any] = {}
        self._ring = HashRing()
        self._ring_epoch = -1
        # Memoized ring walks for the scalar store path: every append to
        # the same region re-derives the same owner list, and the walk
        # was measurable GIL-held time under many appenders.  Cleared on
        # every ring refresh (stale reads race exactly like ``_ring``
        # itself and are caught by the per-store failover walk).
        self._owners_cache: Dict[Any, List[int]] = {}
        self._lock = threading.Lock()
        self._client_ids = itertools.count(1)
        for sid in range(n_servers):
            root = os.path.join(data_dir, f"server_{sid:03d}")
            srv = StorageServer(sid, root,
                                num_backing_files=num_backing_files,
                                service_time_s=storage_service_time,
                                readahead_pool_bytes=(
                                    DEFAULT_READAHEAD_POOL_BYTES
                                    if readahead else 0))
            self.servers[sid] = srv
            self.coordinator.register_server(sid, root)
        self._refresh_ring()
        # The unified async I/O runtime (``iort``): the ONE thread pool and
        # submission queue both scheduler strategy layers and the async
        # client surface execute on, plus the adaptive-threshold cost
        # model.  Explicit gap/coalesce knobs pin the thresholds; None
        # (the default) sizes them from observed round-trip cost.
        self.runtime = IoRuntime(
            max_workers=(fetch_workers if fetch_workers is not None
                         else min(8, max(1, n_servers))),
            gap_override=fetch_gap_bytes,
            coalesce_override=store_coalesce_bytes)
        if readahead:
            # Readahead windows size themselves from the same EWMA cost
            # model as adaptive coalescing (the bytes one round trip is
            # worth); wire it now that the runtime exists.
            for srv in self.servers.values():
                srv.readahead_window = self.runtime.readahead_bytes
        self.scheduler = SliceScheduler(self, self.runtime)
        self.store_batching = store_batching
        # Write-behind (opt-in): clients defer slice stores into a
        # transaction-scoped buffer and flush them through ``wsched`` as
        # ONE planning pass at each commit boundary — cross-op chunks in a
        # region coalesce into covering stores, regions fan out in
        # parallel, and metadata commits only after every slice is durable
        # (§2.1).  Measured by ``ClientStats.writeback_flushes`` /
        # ``slices_cross_op_coalesced``.
        self.write_behind = write_behind
        self.wsched = WriteScheduler(self, self.runtime)
        self.degraded_stores = 0     # replica sets that came up short (§2.9)
        # Failure domain (§2.9 + the repair plane):
        #   health    — per-server circuit breaker + latency EWMA consulted
        #               by every failover walk, so dead servers are skipped
        #               up front instead of paying a failed round each time;
        #   io_deadline_s — per-replica-round budget; with it set, slow
        #               rounds get ONE hedged retry on the next replica
        #               before the deadline abandons them;
        #   min_read_replicas — reads that find fewer live replicas raise
        #               typed ``DegradedRead`` instead of silently serving;
        #   strict_replication — writes that achieve fewer than
        #               ``replication`` replicas raise instead of degrading
        #               (either way a repair ticket is queued first);
        #   repair_queue/repair_stats — cluster-owned so degrade sites can
        #               file tickets and ``total_stats`` reports them even
        #               before any ``repair.RepairDaemon`` is attached.
        self.io_deadline_s = io_deadline_s
        self.min_read_replicas = min_read_replicas
        self.strict_replication = strict_replication
        self.health = HealthTracker(seed=health_seed)
        self.repair_stats = RepairStats()
        self.repair_queue = RepairQueue(self.repair_stats)
        self._repair_daemon: Optional[Any] = None
        self._closed = False
        self._root_client = WtfClient(self, client_id=0)
        self._root_client.mkfs()

    # ----------------------------------------------------------- membership
    def _next_client_id(self) -> int:
        return next(self._client_ids)

    def _refresh_ring(self) -> None:
        from .placement import HashRing

        cfg = self.coordinator.config()
        ring = HashRing(cfg["online"])
        self._owners_cache.clear()
        with self._lock:
            self._ring = ring
            self._ring_epoch = cfg["epoch"]

    def fail_server(self, server_id: int) -> None:
        self.servers[server_id].crash()
        self.coordinator.fail_server(server_id)
        self._refresh_ring()

    def recover_server(self, server_id: int) -> None:
        self.servers[server_id].recover()
        self.coordinator.recover_server(server_id)
        # Forget the circuit-breaker history: a recovered server serves
        # immediately instead of waiting out its pre-crash backoff.
        self.health.reset(server_id)
        self._refresh_ring()

    def client(self) -> WtfClient:
        return WtfClient(self)

    # ------------------------------------------------------------- data I/O
    def store_slice(self, data: bytes, placement_key: Any,
                    hint: int) -> Tuple[SlicePointer, ...]:
        """Create ``replication`` replica slices on distinct servers (§2.9).

        On server failure, falls back to the next servers on the ring — the
        write path never blocks on a single faulty node.
        """
        want = self.replication
        candidates = self._owners_cache.get(placement_key)
        if candidates is None:
            candidates = self._ring.owners(placement_key, len(self.servers))
            self._owners_cache[placement_key] = candidates
        ptrs: list[SlicePointer] = []
        for sid in candidates:
            if len(ptrs) == want:
                break
            srv = self.servers.get(sid)
            if srv is None or not srv.alive or not self.health.allow(sid):
                continue
            t0 = time.perf_counter()
            try:
                ptrs.append(srv.create_slice(data, locality_hint=hint))
            except StorageError:
                self.health.record_failure(sid)
                self._on_server_error(sid)
            else:
                self.health.record_success(sid, time.perf_counter() - t0)
        if not ptrs:
            raise StorageError("no storage server could accept the slice")
        if len(ptrs) < want:
            # Under-replicated, not failed: the write stays available, but
            # the shortfall must never be silent (§2.9) — count it AND file
            # a repair ticket carrying the extent identity, so the repair
            # plane can re-replicate without a full metadata scan.
            self.note_degraded_stores(1)
            self.enqueue_repair(placement_key, ptrs=ptrs)
            if self.strict_replication:
                raise StorageError(
                    f"strict_replication: achieved {len(ptrs)}/{want} "
                    f"replicas for {placement_key!r}")
        return tuple(ptrs)

    def store_slices(self, requests: Sequence[StoreRequest],
                     stats=None) -> dict:
        """Batched stores through the write scheduler (see class docstring);
        ``store_batching=False`` falls back to one scalar round per request
        so benchmarks/tests can compare the two pipelines like for like."""
        if self.store_batching:
            return self.wsched.store_many(requests, stats=stats)
        out = {}
        for r in requests:
            ptrs = self.store_slice(r.data, r.placement_key, r.hint)
            out[r.key] = ptrs
            if stats is not None:
                stats.add(store_batches=len(ptrs),
                          data_bytes_written=len(r.data) * len(ptrs),
                          degraded_stores=(1 if len(ptrs) < self.replication
                                           else 0))
        return out

    def release_slices(self, ptrs: Sequence[SlicePointer]) -> None:
        """End-of-transaction ACK for the tier-3 GC handoff window: the
        transaction that created ``ptrs`` has committed or finally
        aborted, so the servers may stop shielding those ranges from the
        sparse rewrite (§2.8).  Safe to call with foreign/stale pointers;
        releasing twice is a no-op."""
        by_server: dict[int, list[SlicePointer]] = {}
        for p in ptrs:
            by_server.setdefault(p.server_id, []).append(p)
        for sid, plist in by_server.items():
            srv = self.servers.get(sid)
            if srv is not None:
                srv.release_slices(plist)

    def note_degraded_stores(self, n: int) -> None:
        with self._lock:
            self.degraded_stores += n

    def enqueue_repair(self, placement_key: Any,
                       ptrs: Optional[Sequence[SlicePointer]] = None,
                       reason: str = "degraded-store") -> None:
        """File a repair ticket for a store that came up short.  The
        placement key carries the (inode, region) identity; keys the
        parser does not recognize are counted and left to the periodic
        under-replication scan."""
        self.repair_queue.put_from_placement(placement_key, ptrs, reason)

    def note_failed_retrieve(self, inode_id: int) -> None:
        """File a repair ticket for a read that had to fail over past a
        dead replica: the read path knows the inode but not which region
        the extent belongs to, so the ticket covers the whole inode."""
        self.repair_queue.put(RepairTicket(inode_id=inode_id,
                                           reason="failed-retrieve"))

    def fetch_slice(self, ptrs: Sequence[SlicePointer]) -> bytes:
        """Read any replica; fail over across them via the runtime's
        unified candidate walk (§2.9)."""
        return run_with_failover(
            self, [(p.server_id, p) for p in ptrs],
            lambda srv, p: srv.retrieve_slice(p))

    def _on_server_error(self, server_id: int) -> None:
        try:
            self.coordinator.fail_server(server_id)
        except Exception:
            pass
        self._refresh_ring()

    # ------------------------------------------------------------- stats
    def total_stats(self) -> dict:
        agg = {
            "kv": self.kv.stats.snapshot(),
            "servers": {sid: s.stats.snapshot()
                        for sid, s in self.servers.items()},
        }
        agg["data_bytes_written"] = sum(
            s["bytes_written"] for s in agg["servers"].values())
        agg["data_bytes_read"] = sum(
            s["bytes_read"] for s in agg["servers"].values())
        agg["slices_read"] = sum(
            s["slices_read"] for s in agg["servers"].values())
        agg["slices_written"] = sum(
            s["slices_written"] for s in agg["servers"].values())
        agg["append_lock_wait_s"] = sum(
            s["append_lock_wait_s"] for s in agg["servers"].values())
        agg["degraded_stores"] = self.degraded_stores
        agg["io_runtime"] = self.runtime.snapshot()
        agg["io_health"] = self.health.snapshot()
        agg["repair"] = self.repair_stats.snapshot()
        agg["repair"]["tickets_pending"] = len(self.repair_queue)
        # Sharded metadata plane: per-shard KVStats plus the 2PC
        # coordinator's counters (each snapshot is atomic, like the
        # ``io_runtime`` section; the top-level "kv" stays the aggregate).
        kv = self.kv
        if isinstance(kv, ShardedKV):
            agg["kv_shards"] = [sh.stats.snapshot() for sh in kv.shards]
            agg["mdshard"] = kv.stats_2pc.snapshot()
        if self.lease_hub is not None:
            agg["leases"] = self.lease_hub.stats.snapshot()
        return agg

    def reset_io_stats(self) -> None:
        from .storage import StorageStats

        for s in self.servers.values():
            s.stats = StorageStats()
        with self._lock:
            self.degraded_stores = 0

    def close(self) -> None:
        """Idempotent teardown: repair daemon first (it drives the runtime
        and the servers), then the runtime (every in-flight async future
        resolves and all pool threads exit), then the servers."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        daemon = self._repair_daemon
        if daemon is not None:
            daemon.stop()
        self.runtime.close()
        for s in self.servers.values():
            s.close()
