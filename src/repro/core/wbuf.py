"""Transaction-scoped write-behind buffer — deferred slice stores.

PR 2's write scheduler batches the stores *within* one vectored op; this
module batches them *across* ops under one commit point.  CannyFS
(arXiv 1612.06830) and DurableFS (arXiv 1811.00757) both argue the same
bargain: inside a transaction nothing is application-visible until commit,
so there is no reason to pay a storage round per write op — record the
payloads, and make every store at the commit boundary in one scheduled pass.

Mechanics:

  * While write-behind is active, ``_data_slice``/``_data_slices`` call
    ``WriteBehindBuffer.add`` instead of ``Cluster.store_slice(s)``.  The
    buffer returns an ``Extent`` whose pointer is a ``PendingPtr`` — a
    placeholder that is duck-compatible with ``SlicePointer`` for all the
    *metadata* arithmetic (``sub``, offsets, adjacency checks) but carries
    the payload bytes instead of a storage location.  Op bodies queue these
    extents into region lists exactly as they would real ones.
  * Reads inside the same transaction observe buffered writes: the plan /
    overlay path produces pending extents wherever a buffered write is the
    visible layer, and the client's fetch engine serves them from the
    buffer's memory instead of the slice scheduler (read-your-buffered-
    writes).
  * ``flush`` runs at the commit boundary, BEFORE the metadata commit: all
    pending payloads become ``StoreRequest``s and go through ``wsched`` as
    ONE planning pass — requests from *different ops* that share a region
    placement group coalesce into covering stores
    (``ClientStats.slices_cross_op_coalesced``) and distinct regions fan
    out across the ring in parallel.  Once every slice is durable, every
    recorded ``PendingPtr`` is resolved to its real replicated pointers
    (queued commutes, op artifacts, op digests), preserving the
    slices-before-metadata invariant (§2.1) — and the §2.6 replay layer
    then reuses the recorded batch pointers verbatim, never re-storing.
  * ``clear`` (transaction abort) discards the buffer: no store was ever
    dispatched, so an aborted transaction leaves zero storage garbage.

A known, safe sharpening of §2.6 semantics: a ``yank`` inside a buffered
transaction observes *pending* pointer structure; if the transaction
replays, the re-planned (now real, possibly better-merged) extents may
digest differently and abort to the application.  That is a spurious abort
(availability), never an inconsistency.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

from .errors import WtfError
from .slicing import Extent, SlicePointer
from .wsched import StoreRequest


class _PendingSlice:
    """One deferred slice creation: payload + placement, resolved to real
    replicated pointers at flush time (``ptrs`` is None until then)."""

    __slots__ = ("data", "placement_key", "hint", "op_tag", "ptrs")

    def __init__(self, data: bytes, placement_key: Any, hint: int,
                 op_tag: Any):
        self.data = data
        self.placement_key = placement_key
        self.hint = hint
        self.op_tag = op_tag
        self.ptrs: Optional[Tuple[SlicePointer, ...]] = None


class PendingPtr:
    """Placeholder pointer into a not-yet-stored slice.

    Duck-compatible with ``SlicePointer`` for metadata arithmetic:
    ``sub`` derives sub-ranges, ``offset``/``length`` locate the bytes
    within the pending payload, and ``server_id`` is a sentinel (-1) so a
    pending pointer never compares adjacent/equal to a real one —
    ``merge_adjacent`` must not fuse pending pointers into fake
    ``SlicePointer`` arithmetic.
    """

    __slots__ = ("cell", "offset", "length")

    backing_file = "<write-behind>"
    server_id = -1                      # never a real ring member

    def __init__(self, cell: _PendingSlice, offset: int, length: int):
        self.cell = cell
        self.offset = offset
        self.length = length

    def sub(self, start: int, length: int) -> "PendingPtr":
        if start < 0 or length < 0 or start + length > self.length:
            raise ValueError(
                f"sub-slice [{start},{start + length}) out of bounds "
                f"for pending slice of length {self.length}")
        return PendingPtr(self.cell, self.offset + start, length)

    def is_adjacent(self, other) -> bool:
        return False                    # pending pointers never merge

    # ------------------------------------------------------------- payload
    def data(self) -> bytes:
        return self.cell.data[self.offset:self.offset + self.length]

    @property
    def resolved(self) -> bool:
        return self.cell.ptrs is not None

    def real_ptrs(self) -> Tuple[SlicePointer, ...]:
        """Per-replica real pointers for this sub-range (post-flush)."""
        if self.cell.ptrs is None:
            raise WtfError("pending slice pointer dereferenced before flush")
        return tuple(p.sub(self.offset, self.length) for p in self.cell.ptrs)

    def __repr__(self) -> str:
        state = "resolved" if self.resolved else "pending"
        return f"<PendingPtr {state} +{self.offset}:{self.length}>"


# ----------------------------------------------------------- extent helpers
def extent_is_pending(e: Extent) -> bool:
    return any(isinstance(p, PendingPtr) for p in e.ptrs)


def extent_is_resolved(e: Extent) -> bool:
    return all(p.resolved for p in e.ptrs if isinstance(p, PendingPtr))


def pending_extent_bytes(e: Extent) -> bytes:
    """Serve a pending extent's bytes straight from the buffered payload."""
    for p in e.ptrs:
        if isinstance(p, PendingPtr):
            return p.data()
    raise WtfError("extent has no pending pointer")


def resolve_extent(e: Extent) -> Extent:
    """Swap every pending pointer for its real replicated pointers."""
    if not extent_is_pending(e):
        return e
    ptrs: List[SlicePointer] = []
    for p in e.ptrs:
        if isinstance(p, PendingPtr):
            ptrs.extend(p.real_ptrs())
        else:
            ptrs.append(p)
    return Extent(e.offset, e.length, tuple(ptrs))


def resolve_value(v: Any) -> Any:
    """Recursively resolve pending extents inside op artifacts/digests."""
    if isinstance(v, Extent):
        return resolve_extent(v)
    if isinstance(v, tuple):
        return tuple(resolve_value(x) for x in v)
    if isinstance(v, list):
        return [resolve_value(x) for x in v]
    if isinstance(v, dict):
        return {k: resolve_value(x) for k, x in v.items()}
    return v


class WriteBehindBuffer:
    """Per-client accumulator of deferred stores (one commit scope at a
    time: either the open ``WtfTransaction`` or the current auto-commit op,
    matching the client's not-thread-safe contract)."""

    __slots__ = ("_slices", "_live")

    def __init__(self):
        self._slices: List[_PendingSlice] = []
        self._live: set = set()          # id(cell) of every live cell

    @property
    def pending(self) -> bool:
        return bool(self._slices)

    def __len__(self) -> int:
        return len(self._slices)

    def add(self, placement_key: Any, hint: int, data: bytes,
            op_tag: Any) -> Extent:
        """Record one deferred slice; returns the placeholder extent the op
        body queues into region metadata."""
        cell = _PendingSlice(bytes(data), placement_key, hint, op_tag)
        self._slices.append(cell)
        self._live.add(id(cell))
        return Extent(0, len(cell.data), (PendingPtr(cell, 0,
                                                     len(cell.data)),))

    def owns(self, e: Extent) -> bool:
        """True iff every unresolved pending pointer in ``e`` references a
        cell of THIS buffer's current commit scope — a dead pointer from an
        aborted scope must be rejected at the call site, not at flush."""
        return all(id(p.cell) in self._live for p in e.ptrs
                   if isinstance(p, PendingPtr) and not p.resolved)

    def flush(self, cluster, stats=None) -> int:
        """Store every pending payload through the write scheduler as ONE
        planning pass and resolve the cells.  All data is durable before
        this returns; the caller then rewrites queued metadata with the
        real pointers and commits (§2.1 order).  Raises ``StorageError``
        if any slice achieved zero replicas — the commit must not proceed.
        """
        if not self._slices:
            return 0
        requests = [StoreRequest(i, c.data, c.placement_key, c.hint,
                                 op_tag=c.op_tag)
                    for i, c in enumerate(self._slices)]
        ptrs = cluster.store_slices(requests, stats=stats)
        for i, cell in enumerate(self._slices):
            cell.ptrs = ptrs[i]
        n = len(self._slices)
        if stats is not None:
            stats.add(writeback_flushes=1)
        # Cells stay alive through any PendingPtr the application still
        # holds (e.g. yanked extents); the buffer itself is spent.
        self._slices = []
        self._live = set()
        return n

    def clear(self) -> None:
        """Abort path: drop the pending payloads.  Nothing was ever sent to
        a storage server, so there is no garbage to reclaim."""
        self._slices = []
        self._live = set()
