"""Sharded metadata plane: N independent WarpKV shards + cross-shard 2PC.

Every transaction used to funnel through ONE ``WarpKV`` — one commit mutex,
one WAL, one subscribe stream — the hard ceiling on metadata throughput no
matter how fast the single-store path is.  The WTF paper itself runs
against a HyperDex Warp *ensemble*, not a single node; this module is the
in-process stand-in for that ensemble.

``ShardedKV`` partitions the keyspace across ``n_shards`` full ``WarpKV``
instances, each keeping its own group commit, stripe locks, bounded WAL and
version-preserving compaction:

  * ``inodes`` and ``regions`` keys route by ``inode_id % n_shards`` — an
    inode and ALL its region metadata live on one shard;
  * everything else (``paths``, auxiliary spaces) routes by stable hash;
  * ``colocated_inode_id`` biases inode-id allocation so an inode lands on
    the same shard as its path, making the hot per-file transactions
    (open/read/write/append on one file) **single-shard by construction**.

Single-shard commits are handed verbatim to that shard's ``_commit`` — the
exact group-commit fast path, zero new overhead, no 2PC counters touched.

The rare transaction whose footprint spans shards (namespace ops touching a
parent directory on another shard, multi-file transactions) runs two-phase
commit, built from the shard-local hooks ``lock_keys`` /
``_validate_and_stage`` / ``_apply_staged``:

  prepare  — per touched shard, in ascending shard order: acquire that
             shard's stripe locks (canonical sorted order), validate read
             versions + commutative preconditions, stage results.  Any
             failure releases everything; no shard has been mutated, so
             nothing is ever visible (all-or-nothing trivially holds).
  decide   — the commit point.  A coordinator crash here resolves either
             way (``PhaseCrash``): "abort" rolls back exactly like a
             prepare failure; "commit" means the decision record survived,
             so the coordinator rolls FORWARD and applies everywhere.
  apply    — per shard, ``_apply_staged`` (cannot fail — everything was
             validated under locks that are still held).

Deadlock freedom: every committer — group-commit leaders within a shard and
2PC coordinators across shards — acquires stripes in the global
(shard index, stripe id) order.

``subscribe`` keeps the single totally-ordered event stream consumers
expect: each subscriber gets per-shard forwarders serialized through one
reentrant lock (replay shard 0..N-1, then live events in a total order that
preserves each shard's commit order), with per-shard sequence numbers
available via ``with_meta=True``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, List

from .errors import KVConflict
from .iort import AtomicStatsMixin
from .metadata import Transaction, WarpKV
from .placement import stable_hash
from .testing import witness_lock


class PhaseCrash(Exception):
    """Injected coordinator crash at the 2PC commit point (testing).

    ``resolution`` is what the recovery protocol would read back from the
    (modeled) decision record: "abort" → roll back everywhere, surface a
    retryable ``KVConflict``; "commit" → the decision was durable, roll
    forward and complete the commit as if nothing happened.
    """

    def __init__(self, resolution: str = "abort"):
        super().__init__(f"injected coordinator crash (resolution={resolution})")
        self.resolution = resolution


@dataclass(slots=True)
class MdShardStats(AtomicStatsMixin):
    """2PC coordinator counters (cluster-level, not per shard)."""

    single_shard_commits: int = 0    # routed straight to one shard
    cross_shard_commits: int = 0     # committed through 2PC
    prepare_aborts: int = 0          # 2PC aborted before the commit point
    recovered_commits: int = 0       # crash at decide resolved as commit
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class _TxnPart:
    """One shard's slice of a cross-shard transaction — duck-typed to what
    ``WarpKV._validate_and_stage`` / ``_apply_staged`` read."""

    __slots__ = ("_reads", "_writes", "_commutes")

    def __init__(self):
        self._reads: dict = {}
        self._writes: dict = {}
        self._commutes: list = []

    def touched(self) -> set:
        t = set(self._reads) | set(self._writes)
        t.update((s, k) for s, k, _, _ in self._commutes)
        return t


class _AggKVStats:
    """Read-only aggregated view over every shard's ``KVStats`` so
    ``cluster.kv.stats.commits`` / ``.snapshot()`` keep working unchanged
    on a sharded cluster.  A cross-shard commit counts once per shard it
    applied on; per-shard truth is in ``ShardedKV.shards[i].stats``."""

    def __init__(self, shards: List[WarpKV]):
        self._shards = shards

    def snapshot(self) -> dict:
        out: dict = {}
        for sh in self._shards:
            for name, v in sh.stats.snapshot().items():
                out[name] = out.get(name, 0) + v
        return out

    def add(self, **counts) -> None:
        """Attribute the increment to shard 0 (callers that bump counters
        through the aggregate — e.g. FlakyKV's injected aborts — don't
        belong to any particular shard; sums stay correct)."""
        self._shards[0].stats.add(**counts)

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return sum(getattr(sh.stats, name) for sh in self._shards)


class ShardedKV:
    """Drop-in ``WarpKV`` replacement routing over N real shards."""

    def __init__(self, n_shards: int, group_commit: bool = True,
                 service_time_s: float = 0.0):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.group_commit = group_commit
        self.shards: List[WarpKV] = [
            WarpKV(group_commit=group_commit, service_time_s=service_time_s,
                   shard_index=i)
            for i in range(n_shards)]
        self.stats_2pc = MdShardStats()
        self._fail_next_commits = 0

    # -- routing ------------------------------------------------------------
    def shard_index(self, space: str, key: Any) -> int:
        """Shard owning ``space:key``.  Inode-keyed spaces route by inode id
        so an inode and its regions are always colocated; other spaces by
        content-stable hash (deterministic across processes)."""
        if space == "inodes":
            return key % self.n_shards
        if space == "regions":
            return key[0] % self.n_shards
        return stable_hash(space, key, salt="mdshard") % self.n_shards

    def colocated_inode_id(self, path: str, raw_id: int) -> int:
        """Stretch a unique raw id onto the shard of ``path`` so the file's
        inode/regions join its path entry — the hot open/read/write
        transactions then touch exactly one shard."""
        return raw_id * self.n_shards + self.shard_index("paths", path)

    def _shard(self, space: str, key: Any) -> WarpKV:
        return self.shards[self.shard_index(space, key)]

    # -- WarpKV surface -----------------------------------------------------
    @property
    def stats(self) -> _AggKVStats:
        return _AggKVStats(self.shards)

    def _read_versioned(self, space: str, key: Any) -> tuple:
        return self._shard(space, key)._read_versioned(space, key)

    def get(self, space: str, key: Any, default: Any = None) -> Any:
        return self._shard(space, key).get(space, key, default)

    def put(self, space: str, key: Any, value: Any) -> None:
        txn = self.begin()
        txn.put(space, key, value)
        txn.commit()

    def keys(self, space: str) -> list:
        """Shard-aware walk: each shard's keys in shard order (the GC
        scanner's deterministic iteration across the whole plane)."""
        out: list = []
        for sh in self.shards:
            out.extend(sh.keys(space))
        return out

    def begin(self) -> Transaction:
        return Transaction(self)

    def add_invalidation_listener(self, fn: Callable[[list], None]) -> None:
        for sh in self.shards:
            sh.add_invalidation_listener(fn)

    def inject_aborts(self, n: int = 1) -> None:
        self._fail_next_commits = n

    # -- replication / subscribe fan-in -------------------------------------
    def subscribe(self, fn: Callable, with_meta: bool = False
                  ) -> Callable[[], None]:
        """Single totally-ordered stream over all shards.

        Replay delivers shard 0's compacted snapshot + tail, then shard
        1's, … — deterministic.  Live events from all shards serialize
        through one per-subscriber reentrant lock (reentrant because a
        listener may itself commit, re-entering the stream on the same
        thread), preserving each shard's commit order within the total
        order.  ``with_meta=True`` delivers ``fn(space, key, value,
        version, shard, seq)`` where ``seq`` is that shard's 1-based,
        gap-free sequence number for this subscriber.

        Returns a zero-argument cancel callable that detaches every
        per-shard forwarder (mirrors ``WarpKV.subscribe``).
        """
        sub_lock = witness_lock(threading.RLock(), "sub.fanin")
        seqs = [0] * self.n_shards

        def forwarder(i: int) -> Callable:
            def forward(space, key, value, version):
                with sub_lock:
                    seqs[i] += 1
                    if with_meta:
                        fn(space, key, value, version, i, seqs[i])
                    else:
                        fn(space, key, value, version)
            return forward

        cancels = [sh.subscribe(forwarder(i))
                   for i, sh in enumerate(self.shards)]

        def cancel() -> None:
            for c in cancels:
                c()

        return cancel

    def wal_entries(self) -> int:
        return sum(sh.wal_entries() for sh in self.shards)

    # -- commit routing -----------------------------------------------------
    def _commit(self, txn) -> None:
        if self._fail_next_commits > 0:
            self._fail_next_commits -= 1
            self.shards[0].stats.add(aborts=1)
            raise KVConflict("injected abort")
        touched_shards: set[int] = set()
        for space, key in txn._reads:
            touched_shards.add(self.shard_index(space, key))
        for space, key in txn._writes:
            touched_shards.add(self.shard_index(space, key))
        for space, key, _, _ in txn._commutes:
            touched_shards.add(self.shard_index(space, key))
        if len(touched_shards) <= 1:
            # The PR 5 fast path, verbatim: group commit, stripe locks,
            # leader/follower batching — all inside the owning shard.
            idx = touched_shards.pop() if touched_shards else 0
            self.stats_2pc.add(single_shard_commits=1)
            self.shards[idx]._commit(txn)
            return
        self._commit_cross(txn, touched_shards)

    def _commit_cross(self, txn, touched_shards: set[int]) -> None:
        """Two-phase commit across ``touched_shards`` (ascending order)."""
        parts: dict[int, _TxnPart] = {i: _TxnPart()
                                      for i in sorted(touched_shards)}
        for sk, ver in txn._reads.items():
            parts[self.shard_index(*sk)]._reads[sk] = ver
        for sk, val in txn._writes.items():
            parts[self.shard_index(*sk)]._writes[sk] = val
        for entry in txn._commutes:
            parts[self.shard_index(entry[0], entry[1])]._commutes.append(
                entry)
        hook = getattr(txn, "_phase_hook", None)

        held: list[tuple[WarpKV, list]] = []
        staged_all: list[tuple[WarpKV, _TxnPart, list]] = []
        try:
            try:
                pos = 0
                for idx in sorted(parts):
                    pos += 1
                    if hook is not None:
                        hook("prepare", pos)
                    shard = self.shards[idx]
                    part = parts[idx]
                    shard._service_delay()      # prepare round trip
                    held.append((shard, shard.lock_keys(part.touched())))
                    staged_all.append(
                        (shard, part, shard._validate_and_stage(part)))
                if hook is not None:
                    hook("decide", 0)           # the commit point
            except PhaseCrash as crash:
                if crash.resolution == "commit" \
                        and len(staged_all) == len(parts):
                    # Decision record survived the crash: roll forward.
                    self._apply_all(staged_all)
                    self.stats_2pc.add(cross_shard_commits=1,
                                       recovered_commits=1)
                    return
                self.stats_2pc.add(prepare_aborts=1)
                raise KVConflict(
                    "2PC coordinator crashed before commit decision; "
                    "resolved as abort") from crash
            except BaseException:
                # Prepare failed on some shard: nothing was applied
                # anywhere, so releasing the locks IS the rollback.
                self.stats_2pc.add(prepare_aborts=1)
                raise
            self._apply_all(staged_all)
            self.stats_2pc.add(cross_shard_commits=1)
        finally:
            for shard, stripe_ids in reversed(held):
                shard.unlock_keys(stripe_ids)

    def _apply_all(self, staged_all) -> None:
        for shard, part, staged in staged_all:
            shard._service_delay()              # apply round trip
            shard._apply_staged(part, staged)
