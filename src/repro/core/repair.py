"""Background repair plane: re-replication after server loss (§2.9 healing).

Before this module the crash story stopped at *degrade*: a failed server
left every extent it hosted under-replicated forever, ``degraded_stores``
counted the damage, and nothing healed it.  This module closes the
crash → detect → degrade → **repair** loop:

  * **Tickets, not scans, find the damage.**  Every degrade site (a store
    that achieved fewer than ``replication`` replicas, a read that failed
    over past a dead replica) enqueues a :class:`RepairTicket` naming the
    affected ``(inode, region)`` — the identity was always in the
    placement key (``placement.region_placement_key``), it just used to be
    thrown away.  The queue dedups by region, so a hot region under a
    write storm costs one ticket, and the daemon never needs a full
    metadata walk to find fresh damage.
  * **A periodic under-replication scan backstops the tickets.**  Walking
    region metadata shard-by-shard exactly like ``gc.GarbageCollector``
    does, the scan catches damage that predates the queue (a server that
    died silently between workloads) and re-verifies after repair.
  * **Repair is a normal commuting commit.**  For each under-replicated
    extent the daemon fetches the bytes from a surviving replica,
    re-replicates onto ring successors via ``create_slices`` (same
    placement key and locality hint the original writer used), and commits
    the new replica set through :class:`inode.ReplaceExtentPtrs` — no read
    dependency, so repair NEVER aborts a concurrent appender, and entries
    that changed under the scan are simply left for the next pass.
  * **Pointer canonicalization stays stable where it can.**  Surviving
    replicas keep their order, so when replica 0 survived the canonical
    first pointer — the PR 9 ``BlockCache`` key — is unchanged and hot
    cached blocks stay addressable.  When replica 0 is the casualty the
    canonical pointer must change; the daemon then drops the inode from
    the cluster-shared plan/block caches (per-client plan caches are
    version-validated and the ``ReplaceExtentPtrs`` version bump already
    invalidates them; per-client block caches keyed on the dead pointer
    only ever serve the immutable bytes that pointer named, so they stay
    correct and merely age out).

The daemon is deliberately a *client* of the existing machinery: it walks
metadata through ordinary transactions, stores through the ordinary
server API, and observes the create→commit GC shield (``release_slices``)
exactly like ``gc.compact_region`` does.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .errors import KVConflict, PreconditionFailed, StorageError
from .inode import RegionData, ReplaceExtentPtrs, region_key
from .iort import AtomicStatsMixin
from .placement import region_placement_key, stable_hash
from .slicing import SlicePointer
from .testing import witness_lock


@dataclass(frozen=True, slots=True)
class RepairTicket:
    """One unit of suspected damage.

    ``region_idx=None`` means "every region of this inode" (a failed
    retrieve knows the inode but not which region the extent came from).
    ``ptrs`` is advisory — the replica set observed at degrade time; repair
    always re-reads the authoritative region metadata before acting.
    """

    inode_id: int
    region_idx: Optional[int] = None
    ptrs: Optional[Tuple[SlicePointer, ...]] = None
    reason: str = "degraded-store"


def ticket_from_placement(placement_key: Any,
                          ptrs: Optional[Sequence[SlicePointer]] = None,
                          reason: str = "degraded-store"
                          ) -> Optional[RepairTicket]:
    """Parse a store-path placement key into a ticket.

    Region writes (``("region", inode, idx)``) and GC spills
    (``("gc-spill", inode, idx)``) both carry the (inode, region) identity;
    anything else (fixture keys in tests) yields ``None`` and the periodic
    scan remains the safety net.
    """
    if (isinstance(placement_key, tuple) and len(placement_key) == 3
            and placement_key[0] in ("region", "gc-spill")):
        return RepairTicket(inode_id=placement_key[1],
                            region_idx=placement_key[2],
                            ptrs=tuple(ptrs) if ptrs else None,
                            reason=reason)
    return None


@dataclass(slots=True)
class RepairStats(AtomicStatsMixin):
    """Repair-plane accounting (surfaced via ``Cluster.total_stats()``)."""

    tickets_enqueued: int = 0        # tickets accepted into the queue
    tickets_deduped: int = 0         # tickets folded into a queued one
    tickets_unparsed: int = 0        # degrade sites with no (inode, region)
    tickets_processed: int = 0       # tickets consumed by repair passes
    scan_passes: int = 0             # full under-replication scans run
    regions_examined: int = 0
    extents_repaired: int = 0        # entries whose replica set was healed
    replicas_created: int = 0        # fresh replica slices stored
    bytes_recopied: int = 0          # bytes fetched + re-stored for repair
    unrepairable: int = 0            # visible extents with zero live copies
    repair_conflicts: int = 0        # commits lost to a concurrent writer
    cache_drops: int = 0             # inode evictions (canonical ptr moved)
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class RepairQueue:
    """Deduplicating ticket intake between the degrade sites and the daemon.

    Thread-safe: stores degrade on runtime pool threads while the daemon
    drains on its own.  Guarded by the ``repair.queue`` lock (ranked
    outermost in ``analysis.lockspec``); ``drain`` copies tickets out and
    releases before the caller touches any metadata or storage lock.
    """

    def __init__(self, stats: Optional[RepairStats] = None):
        self._lock = witness_lock(threading.Lock(), "repair.queue")
        self._pending: "Dict[tuple, RepairTicket]" = {}
        self.stats = stats if stats is not None else RepairStats()

    def put(self, ticket: RepairTicket) -> None:
        key = (ticket.inode_id, ticket.region_idx)
        with self._lock:
            known = key in self._pending \
                or (ticket.inode_id, None) in self._pending
            if not known:
                self._pending[key] = ticket
        if known:
            self.stats.add(tickets_deduped=1)
        else:
            self.stats.add(tickets_enqueued=1)

    def put_from_placement(self, placement_key: Any,
                           ptrs: Optional[Sequence[SlicePointer]] = None,
                           reason: str = "degraded-store") -> None:
        ticket = ticket_from_placement(placement_key, ptrs, reason)
        if ticket is None:
            self.stats.add(tickets_unparsed=1)
        else:
            self.put(ticket)

    def drain(self) -> List[RepairTicket]:
        with self._lock:
            tickets = list(self._pending.values())
            self._pending.clear()
        return tickets

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)


def _subtract_interval(spans: List[Tuple[int, int]],
                       lo: int, hi: int) -> List[Tuple[int, int]]:
    """Remove [lo, hi) from a sorted disjoint span list."""
    out: List[Tuple[int, int]] = []
    for a, b in spans:
        if b <= lo or a >= hi:
            out.append((a, b))
            continue
        if a < lo:
            out.append((a, lo))
        if b > hi:
            out.append((hi, b))
    return out


class RepairDaemon:
    """Consumes repair tickets and runs under-replication scans.

    Usable synchronously (``repair_pass`` / ``scan`` / ``verify`` from
    tests and benchmarks) or as a background thread (``start``/``stop``,
    registered with the cluster so an idempotent ``Cluster.close`` tears
    it down).  One daemon per cluster is the intended shape; nothing
    breaks with more, they just race to fix the same damage (commutes make
    the race benign — the loser's swap is a no-op merge).
    """

    def __init__(self, cluster, scan_every: int = 20):
        self.cluster = cluster
        self.queue: RepairQueue = cluster.repair_queue
        self.stats: RepairStats = cluster.repair_stats
        self._scan_every = max(1, scan_every)
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self, interval_s: float = 0.05) -> "RepairDaemon":
        """Run repair passes every ``interval_s`` (a full scan every
        ``scan_every``-th pass) until ``stop()`` or cluster close."""
        if self._thread is not None:
            return self
        self._stop_evt.clear()

        def loop() -> None:
            ticks = 0
            while not self._stop_evt.wait(interval_s):
                ticks += 1
                self.repair_pass(full_scan=(ticks % self._scan_every == 0))

        self._thread = threading.Thread(target=loop, name="wtf-repair",
                                        daemon=True)
        self.cluster._repair_daemon = self
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None

    # -------------------------------------------------------------- passes
    def repair_pass(self, full_scan: bool = False) -> dict:
        """Drain the ticket queue (optionally walking everything instead)
        and repair each named region.  Returns a pass summary."""
        summary = {"tickets": 0, "regions": 0, "repaired": 0,
                   "replicas_created": 0, "unrepairable": 0}
        if full_scan:
            drained = self.queue.drain()       # the walk covers them all
            if drained:
                self.stats.add(tickets_processed=len(drained))
            self.stats.add(scan_passes=1)
            targets = list(self._walk_regions())
        else:
            tickets = self.queue.drain()
            if not tickets:
                return summary
            self.stats.add(tickets_processed=len(tickets))
            summary["tickets"] = len(tickets)
            targets = []
            seen = set()
            for t in tickets:
                if t.region_idx is not None:
                    if (t.inode_id, t.region_idx) not in seen:
                        seen.add((t.inode_id, t.region_idx))
                        targets.append((t.inode_id, t.region_idx))
                else:
                    for key in self._walk_regions():
                        if key[0] == t.inode_id and key not in seen:
                            seen.add(key)
                            targets.append(key)
        for inode_id, region_idx in targets:
            r = self._repair_region(inode_id, region_idx)
            summary["regions"] += 1
            summary["repaired"] += r["repaired"]
            summary["replicas_created"] += r["replicas_created"]
            summary["unrepairable"] += r["unrepairable"]
        return summary

    def verify(self) -> dict:
        """Post-repair audit: walk every region and report replication of
        each *visible* extent against the achievable target
        (min(replication, live servers)).  ``replication_restored`` is the
        benchmark's acceptance bit."""
        cluster = self.cluster
        target = min(cluster.replication, self._n_live_servers())
        extents = under = lost = 0
        for inode_id, region_idx in self._walk_regions():
            rd = cluster.kv.get("regions", region_key(inode_id, region_idx))
            if rd is None:
                continue
            for e, visible in self._entries_with_visibility(rd):
                if not visible:
                    continue
                extents += 1
                live = sum(1 for p in e.ptrs if self._is_live(p.server_id))
                if live < target:
                    under += 1
                if live == 0:
                    lost += 1
        return {"extents": extents, "under_replicated": under,
                "lost": lost, "target_replication": target,
                "replication_restored": under == 0}

    # ----------------------------------------------------------- internals
    def _walk_regions(self):
        """Shard-by-shard region walk, same shape as ``gc._walk_keys``."""
        kv = self.cluster.kv
        shards = getattr(kv, "shards", None)
        if shards is None:
            yield from kv.keys("regions")
            return
        for shard in shards:
            yield from shard.keys("regions")

    def _is_live(self, server_id: int) -> bool:
        srv = self.cluster.servers.get(server_id)
        return srv is not None and srv.alive

    def _n_live_servers(self) -> int:
        return sum(1 for s in self.cluster.servers.values() if s.alive)

    def _entries_with_visibility(self, rd: RegionData):
        """Yield ``(extent, contributes_visible_bytes)`` for the region's
        raw overlay list (and the tier-2 indirect extent, obscured by every
        listed entry).  Later entries obscure earlier ones, so visibility
        is what's left after subtracting every *later* entry's range."""
        entries = list(rd.entries)
        layers = ([rd.indirect] if rd.indirect is not None else []) + entries
        for i, e in enumerate(layers):
            spans = [(e.offset, e.offset + e.length)]
            for later in layers[i + 1:]:
                spans = _subtract_interval(spans, later.offset,
                                           later.offset + later.length)
                if not spans:
                    break
            yield e, bool(spans)

    def _repair_region(self, inode_id: int, region_idx: int) -> dict:
        """Heal one region: re-replicate under-replicated extents and
        commit the swapped replica sets as ONE commuting op."""
        cluster = self.cluster
        out = {"repaired": 0, "replicas_created": 0, "unrepairable": 0}
        want = min(cluster.replication, self._n_live_servers())
        if want < 1:
            return out
        self.stats.add(regions_examined=1)
        kv = cluster.kv
        txn = kv.begin()
        rd: Optional[RegionData] = txn.peek("regions",
                                            region_key(inode_id, region_idx))
        if rd is None:
            txn.abort()
            return out
        pk = region_placement_key(inode_id, region_idx)
        hint = stable_hash(pk)
        mapping: Dict[Tuple[SlicePointer, ...],
                      Tuple[SlicePointer, ...]] = {}
        created: List[SlicePointer] = []
        canonical_moved = False
        recopied = 0
        for e, visible in self._entries_with_visibility(rd):
            if e.length == 0 or not e.ptrs:
                continue
            live = [p for p in e.ptrs if self._is_live(p.server_id)]
            if len(live) >= want:
                continue
            if not live:
                if visible:
                    out["unrepairable"] += 1
                    self.stats.add(unrepairable=1)
                continue
            try:
                data = bytes(cluster.fetch_slice(tuple(live)))
            except StorageError:
                out["unrepairable"] += 1 if visible else 0
                continue
            hosting = {p.server_id for p in live}
            new_ptrs: List[SlicePointer] = []
            for sid in cluster._ring.owners(pk, len(cluster.servers)):
                if len(live) + len(new_ptrs) >= want:
                    break
                if sid in hosting or not self._is_live(sid) \
                        or not cluster.health.allow(sid):
                    continue
                try:
                    ptr = cluster.servers[sid].create_slices(
                        [data], hint)[0]
                except StorageError:
                    cluster.health.record_failure(sid)
                    continue
                cluster.health.record_success(sid, 0.0)
                hosting.add(sid)
                new_ptrs.append(ptr)
            if not new_ptrs:
                continue
            # Surviving replicas keep their order: the canonical first
            # pointer (the block-cache key) is stable iff replica 0 lived.
            mapping[e.ptrs] = tuple(live) + tuple(new_ptrs)
            if live[0] != e.ptrs[0]:
                canonical_moved = True
            created.extend(new_ptrs)
            recopied += len(data) * len(new_ptrs)
            out["repaired"] += 1
            out["replicas_created"] += len(new_ptrs)
        if not mapping:
            txn.abort()
            return out
        txn.commute("regions", region_key(inode_id, region_idx),
                    ReplaceExtentPtrs(mapping))
        try:
            try:
                txn.commit()
            finally:
                # Release the create→commit GC shield on the fresh
                # replicas: published by the commit, or plain garbage.
                cluster.release_slices(created)
        except (KVConflict, PreconditionFailed):
            self.stats.add(repair_conflicts=1)
            return {"repaired": 0, "replicas_created": 0,
                    "unrepairable": out["unrepairable"]}
        self.stats.add(extents_repaired=out["repaired"],
                       replicas_created=out["replicas_created"],
                       bytes_recopied=recopied)
        if canonical_moved:
            # The block-cache/plan-cache canonical key changed for at
            # least one extent: evict the inode from the cluster-shared
            # caches.  (Per-client plan caches are version-validated — the
            # ReplaceExtentPtrs version bump invalidates them; per-client
            # block caches keyed on the dead pointer still name immutable
            # bytes and simply age out.)
            drops = 0
            if cluster.shared_plan_cache is not None:
                drops += cluster.shared_plan_cache.drop_inode(inode_id)
            if cluster.shared_block_cache is not None:
                drops += cluster.shared_block_cache.drop_inode(inode_id)
            self.stats.add(cache_drops=drops)
        return out
