"""Unified async I/O runtime — the single data-plane engine under both
schedulers.

Before this module, the read scheduler (``iosched``) and write scheduler
(``wsched``) were two near-duplicate engines: each had its own grouping
logic, its own failover loop, and the read side owned the thread pool the
write side borrowed.  The paper's performance story (§4) is that cheap
slice-pointer metadata work overlaps with batched data-plane rounds; a
client that serializes every ``readv``/``writev`` against its scheduler
forfeits exactly that overlap.  This module hosts everything the two
directions share, and the pieces the overlap needs:

  * **One pool, one submission queue.**  ``IoRuntime`` owns the only
    thread pool in the client stack.  Work is submitted as ``IoTask``s —
    a fetch batch, a store-group replica round, or a whole async client
    op — and completes through futures.  Both schedulers are thin
    strategy layers: they *plan* (group/coalesce/pack) and hand the
    resulting tasks here for execution, timing and failover accounting.
  * **Futures-based completion.**  ``submit_op`` runs an entire client op
    on the pool and returns an ``IoFuture``; the async surface
    (``readv_async``/``writev_async`` and friends) is built on it, so
    metadata planning for op N+1 overlaps the data rounds of op N
    (CannyFS, arXiv 1612.06830, measures how much this buys in exactly
    this batch-transactional setting).  A round dispatched *from* a pool
    worker runs inline rather than re-entering the queue, so async ops
    can never deadlock the pool against itself.
  * **Unified replica failover.**  ``run_with_failover`` is the one
    candidate-walk loop both directions use (§2.9): skip dead servers,
    mark a ``StorageError`` server failed with the coordinator, move to
    the next candidate, and surface exhaustion to the caller's
    degraded/fatal policy.
  * **Adaptive coalescing.**  Every round observed through the runtime
    updates an EWMA cost model (per-server round-trip cost plus a global
    bandwidth estimate).  The gap/pack thresholds the schedulers use are
    sized from it — the bytes one round-trip is worth — replacing the two
    fixed 32 KiB constants.  Explicit ``fetch_gap_bytes`` /
    ``store_coalesce_bytes`` knobs pin the thresholds and disable
    adaptation (benchmarks pin them so paper-reproduction accounting
    stays comparable across runs).
  * **Read-plan cache.**  ``PlanCache`` memoizes resolved read plans
    keyed on ``(inode, requested ranges)`` and *validated* against the
    region versions observed when the plan was built — the commutes a
    commit applies bump those versions, so invalidation is exactly the
    KV's own conflict rule (FaaSFS-style version-keyed client caching).
    Pending write-behind extents never enter the cache, mirroring
    ``overlay_cached``.
  * **Atomic stats.**  ``AtomicStatsMixin`` routes every counter
    mutation through a per-stats lock; with rounds and whole ops running
    on pool threads, the bare ``+=`` updates ``ClientStats`` and
    ``StorageStats`` used before this PR were lost-update races.
"""
from __future__ import annotations

import itertools
import queue as _queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .errors import DeadlineExceeded, ReplicaExhausted, StorageError
from .placement import stable_hash
from .testing import witness_lock

# Seed/floor/ceiling for the adaptive thresholds.  The seed matches the old
# fixed constant so a fresh cluster behaves identically until it has
# observed real rounds; the clamps keep a noisy estimate from degenerating
# into no coalescing at all or whole-file over-reads.
ADAPTIVE_SEED = 32 << 10
ADAPTIVE_FLOOR = 4 << 10
ADAPTIVE_CEILING = 256 << 10

# Server readahead window clamps (see IoRuntime.readahead_bytes): deep
# enough to cover several coalesced batches of a sequential stream, small
# enough that a handful of concurrent streams fit one server's pool.
READAHEAD_FLOOR = 128 << 10
READAHEAD_CEILING = 4 << 20

# EWMA blend weight for new observations (two-ish dozen rounds to converge).
_EWMA_ALPHA = 0.15

# Health-tracker policy (see HealthTracker): a server is circuit-broken
# after this many consecutive failures, backs off exponentially from the
# base up to the cap (plus deterministic seeded jitter, so a fleet of
# clients never probes in lockstep), and a hedged retry fires when a round
# runs past this multiple of the server's EWMA latency.
HEALTH_FAILURE_THRESHOLD = 3
HEALTH_BACKOFF_BASE_S = 0.05
HEALTH_BACKOFF_CAP_S = 5.0
HEALTH_JITTER_FRAC = 0.25
HEDGE_EWMA_MULTIPLIER = 4.0
HEDGE_MIN_S = 0.001
# Rounds at most this big estimate fixed per-round cost; rounds at least
# this big estimate bandwidth.  In between they update neither cleanly.
_SMALL_ROUND_BYTES = 4 << 10
_LARGE_ROUND_BYTES = 64 << 10


class AtomicStatsMixin:
    """Lock-guarded counter mutation for the stats dataclasses.

    Pool threads bump ``ClientStats`` (async ops) and ``StorageStats``
    (concurrent rounds) concurrently with the application thread; a bare
    ``+=`` on an attribute is a read-modify-write race.  All mutation goes
    through ``add``; ``snapshot`` reads under the same lock.

    Declares empty ``__slots__`` and reads fields via dataclass
    introspection so the (heavily-instantiated, hot-path) stats
    dataclasses can opt into ``slots=True`` without growing a ``__dict__``.
    """

    __slots__ = ()

    def add(self, **deltas: int) -> None:
        with self._stats_lock:
            for name, delta in deltas.items():
                setattr(self, name, getattr(self, name) + delta)

    def snapshot(self) -> dict:
        import dataclasses as _dc

        with self._stats_lock:
            return {f.name: getattr(self, f.name)
                    for f in _dc.fields(self)
                    if not f.name.startswith("_")}


class IoTask:
    """One unit of data-plane work submitted to the runtime.

    ``kind`` is ``"fetch"`` / ``"store"`` for storage-server rounds (timed
    into the adaptive cost model) or ``"op"`` for a whole async client op
    (not a round — excluded from the model).  ``server_id``/``nbytes`` may
    be refined by the executing function (e.g. the store path only knows
    its server after the ring walk claims one).
    """

    __slots__ = ("kind", "server_id", "nbytes", "payload")

    def __init__(self, kind: str, server_id: Optional[int] = None,
                 nbytes: int = 0, payload: Any = None):
        self.kind = kind
        self.server_id = server_id
        self.nbytes = nbytes
        self.payload = payload


class IoFuture:
    """Future for an async client op.

    Thin wrapper over ``concurrent.futures.Future`` that records, in the
    owning client's stats, whether the caller had to *block* for the
    result (``blocked_waits``) — the counter the pipeline overlap
    benchmark uses to show async prefetch hiding data rounds behind
    compute.
    """

    __slots__ = ("_fut", "_stats", "_counted")

    def __init__(self, fut: Future, stats=None):
        self._fut = fut
        self._stats = stats
        self._counted = False

    @classmethod
    def resolved(cls, value: Any) -> "IoFuture":
        f: Future = Future()
        f.set_result(value)
        return cls(f)

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._fut.done() and self._stats is not None \
                and not self._counted:
            self._counted = True
            self._stats.add(blocked_waits=1)
        return self._fut.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._fut.exception(timeout)

    def add_done_callback(self, fn: Callable[["IoFuture"], None]) -> None:
        self._fut.add_done_callback(lambda _f: fn(self))


class _ServerHealth:
    """Per-server circuit state (mutated only under HealthTracker._lock)."""

    __slots__ = ("consecutive_failures", "ewma_latency_s", "open_until",
                 "backoff_exp", "probing", "failures_total", "opens")

    def __init__(self):
        self.consecutive_failures = 0
        self.ewma_latency_s: Optional[float] = None
        self.open_until = 0.0          # monotonic time the circuit re-arms
        self.backoff_exp = 0           # consecutive re-opens (backoff power)
        self.probing = False           # one half-open probe in flight
        self.failures_total = 0
        self.opens = 0


class HealthTracker:
    """Per-server failure memory behind the §2.9 candidate walk.

    The stateless walk re-probed every dead server on every round — one
    wasted timeout per round per corpse.  This tracker gives the walk
    memory, as a classic circuit breaker:

      * **closed** — fewer than ``failure_threshold`` consecutive failures:
        the server is tried normally.  Successes record an EWMA of round
        latency (feeds the hedge threshold) and reset the failure count.
      * **open** — at the threshold the circuit opens for an exponentially
        growing backoff (base × 2^n, capped) plus *deterministic seeded
        jitter* — ``stable_hash(seed, sid, opens)`` spreads a fleet's
        probes without making any test run nondeterministic.  While open,
        ``allow`` says no and the walk skips the server up front.
      * **half-open** — once the backoff elapses, exactly ONE caller is
        admitted as a probe; success closes the circuit (and resets the
        backoff exponent), failure re-opens it with a doubled backoff.

    ``reset`` (wired to ``Cluster.recover_server``) clears a server's
    state when an operator declares it healthy.  All state lives under the
    ``iort.health`` lock (ranked in ``analysis.lockspec``); nothing blocks
    under it.  Counters surface via ``snapshot()`` in ``total_stats()``.
    """

    def __init__(self, seed: int = 0,
                 failure_threshold: int = HEALTH_FAILURE_THRESHOLD,
                 backoff_base_s: float = HEALTH_BACKOFF_BASE_S,
                 backoff_cap_s: float = HEALTH_BACKOFF_CAP_S,
                 clock: Callable[[], float] = time.monotonic):
        self._lock = witness_lock(threading.Lock(), "iort.health")
        self._seed = seed
        self._threshold = max(1, failure_threshold)
        self._backoff_base_s = backoff_base_s
        self._backoff_cap_s = backoff_cap_s
        self._clock = clock
        self._servers: Dict[int, _ServerHealth] = {}
        # Walk-level counters (guarded by the same lock).
        self._skips = 0
        self._probes = 0
        self._hedged_rounds = 0
        self._deadline_timeouts = 0

    def _state(self, sid: int) -> _ServerHealth:
        st = self._servers.get(sid)
        if st is None:
            st = self._servers[sid] = _ServerHealth()
        return st

    def _jitter(self, sid: int, n: int) -> float:
        """Deterministic jitter fraction in [0, 1): seeded, per (server,
        re-open count), stable across runs and threads."""
        return (stable_hash(self._seed, sid, n, salt="health")
                % 10_000) / 10_000.0

    def allow(self, sid: int) -> bool:
        """May the walk try ``sid`` right now?  Grants the single half-open
        probe token when an open circuit's backoff has elapsed."""
        with self._lock:
            st = self._servers.get(sid)
            if st is None or st.consecutive_failures < self._threshold:
                return True
            if st.probing:
                self._skips += 1
                return False
            if self._clock() >= st.open_until:
                st.probing = True
                self._probes += 1
                return True
            self._skips += 1
            return False

    def record_success(self, sid: int, seconds: float) -> None:
        with self._lock:
            st = self._state(sid)
            st.consecutive_failures = 0
            st.backoff_exp = 0
            st.probing = False
            st.open_until = 0.0
            if seconds > 0:
                prev = st.ewma_latency_s
                st.ewma_latency_s = (
                    seconds if prev is None
                    else prev + _EWMA_ALPHA * (seconds - prev))

    def record_failure(self, sid: int) -> None:
        with self._lock:
            st = self._state(sid)
            st.failures_total += 1
            st.consecutive_failures += 1
            st.probing = False
            if st.consecutive_failures < self._threshold:
                return
            backoff = min(self._backoff_cap_s,
                          self._backoff_base_s * (2 ** st.backoff_exp))
            backoff *= 1.0 + HEALTH_JITTER_FRAC * self._jitter(sid, st.opens)
            st.open_until = self._clock() + backoff
            st.backoff_exp += 1
            st.opens += 1

    def reset(self, sid: int) -> None:
        """Operator-declared recovery: forget the server's failure state."""
        with self._lock:
            self._servers.pop(sid, None)

    def hedge_threshold_s(self, sid: int, deadline_s: float) -> float:
        """When to fire the hedged retry for a round on ``sid``: a multiple
        of the server's EWMA latency (a healthy round should be long done),
        clamped into (HEDGE_MIN_S, deadline)."""
        with self._lock:
            st = self._servers.get(sid)
            ewma = st.ewma_latency_s if st is not None else None
        if ewma is None:
            return deadline_s / 2
        return max(HEDGE_MIN_S, min(deadline_s, ewma * HEDGE_EWMA_MULTIPLIER))

    def note_hedge(self) -> None:
        with self._lock:
            self._hedged_rounds += 1

    def note_deadline_timeout(self) -> None:
        with self._lock:
            self._deadline_timeouts += 1

    def snapshot(self) -> dict:
        with self._lock:
            servers = {
                sid: {
                    "consecutive_failures": st.consecutive_failures,
                    "failures_total": st.failures_total,
                    "circuit_open": (st.consecutive_failures
                                     >= self._threshold),
                    "opens": st.opens,
                    "ewma_latency_s": st.ewma_latency_s,
                }
                for sid, st in self._servers.items()}
            return {
                "servers_skipped": self._skips,
                "half_open_probes": self._probes,
                "hedged_rounds": self._hedged_rounds,
                "deadline_timeouts": self._deadline_timeouts,
                "circuit_opens": sum(st.opens
                                     for st in self._servers.values()),
                "servers": servers,
            }


def run_with_failover(cluster, candidates: Sequence[Tuple[int, Any]],
                      attempt: Callable[[Any, Any], Any],
                      release: Optional[Callable[[int], None]] = None,
                      exhausted: Optional[Callable[[Optional[Exception]],
                                                   Any]] = None) -> Any:
    """The one §2.9 candidate-walk failover loop, shared by both directions.

    Walks ``(server_id, payload)`` candidates in order: dead, missing, or
    circuit-broken servers (the cluster's ``HealthTracker``) are skipped up
    front; ``attempt(server, payload)`` returning is success (recorded into
    the server's health EWMA); a ``StorageError`` bumps the server's
    failure count, marks it failed with the coordinator
    (``cluster._on_server_error``), optionally ``release``s any claim the
    caller took on it, and moves on.  When every candidate is exhausted,
    ``exhausted(last_error)`` decides the outcome (default: raise
    ``ReplicaExhausted`` — a ``StorageError`` subclass, so existing
    degraded-path handlers keep working).

    With ``Cluster(io_deadline_s=...)`` set, rounds run with a per-round
    deadline and one hedged retry (``_run_with_deadline``): a round that
    outlives the health-EWMA-derived hedge threshold stops gating the walk.
    """
    health = getattr(cluster, "health", None)
    deadline = getattr(cluster, "io_deadline_s", None)
    if deadline is not None and health is not None:
        return _run_with_deadline(cluster, candidates, attempt, release,
                                  exhausted, health, deadline)
    last: Optional[Exception] = None
    for sid, payload in candidates:
        srv = cluster.servers.get(sid)
        if srv is None or not srv.alive or \
                (health is not None and not health.allow(sid)):
            if release is not None:
                release(sid)
            continue
        t0 = time.perf_counter()
        try:
            result = attempt(srv, payload)
        except StorageError as e:
            last = e
            if health is not None:
                health.record_failure(sid)
            if release is not None:
                release(sid)
            cluster._on_server_error(sid)
            continue
        if health is not None:
            health.record_success(sid, time.perf_counter() - t0)
        return result
    if exhausted is not None:
        return exhausted(last)
    raise ReplicaExhausted(f"all replicas unavailable: {last}")


def _run_with_deadline(cluster, candidates, attempt, release, exhausted,
                       health: HealthTracker, deadline: float) -> Any:
    """Deadline + hedged variant of the candidate walk.

    Attempts run on the runtime's dedicated hedge pool (never the shared
    round pool — a walk frequently *runs on* a round-pool worker, and
    blocking there on work only that pool could run is the classic
    self-deadlock).  The walk waits on a completion queue with three
    timers:

      * **hedge** — the first time a round outlives the server's
        health-EWMA-derived hedge threshold, ONE hedged retry is launched
        on the next candidate; first success wins, the loser is abandoned
        (reads are idempotent; an abandoned store's slices are unreferenced
        garbage the §2.8 collector reclaims).
      * **deadline** — a round older than ``io_deadline_s`` is abandoned
        and counted as a failure against the server's health (it ate a
        full timeout) without being declared dead to the coordinator —
        slow is not dead.
      * **exhaustion** — no replicas in flight and no candidates left:
        the caller's ``exhausted`` policy (default ``ReplicaExhausted``).
    """
    it = iter(candidates)
    results: "_queue.SimpleQueue" = _queue.SimpleQueue()
    tokens = itertools.count()
    inflight: Dict[int, Tuple[int, float]] = {}   # token -> (sid, start)
    last: Optional[Exception] = None
    hedged = False

    def next_live():
        for sid, payload in it:
            srv = cluster.servers.get(sid)
            if srv is None or not srv.alive or not health.allow(sid):
                if release is not None:
                    release(sid)
                continue
            return sid, payload, srv
        return None

    def launch(sid, payload, srv) -> None:
        tok = next(tokens)
        inflight[tok] = (sid, time.perf_counter())

        def body():
            try:
                results.put((tok, True, attempt(srv, payload)))
            except BaseException as e:   # noqa: BLE001 — relayed to caller
                results.put((tok, False, e))

        cluster.runtime.hedge_submit(body)

    def exhaust():
        if exhausted is not None:
            return exhausted(last)
        raise ReplicaExhausted(f"all replicas unavailable: {last}")

    first = next_live()
    if first is None:
        return exhaust()
    launch(*first)
    while True:
        now = time.perf_counter()
        timers = [t0 + deadline for (_sid, t0) in inflight.values()]
        if not hedged and len(inflight) == 1:
            (h_sid, h_t0), = inflight.values()
            timers.append(h_t0 + health.hedge_threshold_s(h_sid, deadline))
        try:
            tok, ok, val = results.get(
                timeout=max(0.0, min(timers) - now))
        except _queue.Empty:
            now = time.perf_counter()
            if not hedged and len(inflight) == 1:
                (h_sid, h_t0), = inflight.values()
                if now >= h_t0 + health.hedge_threshold_s(h_sid, deadline):
                    hedged = True        # one hedge per walk, fired or not
                    nxt = next_live()
                    if nxt is not None:
                        health.note_hedge()
                        launch(*nxt)
                        continue
            expired = [tok for tok, (_sid, t0) in inflight.items()
                       if now >= t0 + deadline]
            for tok in expired:
                sid, _t0 = inflight.pop(tok)
                health.record_failure(sid)
                health.note_deadline_timeout()
                if release is not None:
                    release(sid)
                last = DeadlineExceeded(
                    f"round on server {sid} exceeded io_deadline_s="
                    f"{deadline}")
            if not inflight:
                nxt = next_live()
                if nxt is None:
                    return exhaust()
                launch(*nxt)
            continue
        entry = inflight.pop(tok, None)
        if entry is None:
            continue                     # abandoned attempt resolved late
        sid, t0 = entry
        if ok:
            health.record_success(sid, time.perf_counter() - t0)
            return val
        if isinstance(val, StorageError):
            last = val
            health.record_failure(sid)
            if release is not None:
                release(sid)
            cluster._on_server_error(sid)
            if not inflight:
                nxt = next_live()
                if nxt is None:
                    return exhaust()
                launch(*nxt)
            continue
        raise val                        # non-StorageError: programming bug


class PlanCache:
    """Version-validated LRU of resolved read plans.

    Key: ``(inode_id, clamped ranges)``.  Value: the region versions the
    plan was built against plus the prepared per-range extent plans.  A
    lookup revalidates every version through the caller's transaction (the
    read dependency is recorded at the same version, so a hit is exactly
    as serializable as a re-plan); any commit whose commutes touched a
    region bumped its version, which is the whole invalidation story.
    Thread-safe: async ops consult it from pool workers.

    Because hits are version-validated per transaction, one cache is safe
    to share across *clients*: on lease-enabled clusters the cluster owns a
    single shared instance (see ``client.Cluster``), so a file one client
    has planned is a plan-cache hit for every other client — the same
    lease rule that lets hot re-reads skip the KV.  The lease hub evicts a
    whole inode's plans when its region metadata changes (``drop_inode``,
    fed by the WAL subscribe stream); stale entries could only fail their
    validation anyway, eviction just keeps the shared LRU useful.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._lock = witness_lock(threading.Lock(), "cache.plan")
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        # inode id → set of live keys, so lease-driven invalidation of one
        # inode's plans is O(its entries), not a scan of the whole LRU.
        self._by_inode: dict = {}

    def get(self, key: tuple) -> Optional[tuple]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
            return entry

    def put(self, key: tuple, value: tuple) -> None:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            self._by_inode.setdefault(key[0], set()).add(key)
            while len(self._entries) > self.maxsize:
                old, _ = self._entries.popitem(last=False)
                self._drop_index(old)

    def _drop_index(self, key: tuple) -> None:
        keys = self._by_inode.get(key[0])
        if keys is not None:
            keys.discard(key)
            if not keys:
                del self._by_inode[key[0]]

    def drop_inode(self, inode_id: int) -> int:
        """Evict every plan for ``inode_id``; returns entries dropped."""
        with self._lock:
            keys = self._by_inode.pop(inode_id, None)
            if not keys:
                return 0
            for key in keys:
                self._entries.pop(key, None)
            return len(keys)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_inode.clear()


class IoRuntime:
    """The cluster's single data-plane execution engine.

    One runtime per cluster, shared by every client and both scheduler
    strategy layers.  Owns the only thread pool, the adaptive-threshold
    cost model, and the failover/degraded accounting helpers.
    """

    def __init__(self, max_workers: int = 8,
                 gap_override: Optional[int] = None,
                 coalesce_override: Optional[int] = None):
        self._max_workers = max(1, max_workers)
        self._pool: Optional[ThreadPoolExecutor] = None
        # Dedicated pool for deadline/hedged replica attempts (created on
        # first use; only clusters with ``io_deadline_s`` set ever pay for
        # it).  Separate from the round pool on purpose: the failover walk
        # usually RUNS on a round-pool worker, and a worker blocking on
        # work only its own pool can execute is the self-deadlock
        # ``run_tasks``'s help-drain exists to avoid.  Hedge tasks are leaf
        # storage calls that never re-enter either pool, so sizing is just
        # capacity: two attempts (primary + hedge) per concurrent walk.
        self._hedge_pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        self._in_worker = threading.local()
        self._closed = False
        # Adaptive cost model (guarded by _model_lock): per-server EWMA of
        # round wall time, global EWMAs of fixed round cost + bandwidth.
        self._model_lock = threading.Lock()
        self._gap_override = gap_override
        self._coalesce_override = coalesce_override
        self._rtt_by_server: Dict[int, float] = {}
        self._ewma_round_s: Optional[float] = None   # fixed per-round cost
        self._ewma_bw: Optional[float] = None        # bytes / second
        self._rounds_observed = 0
        # Pool admission delay: how long a submitted async op sat queued
        # before a worker picked it up — the runtime-side analogue of the
        # KV plane's ``commit_wait_s`` (queueing here means concurrent ops
        # are serializing on pool capacity, not on locks).
        self._ewma_op_wait_s: Optional[float] = None
        self._ops_observed = 0

    # ----------------------------------------------------------------- pool
    def _pool_get(self) -> ThreadPoolExecutor:
        pool = self._pool
        if pool is not None:
            return pool
        with self._pool_lock:
            if self._closed:
                raise RuntimeError("I/O runtime is closed")
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="wtf-iort")
        return self._pool

    def _hedge_pool_get(self) -> ThreadPoolExecutor:
        pool = self._hedge_pool
        if pool is not None:
            return pool
        with self._pool_lock:
            if self._closed:
                raise RuntimeError("I/O runtime is closed")
            if self._hedge_pool is None:
                self._hedge_pool = ThreadPoolExecutor(
                    max_workers=2 * self._max_workers + 2,
                    thread_name_prefix="wtf-hedge")
        return self._hedge_pool

    def hedge_submit(self, fn: Callable[[], Any]) -> None:
        """Run one deadline-governed replica attempt on the hedge pool."""
        self._hedge_pool_get().submit(fn)

    def in_worker(self) -> bool:
        """True when called from one of the runtime's own pool threads."""
        return getattr(self._in_worker, "active", False)

    def close(self) -> None:
        """Drain and shut down: every submitted task (queued or running)
        completes, its future resolves, and all pool threads exit — no
        in-flight future is ever abandoned.  The executor stays visible
        while draining so in-flight ops that try to fan out degrade to
        inline execution (``run_tasks``) instead of erroring.  Abandoned
        hedge attempts (already timed out and failed over past) are the
        one exception: their threads are joined here too, after the round
        pool drains, so a sleeping slow replica can't leak a thread."""
        with self._pool_lock:
            self._closed = True
            pool = self._pool
            hedge = self._hedge_pool
        if pool is not None:
            pool.shutdown(wait=True)
            with self._pool_lock:
                self._pool = None
        if hedge is not None:
            hedge.shutdown(wait=True)
            with self._pool_lock:
                self._hedge_pool = None

    # ------------------------------------------------------------ execution
    def _execute(self, task: IoTask, fn: Callable[[IoTask], Any]) -> Any:
        prev = getattr(self._in_worker, "active", False)
        self._in_worker.active = True
        t0 = time.perf_counter()
        try:
            return fn(task)
        finally:
            self._in_worker.active = prev
            if task.kind in ("fetch", "store"):
                self.observe_round(task.server_id,
                                   time.perf_counter() - t0, task.nbytes)

    def run_tasks(self, tasks: Sequence[IoTask],
                  fn: Callable[[IoTask], Any]) -> List[Any]:
        """Execute a planned round set; returns results in task order.

        From the application thread, fan-out happens on the pool.  From a
        pool worker (an async op issuing its own rounds) a plain blocking
        fan-out is how shared-pool designs deadlock — every worker waiting
        on a queue only workers can drain — so workers use *help-drain*:
        submit every round, then walk them in order, CANCELLING any round
        no other worker has started yet and running it inline.  A started
        round is leaf work (it never re-enters this wait), so blocking on
        it is deadlock-free; a queued round is always cancellable.  Idle
        workers therefore still lend parallelism to an async op's rounds,
        and a saturated pool degrades to inline execution instead of
        deadlock.
        """
        if len(tasks) <= 1 or self._max_workers <= 1:
            return [self._execute(t, fn) for t in tasks]
        pool = self._pool_get()
        if not self.in_worker():
            return list(pool.map(lambda t: self._execute(t, fn), tasks))
        futs: List[Optional[Future]] = []
        try:
            for t in tasks:
                futs.append(pool.submit(self._execute, t, fn))
        except RuntimeError:
            # Pool draining for shutdown: the rounds run inline instead.
            futs.extend([None] * (len(tasks) - len(futs)))
        results: List[Any] = []
        try:
            for t, fut in zip(tasks, futs):
                if fut is None or fut.cancel():
                    results.append(self._execute(t, fn))
                else:
                    results.append(fut.result())
        except BaseException:
            for fut in futs:
                if fut is not None:
                    fut.cancel()
            raise
        return results

    def submit_op(self, fn: Callable[[], Any], stats=None) -> IoFuture:
        """Run a whole client op on the pool; returns its ``IoFuture``.

        The async surface's engine: the op body (plan + rounds + commit)
        executes on a worker, and the caller's thread is free to plan the
        next op.  ``stats`` is the owning client's ``ClientStats``
        (records ``blocked_waits`` when the caller has to block on the
        result).
        """
        task = IoTask("op")
        t0 = time.perf_counter()

        def body(_t):
            self._observe_op_wait(time.perf_counter() - t0)
            return fn()

        fut = self._pool_get().submit(self._execute, task, body)
        return IoFuture(fut, stats)

    def _observe_op_wait(self, seconds: float) -> None:
        with self._model_lock:
            self._ops_observed += 1
            prev = self._ewma_op_wait_s
            self._ewma_op_wait_s = (
                seconds if prev is None
                else prev + _EWMA_ALPHA * (seconds - prev))

    # ------------------------------------------------------- adaptive model
    def observe_round(self, server_id: Optional[int], seconds: float,
                      nbytes: int) -> None:
        """Feed one completed storage round into the EWMA cost model."""
        if seconds <= 0:
            return
        with self._model_lock:
            self._rounds_observed += 1
            if server_id is not None:
                prev = self._rtt_by_server.get(server_id)
                self._rtt_by_server[server_id] = (
                    seconds if prev is None
                    else prev + _EWMA_ALPHA * (seconds - prev))
            if nbytes <= _SMALL_ROUND_BYTES:
                prev = self._ewma_round_s
                self._ewma_round_s = (
                    seconds if prev is None
                    else prev + _EWMA_ALPHA * (seconds - prev))
            elif nbytes >= _LARGE_ROUND_BYTES:
                bw = nbytes / seconds
                prev = self._ewma_bw
                self._ewma_bw = (bw if prev is None
                                 else prev + _EWMA_ALPHA * (bw - prev))

    def _adaptive_bytes(self) -> int:
        with self._model_lock:
            if self._ewma_round_s is None or self._ewma_bw is None:
                return ADAPTIVE_SEED
            est = int(self._ewma_round_s * self._ewma_bw)
        return max(ADAPTIVE_FLOOR, min(ADAPTIVE_CEILING, est))

    def gap_bytes(self) -> int:
        """Read-side coalescing threshold: fetch-and-discard a gap of at
        most this many bytes rather than pay another round trip.  Pinned
        by the ``fetch_gap_bytes`` knob; otherwise one round-trip's worth
        of bytes under the current EWMA estimates."""
        if self._gap_override is not None:
            return self._gap_override
        return self._adaptive_bytes()

    def coalesce_bytes(self) -> int:
        """Write-side packing threshold (``store_coalesce_bytes`` pins)."""
        if self._coalesce_override is not None:
            return self._coalesce_override
        return self._adaptive_bytes()

    def readahead_bytes(self) -> int:
        """Server readahead window: how far past a sequential reader's
        last batch the storage server speculates.  A multiple of the
        round-trip-worth estimate so a stream absorbs several coalesced
        batches per speculative read, clamped to keep the per-server
        buffer pool bounded."""
        return max(READAHEAD_FLOOR,
                   min(READAHEAD_CEILING, 8 * self._adaptive_bytes()))

    def snapshot(self) -> dict:
        """Adaptive-threshold accounting for ``Cluster.total_stats``."""
        with self._model_lock:
            rtt = dict(self._rtt_by_server)
            round_s, bw = self._ewma_round_s, self._ewma_bw
            rounds = self._rounds_observed
            op_wait, ops = self._ewma_op_wait_s, self._ops_observed
        return {
            "ops_observed": ops,
            "ewma_op_wait_s": op_wait,
            "adaptive_gap_bytes": self.gap_bytes(),
            "adaptive_coalesce_bytes": self.coalesce_bytes(),
            "gap_pinned": self._gap_override is not None,
            "coalesce_pinned": self._coalesce_override is not None,
            "rounds_observed": rounds,
            "ewma_round_s": round_s,
            "ewma_bandwidth_bps": bw,
            "ewma_rtt_by_server": rtt,
        }
