"""Three-tier garbage collection (paper §2.8).

Tier 1 — metadata compaction: replace a region's overlay list with its
compacted equivalent in one KV transaction.  No storage I/O at all; reclaims
the metadata growth caused by many appends and overlapped writes.

Tier 2 — metadata spill: when even the compacted list is too fragmented
(random writes defeat locality), serialize it into a slice and store only a
pointer.  The region list shrinks to O(1) regardless of fragmentation.

Tier 3 — storage scan: periodically walk the *entire* filesystem metadata,
build per-server in-use pointer lists, and publish them as files under the
reserved ``/.wtf-gc`` directory — servers read their own file (they link the
client library, §2.8) and sparse-rewrite their most-garbaged backing files.
The two-consecutive-scans rule (enforced inside ``StorageServer.gc_pass``)
closes the race with slices created but not yet referenced.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from .client import GC_DIR, Cluster, WtfClient
from .inode import RegionData, region_key
from .slicing import (Extent, SlicePointer, compact, decode_extents,
                      encode_extents)


class GarbageCollector:
    def __init__(self, cluster: Cluster, spill_threshold: int = 64):
        self.cluster = cluster
        self.client = cluster.client()
        self.spill_threshold = spill_threshold

    # ------------------------------------------------------------- tier 1+2
    def compact_region(self, inode_id: int, region_idx: int) -> dict:
        """Tier 1 (+ tier 2 if still fragmented), one KV transaction.

        Runs optimistically: a concurrent append bumps the region version
        and our read-dependency aborts the swap — compaction can never lose
        a write.  (We simply skip; the next pass retries.)
        """
        from .errors import KVConflict, PreconditionFailed

        kv = self.cluster.kv
        txn = kv.begin()
        rd: Optional[RegionData] = txn.get("regions",
                                           region_key(inode_id, region_idx))
        if rd is None:
            txn.abort()
            return {"skipped": True}
        entries = list(rd.entries)
        if rd.indirect is not None:
            raw = self.cluster.fetch_slice(rd.indirect.ptrs)
            entries = decode_extents(raw) + entries
        before = len(entries)
        compacted = compact(entries)
        if (rd.indirect is None and tuple(compacted) == rd.entries
                and len(compacted) <= self.spill_threshold):
            # Already minimal — the common case now that writers piggyback
            # commit-time compaction (``inode.CompactRegion``).  Rewriting
            # it anyway would bump the region version and spuriously
            # invalidate concurrent readers' plans/read sets for a no-op.
            txn.abort()
            return {"skipped": False, "noop": True, "before": before,
                    "after": len(compacted), "spilled": False}
        if len(compacted) > self.spill_threshold:
            # Tier 2: spill the compacted list into a slice; the region
            # keeps a single indirect pointer (§2.8).
            blob = encode_extents(compacted)
            ptrs = self.cluster.store_slice(
                blob, ("gc-spill", inode_id, region_idx),
                hint=inode_id)
            new = RegionData(entries=(), end=rd.end,
                             indirect=Extent(0, len(blob), ptrs))
            spilled = True
        else:
            new = RegionData(entries=tuple(compacted), end=rd.end,
                             indirect=None)
            spilled = False
        txn.put("regions", region_key(inode_id, region_idx), new)
        try:
            try:
                txn.commit()
            finally:
                # Spill slices were stored outside any client op, so the
                # create→commit GC shield is released here: published by
                # the commit, or plain garbage after the abort.
                if spilled:
                    self.cluster.release_slices(ptrs)
        except (KVConflict, PreconditionFailed):
            return {"skipped": True}
        return {"skipped": False, "before": before,
                "after": len(compacted), "spilled": spilled}

    def _walk_keys(self, space: str):
        """Deterministic space walk across the whole metadata plane.  On a
        sharded plane (``mdshard.ShardedKV``) the walk goes shard by shard
        in shard order — each shard's keys are a consistent snapshot of
        that shard, and a scan never straddles a shard boundary mid-shard —
        which also keeps the GC's iteration order stable across runs."""
        kv = self.cluster.kv
        shards = getattr(kv, "shards", None)
        if shards is None:
            yield from kv.keys(space)
            return
        for shard in shards:
            yield from shard.keys(space)

    def compact_all(self) -> dict:
        stats = {"regions": 0, "entries_before": 0, "entries_after": 0,
                 "spilled": 0, "noop": 0}
        for key in self._walk_keys("regions"):
            inode_id, region_idx = key
            r = self.compact_region(inode_id, region_idx)
            if r.get("noop"):
                stats["noop"] += 1
                continue
            if r.get("skipped"):
                continue
            stats["regions"] += 1
            stats["entries_before"] += r["before"]
            stats["entries_after"] += r["after"]
            stats["spilled"] += bool(r["spilled"])
        return stats

    # --------------------------------------------------------------- tier 3
    def scan_filesystem(self) -> Dict[int, List[SlicePointer]]:
        """Build the per-server in-use pointer lists from all metadata."""
        live: Dict[int, List[SlicePointer]] = {
            sid: [] for sid in self.cluster.servers
        }

        def note(ptrs):
            for p in ptrs:
                if p.server_id in live:
                    live[p.server_id].append(p)

        kv = self.cluster.kv
        for key in self._walk_keys("regions"):
            rd: RegionData = kv.get("regions", key)
            if rd is None:
                continue
            if rd.indirect is not None:
                note(rd.indirect.ptrs)
                for e in decode_extents(
                        self.cluster.fetch_slice(rd.indirect.ptrs)):
                    note(e.ptrs)
            for e in rd.entries:
                note(e.ptrs)
        return live

    def publish_live_lists(self, live: Dict[int, List[SlicePointer]]) -> None:
        """Store the lists as files in the reserved WTF directory (§2.8) —
        no out-of-band channel to the storage servers is needed."""
        for sid, ptrs in live.items():
            path = f"{GC_DIR}/server-{sid:03d}"
            payload = encode_extents(
                [Extent(0, p.length, (p,)) for p in ptrs])
            if self.client.exists(path):
                fd = self.client.open(path, "rw")
                self.client.truncate(fd, 0)
            else:
                fd = self.client.open(path, "w")
            self.client.write(fd, payload)
            self.client.close(fd)

    def read_live_list(self, server_id: int) -> List[SlicePointer]:
        """What a storage server does: read its own live list via the
        client library (§2.8)."""
        path = f"{GC_DIR}/server-{server_id:03d}"
        fd = self.client.open(path, "r")
        raw = self.client.read(fd)
        self.client.close(fd)
        # The GC files themselves live on the servers; exclude nothing —
        # their own extents are in the metadata scan like any other file.
        return [e.ptrs[0] for e in decode_extents(raw)]

    def storage_gc_pass(self, max_files_per_server: Optional[int] = None) -> dict:
        """One full tier-3 cycle: scan → publish → per-server collect."""
        # Stamp the walk start BEFORE reading any metadata: the servers
        # shield handoff releases newer than the previous pass's stamp,
        # because neither that walk nor this one can be trusted about
        # ranges whose commit raced the scan pipeline.
        walk_started_at = time.monotonic()
        live = self.scan_filesystem()
        self.publish_live_lists(live)
        # Re-scan after publishing so the live lists include the GC files
        # we just wrote (they are ordinary files whose slices must survive).
        live = self.scan_filesystem()
        totals = {"reclaimed": 0, "rewritten": 0, "files": 0}
        for sid, server in self.cluster.servers.items():
            if not server.alive:
                continue
            result = server.gc_pass(live.get(sid, []),
                                    max_files=max_files_per_server,
                                    walk_started_at=walk_started_at)
            for k in totals:
                totals[k] += result[k]
        return totals

    def full_cycle(self) -> dict:
        """Tier 1+2 across all regions, then a tier-3 storage pass."""
        meta = self.compact_all()
        storage = self.storage_gc_pass()
        return {"metadata": meta, "storage": storage}
