"""File-slicing surface and the client data plane — the middle layer of the
split client (see ``client.py`` for how the layers assemble).

Public surface (Table 1 plus the vectored extensions):

  * scalar: ``yank``/``paste``/``punch``/``append``/``append_slices``/
    ``concat``/``copy``;
  * vectored: ``yankv(fd, ranges)`` plans many byte ranges in one
    transaction, ``pastev(fd, batches)`` overlays many extent batches
    back-to-back at the fd offset as a single atomic op.

This module also owns the shared data-plane engine used by the POSIX layer:
range planning (``_plan_range``), batched fetching through the
``iosched.SliceScheduler`` (``_fetch``/``_fetch_many``), slice creation
(``_data_slice`` scalar, ``_data_slices`` batched through the
``wsched.WriteScheduler``), and the write/paste engines
(``_write_at``/``_writev_at``/``_paste_at``).
Writers create slices on storage servers *before* their metadata commits, so
any transaction that can observe a slice pointer can safely dereference it —
the cornerstone invariant of the design (§2.1).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from .client_runtime import _Ctx, _Op
from .errors import (InvalidOffset, KVConflict, NotFound,
                     PreconditionFailed, TransactionAborted, WtfError)
from .inode import (AppendExtents, BumpInode, ClearRegion, CompactRegion,
                    Inode, RegionData, ResetInode, region_key)
from .placement import region_placement_key, stable_hash
from .slicing import (Extent, decode_extents, merge_adjacent, overlay_cached,
                      shift, slice_resolved, split_by_regions)
from .wbuf import (extent_is_pending, extent_is_resolved,
                   pending_extent_bytes, resolve_extent)
from .wsched import StoreRequest


class SliceOps:
    """Mixin: slicing API + data-plane engine for ``WtfClient``."""

    # ============================================= public API: file slicing
    def yank(self, fd: int, size: int, want_data: bool = False):
        """Copy ``size`` bytes from fd as slice pointers (Table 1)."""
        return self._run("yank", fd, size, want_data)

    def paste(self, fd: int, extents: Sequence[Extent]) -> int:
        """Write slices to fd at its offset — metadata only, zero data I/O."""
        return self._run("paste", fd, tuple(extents))

    def punch(self, fd: int, amount: int) -> int:
        """Zero ``amount`` bytes at the offset, freeing underlying storage."""
        return self._run("punch", fd, amount)

    def append(self, fd: int, data: bytes) -> int:
        """Append with the §2.5 relative-append fast path (commutative)."""
        return self._run("append", fd, bytes(data))

    def append_slices(self, fd: int, extents: Sequence[Extent]) -> int:
        return self._run("append_slices", fd, tuple(extents))

    def concat(self, sources: Sequence[str], dest: str) -> None:
        """Concatenate files by metadata alone (Table 1)."""
        from .client_runtime import normalize_path
        return self._run("concat",
                         tuple(normalize_path(s) for s in sources),
                         normalize_path(dest))

    def copy(self, source: str, dest: str) -> None:
        from .client_runtime import normalize_path
        return self._run("copy", normalize_path(source), normalize_path(dest))

    # ----------------------------------------------- vectored slicing API
    def yankv(self, fd: int,
              ranges: Sequence[Tuple[int, int]]) -> List[Tuple[Extent, ...]]:
        """Plan many ``(offset, length)`` ranges of fd as slice pointers in
        one transaction — positional (the fd offset does not move) and
        zero data I/O.  Returns one extent tuple per requested range."""
        return list(self._run("yankv", fd,
                              tuple((int(o), int(n)) for o, n in ranges)))

    def pastev(self, fd: int,
               batches: Sequence[Sequence[Extent]]) -> int:
        """Overlay many extent batches back-to-back at the fd offset as one
        atomic op; advances the offset past everything pasted and returns
        the total byte count.  Replaces N scalar ``paste`` calls with one
        logged op — one transaction, one replay unit, O(regions) commit."""
        return self._run("pastev", fd,
                         tuple(tuple(b) for b in batches))

    # ============================================================ op bodies
    def _op_yank(self, ctx: _Ctx, op: _Op, fd: int, size: int,
                 want_data: bool):
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        size = min(size, max(0, length - f.offset))
        extents = self._plan_range(ctx, ino, f.offset, size)
        data = None
        if want_data:
            data = self._fetch(extents, inode_id=ino.inode_id)
            if type(data) is not bytes:
                data = bytes(data)     # user-facing yank payload
            self.stats.add(logical_bytes_read=size)
        f.offset += size
        extents = tuple(extents)
        return (extents, data) if want_data else extents

    def _op_yankv(self, ctx: _Ctx, op: _Op, fd: int,
                  ranges: Tuple[Tuple[int, int], ...]):
        _, plans = self._clamped_plans(ctx, fd, ranges)
        self.stats.add(vectored_ops=1)
        return tuple(tuple(p) for p in plans)

    def _clamped_plans(self, ctx: _Ctx, fd: int,
                       ranges: Sequence[Tuple[int, int]]):
        """Shared readv/yankv prologue: EOF-clamp every range exactly like
        scalar ``pread``, then plan them all with one overlay resolution
        per region.  Rejects negative offsets/sizes (EINVAL-style) instead
        of producing undefined plans.  Returns (fd record, plans)."""
        f = self._get_fd(fd)          # EBADF before EINVAL, like POSIX
        for off, size in ranges:
            if off < 0 or size < 0:
                raise InvalidOffset(
                    f"negative range ({off}, {size}) in vectored read plan")
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        clamped = [(off, min(size, max(0, length - off)))
                   for off, size in ranges]
        return f, self._plan_many_cached(ctx, ino, clamped)

    def _op_paste(self, ctx: _Ctx, op: _Op, fd: int,
                  extents: Tuple[Extent, ...]) -> int:
        f = self._get_wfd(fd)
        n = self._paste_at(ctx, f.inode_id, f.offset,
                           self._realize_app_extents(extents))
        f.offset += n
        self.stats.add(logical_bytes_written=n)
        return n

    def _op_pastev(self, ctx: _Ctx, op: _Op, fd: int,
                   batches: Tuple[Tuple[Extent, ...], ...]) -> int:
        f = self._get_wfd(fd)
        flat = [e for batch in batches for e in batch]
        n = self._paste_at(ctx, f.inode_id, f.offset,
                           self._realize_app_extents(flat))
        f.offset += n
        self.stats.add(logical_bytes_written=n, vectored_ops=1)
        return n

    def _op_punch(self, ctx: _Ctx, op: _Op, fd: int, amount: int) -> int:
        f = self._get_wfd(fd)
        ino = self._inode(ctx, f.inode_id)
        max_r = -1
        for r, rel, _, ln in split_by_regions(f.offset, amount,
                                              ino.region_size):
            self._commute_region_append(ctx, ino.inode_id, r,
                                        AppendExtents([Extent(rel, ln, ())]))
            max_r = max(max_r, r)
        self._bump(ctx, ino.inode_id, op, max_region=max_r)
        f.offset += amount
        return amount

    def _op_append(self, ctx: _Ctx, op: _Op, fd: int, data: bytes) -> int:
        f = self._get_wfd(fd)
        return self._append_fd(ctx, op, f, data)

    def _append_fd(self, ctx: _Ctx, op: _Op, f, data: bytes) -> int:
        """Append ``data`` at the file's current EOF — shared by the
        ``append`` op and by ``write``/``writev`` on O_APPEND fds."""
        ino = self._inode(ctx, f.inode_id)
        last = max(ino.max_region, 0)
        # Unvalidated fit check: the commit-time bound precondition is the
        # real guard, so concurrent appends carry no read dependency (§2.5).
        rd = ctx.txn.peek("regions", region_key(ino.inode_id, last),
                          RegionData())
        if rd.end + len(data) <= ino.region_size:
            # Fast path (§2.5): commutative bounded append — resolved against
            # the region's end at commit time, so concurrent appends all
            # commit without conflicting.  The peek above already counted
            # the region's overlay entries, so pass that down rather than
            # paying a second KV read for the compaction-threshold check.
            full = self._data_slice(ctx, op, ino, last, data, key="a")
            self._commute_region_append(
                ctx, ino.inode_id, last,
                AppendExtents([Extent(0, len(data), full.ptrs)],
                              relative=True, bound=ino.region_size),
                base_hint=len(rd.entries))
            self._bump(ctx, ino.inode_id, op, max_region=last)
        else:
            # Fallback: read end-of-file and write at that offset (§2.5);
            # a replay reuses the already-written slice ("paste the
            # previously written slice at the new end of file").
            eof = self._file_length(ctx, ino)
            self._write_at(ctx, op, ino.inode_id, eof, data, key="a")
        self.stats.add(logical_bytes_written=len(data))
        return len(data)

    def _op_append_slices(self, ctx: _Ctx, op: _Op, fd: int,
                          extents: Tuple[Extent, ...]) -> int:
        f = self._get_wfd(fd)
        ino = self._inode(ctx, f.inode_id)
        eof = self._file_length(ctx, ino)
        n = self._paste_at(ctx, f.inode_id, eof,
                           self._realize_app_extents(extents))
        self.stats.add(logical_bytes_written=n)
        return n

    def _op_concat(self, ctx: _Ctx, op: _Op, sources: Tuple[str, ...],
                   dest: str) -> None:
        cursor = 0
        if ctx.txn.get("paths", dest) is None:
            self._create_file(ctx, op, dest, None)
        dest_ino = self._inode_at(ctx, dest)
        for src in sources:
            ino = self._inode_at(ctx, src)
            length = self._file_length(ctx, ino)
            extents = self._plan_range(ctx, ino, 0, length)
            cursor += self._paste_at(ctx, dest_ino.inode_id, cursor, extents)
        self.stats.add(logical_bytes_written=cursor)

    def _op_copy(self, ctx: _Ctx, op: _Op, source: str, dest: str) -> None:
        return self._op_concat(ctx, op, (source,), dest)

    # ------------------------------------------------------------ internals
    def _inode(self, ctx: _Ctx, inode_id: int) -> Inode:
        # get_view: BumpInode commutes queued earlier in this transaction
        # (e.g. a paste growing max_region) must be visible to later ops.
        ino = ctx.txn.get_view("inodes", inode_id)
        if ino is None:
            raise NotFound(f"inode {inode_id}")
        return ino

    def _inode_at(self, ctx: _Ctx, path: str) -> Inode:
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        return self._inode(ctx, ino_id)

    def _commute_region_append(self, ctx: _Ctx, inode_id: int, region: int,
                               append_op: AppendExtents,
                               base_hint: Optional[int] = None) -> None:
        """Queue a region-list append, piggybacking a commit-time compaction
        (``CompactRegion``) when the overlay list has outgrown the cluster
        threshold.

        The length check is an unvalidated snapshot read plus a count of
        this transaction's queued extents — it records NO read dependency
        and, unlike ``peek``, never materializes the queued view (a
        multi-op transaction hammering one region would otherwise re-apply
        its whole commute chain per call).  Triggering (or not) can never
        make appends conflict (§2.5), and the op re-checks the threshold
        at commit time, so a stale estimate only costs a no-op.  One
        compaction per (transaction, region) is enough: it runs at its
        queue position and the threshold keeps post-compaction growth
        bounded until the next committing writer.

        ``base_hint`` lets a caller that just peeked the region (the
        append fast path) supply its entry count, saving the snapshot
        read; a hint that includes this transaction's queued extents only
        *over*estimates, which at worst queues a compaction early — the
        same harmless no-op as any stale estimate."""
        txn = ctx.txn
        rk = region_key(inode_id, region)
        txn.commute("regions", rk, append_op)
        thr = self.cluster.region_compact_threshold
        if thr is None:
            return
        queued = 0
        for entry in txn._commutes_by_key.get(("regions", rk), ()):
            cop = entry[2]
            if isinstance(cop, CompactRegion):
                return                       # one per (txn, region)
            if isinstance(cop, AppendExtents):
                queued += len(cop.extents)
            elif isinstance(cop, ClearRegion):
                queued = 0
        sk = ("regions", rk)
        if sk in txn._writes:                # rare (GC-style raw put)
            rd = txn.peek("regions", rk)
            base = len(rd.entries) if rd is not None else 0
            queued = 0                       # peek already applied the queue
        elif base_hint is not None:
            base = base_hint
        else:
            _, val = self.kv._read_versioned("regions", rk)
            base = len(val.entries) if val is not None else 0
        if base + queued >= thr:
            txn.commute("regions", rk, CompactRegion(thr))

    def _bump(self, ctx: _Ctx, inode_id: int, op: _Op,
              max_region: Optional[int] = None) -> None:
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ctx.txn.commute("inodes", inode_id,
                        BumpInode(max_region=max_region, mtime=now))

    def _file_length(self, ctx: _Ctx, ino: Inode) -> int:
        if ino.max_region < 0:
            return 0
        rd = ctx.txn.get_view("regions",
                              region_key(ino.inode_id, ino.max_region),
                              RegionData())
        return ino.max_region * ino.region_size + rd.end

    def _region_entries(self, ctx: _Ctx, ino: Inode,
                        region_idx: int) -> list[Extent]:
        rd = ctx.txn.get_view("regions",
                              region_key(ino.inode_id, region_idx))
        if rd is None:
            return ()
        if rd.indirect is None:
            # return the stored tuple itself: `overlay_cached` memoizes on
            # it, so repeated reads of an unchanged region plan in O(1)
            return rd.entries
        # Tier-2 GC: the bulk of the list lives in a slice (§2.8).
        base = decode_extents(self._fetch([rd.indirect]))
        return tuple(base) + tuple(rd.entries)

    def _resolve_region(self, ctx: _Ctx, ino: Inode,
                        region_idx: int) -> Sequence[Extent]:
        """Resolved overlay of one region, via the client's delta-maintained
        resolved index (``slicing.ResolvedIndexCache``) when available.

        Region lists only grow between compactions and WarpKV appends
        extend the stored tuple in place, so a hot region's re-read costs
        O(appended delta) instead of O(full write history).  Any wholesale
        replacement (compaction, truncate, GC) fails the cache's identity
        check and re-resolves; entries carrying write-behind pending
        placeholders bypass the index entirely.

        Tier-2 spilled regions (§2.8 ``indirect``) rebuild their entry
        tuple from freshly-decoded extents on every read, so the identity
        check could never hit — they stay on ``overlay_cached``, whose
        equality-based memoization serves them in one tuple hash."""
        rd = ctx.txn.get_view("regions",
                              region_key(ino.inode_id, region_idx))
        if rd is None:
            return ()
        cache = self._rcache
        if rd.indirect is not None or cache is None \
                or not isinstance(rd.entries, tuple):
            return overlay_cached(self._region_entries(ctx, ino, region_idx))
        return cache.resolve((ino.inode_id, region_idx), rd.entries,
                             stats=self.stats)

    def _plan_range(self, ctx: _Ctx, ino: Inode, offset: int,
                    length: int) -> list[Extent]:
        """File-absolute extents (incl. zero runs) tiling [offset, +length)."""
        out: list[Extent] = []
        for r, rel, _, ln in split_by_regions(offset, length,
                                              ino.region_size):
            part = slice_resolved(self._resolve_region(ctx, ino, r), rel, ln)
            out.extend(shift(part, r * ino.region_size))
        return merge_adjacent(out)

    def _plan_many(self, ctx: _Ctx, ino: Inode,
                   ranges: Sequence[Tuple[int, int]]) -> List[List[Extent]]:
        """Plan many ranges, resolving each touched region's overlay once.

        The per-op ``resolved`` map keeps vectored planning O(ranges log n)
        (one resolution per region per op); the per-client resolved index
        behind ``_resolve_region`` keeps that one resolution O(delta) for
        hot regions across ops."""
        resolved: dict = {}
        plans: List[List[Extent]] = []
        for offset, length in ranges:
            out: list[Extent] = []
            for r, rel, _, ln in split_by_regions(offset, length,
                                                  ino.region_size):
                res = resolved.get(r)
                if res is None:
                    res = self._resolve_region(ctx, ino, r)
                    resolved[r] = res
                part = slice_resolved(res, rel, ln)
                out.extend(shift(part, r * ino.region_size))
            plans.append(merge_adjacent(out))
        return plans

    def _plan_many_cached(self, ctx: _Ctx, ino: Inode,
                          ranges: Sequence[Tuple[int, int]]
                          ) -> List[List[Extent]]:
        """``_plan_many`` behind the version-validated read-plan cache.

        A hot re-read of the same ``(inode, ranges)`` skips overlay
        resolution entirely when every touched region still carries the
        KV version the plan was built against; validation records the
        same read dependencies a fresh plan would, so a hit is exactly as
        serializable as a miss.  Any commit whose commutes touched a
        region bumped its version — that IS the invalidation rule.

        Bypassed (like ``overlay_cached``) whenever this transaction could
        see state no other transaction can: queued commutes or buffered
        writes, or pending write-behind extents in the plan.
        """
        cache = getattr(self, "_plan_cache", None)
        txn = ctx.txn
        if (cache is None or txn._commutes or txn._writes
                or self._wb.pending):
            return self._plan_many(ctx, ino, ranges)
        key = (ino.inode_id, tuple(ranges))
        entry = cache.get(key)
        if entry is not None:
            versions, plans = entry
            if all(txn.get_version("regions", rk) == ver
                   for rk, ver in versions):
                self.stats.add(plan_cache_hits=1)
                return [list(p) for p in plans]
            # An invalidating commit moved a touched region's version:
            # the inode's plans AND its cached data blocks die together
            # (the shared invalidation rule — see ``blockcache``).  The
            # stale blocks were unreachable anyway (new plans carry new
            # pointers); eviction keeps both LRUs useful.
            cache.drop_inode(ino.inode_id)
            bc = getattr(self, "_block_cache", None)
            if bc is not None:
                bc.drop_inode(ino.inode_id)
        regions = sorted({
            r for off, ln in ranges
            for r, _, _, _ in split_by_regions(off, ln, ino.region_size)})
        plans = self._plan_many(ctx, ino, ranges)
        if any(extent_is_pending(e) for p in plans for e in p):
            return plans               # never cache pending extents
        versions = tuple(
            (region_key(ino.inode_id, r),
             txn.get_version("regions", region_key(ino.inode_id, r)))
            for r in regions)
        if all(ver is not None for _, ver in versions):
            cache.put(key, (versions, tuple(tuple(p) for p in plans)))
            self.stats.add(plan_cache_misses=1)
        return plans

    def _read_range(self, ctx: _Ctx, ino: Inode, offset: int,
                    length: int) -> bytes:
        if length <= 0:
            return b""
        data = self._fetch(self._plan_range(ctx, ino, offset, length),
                           inode_id=ino.inode_id)
        # The scalar boundary: internal fetch paths hand around zero-copy
        # buffers; scalar read/pread (and ``_dir_entries``) promise bytes.
        return data if type(data) is bytes else bytes(data)

    def _fetch(self, extents: Sequence[Extent], inode_id=None) -> bytes:
        """Dereference pointers through the batched scheduler (replica-
        failover aware, §2.9); pending write-behind extents are served from
        the buffer's memory (read-your-buffered-writes)."""
        return self._fetch_many([extents], inode_id=inode_id)[0]

    def _fetch_many(self, plans: Sequence[Sequence[Extent]],
                    inode_id=None) -> List[bytes]:
        """Dereference many plans in one scheduler pass: cross-plan
        coalescing plus per-server fan-out.

        Pending-write overlay: while the write-behind buffer holds deferred
        stores, plan extents whose pointers are still pending never reach
        the scheduler — their bytes come straight from the buffered
        payloads, so reads inside the transaction observe its own writes.

        Every call that actually dispatches storage rounds counts one
        ``blocked_waits``: a synchronous fetch blocks the application by
        definition (the async surface's waits count only when the future
        was not yet done — the overlap the runtime exists to create)."""
        if any(not e.is_zero and not extent_is_pending(e)
               for p in plans for e in p):
            self.stats.add(blocked_waits=1)
        bc = self._block_cache
        if not self._wb.pending:
            return self.cluster.scheduler.fetch_many(
                plans, stats=self.stats, block_cache=bc, inode_id=inode_id)
        parts: List[List[bytes]] = [[b""] * len(p) for p in plans]
        sched_plans: List[List[Extent]] = []
        slots: List[tuple] = []
        for pi, plan in enumerate(plans):
            for ci, e in enumerate(plan):
                if extent_is_pending(e):
                    parts[pi][ci] = pending_extent_bytes(e)
                else:
                    sched_plans.append([e])
                    slots.append((pi, ci))
        if sched_plans:
            # Pending extents above never reach the scheduler (served from
            # the write-behind buffer), so they structurally bypass the
            # block cache; committed extents in the same plan still use it.
            datas = self.cluster.scheduler.fetch_many(sched_plans,
                                                      stats=self.stats,
                                                      block_cache=bc,
                                                      inode_id=inode_id)
            for (pi, ci), data in zip(slots, datas):
                parts[pi][ci] = data
        return [b"".join(p) for p in parts]

    def _realize_app_extents(self, extents: Sequence[Extent]) -> list:
        """Normalize application-supplied extents (paste/append_slices):
        pending pointers that already flushed become their real replicated
        pointers; unresolved ones are legal only while this client's buffer
        is still open (they will be rewritten at the commit flush)."""
        out = []
        for e in extents:
            if extent_is_pending(e):
                if extent_is_resolved(e):
                    e = resolve_extent(e)
                elif not self._wb.owns(e):
                    # a dead pointer (aborted scope, or another client's
                    # buffer) must fail HERE, not poison this commit's flush
                    raise WtfError(
                        "extent references an unflushed write-behind "
                        "buffer from another commit scope")
            out.append(e)
        return out

    def _data_slice(self, ctx: _Ctx, op: _Op, ino: Inode, region: int,
                    data: bytes, key: str,
                    defer: Optional[bool] = None) -> Extent:
        """Create one (replicated) slice for ``data``, placed for ``region``.

        Created on first execution only; replays reuse the recorded pointers
        verbatim — the §2.6 op log holds slice pointers, never data.  A write
        that crosses a region boundary stays a *single* slice; each region's
        list gets a sub-ranged pointer (Figure 3, write C).

        ``defer`` overrides the live write-behind check: async op bodies run
        on pool threads and must not consult (or touch) the application
        thread's buffer, so they pin the decision at submission time.
        """
        cached = op.artifacts.get(key)
        if cached is not None:
            return cached
        if defer is None:
            defer = self._write_behind_active()
        if defer:
            # Deferred: record the payload; the store happens at the commit
            # flush, batched with every other op in this commit scope.
            pk = region_placement_key(ino.inode_id, region)
            ext = self._wb.add(pk, stable_hash(pk), data, op_tag=id(op))
            op.artifacts[key] = ext
            return ext
        hint = stable_hash(region_placement_key(ino.inode_id, region))
        ptrs = self.cluster.store_slice(
            data, region_placement_key(ino.inode_id, region), hint)
        self.stats.add(data_bytes_written=len(data) * len(ptrs),
                       store_batches=len(ptrs))  # one round per replica store
        if len(ptrs) < self.cluster.replication:
            self.stats.add(degraded_stores=1)
        ext = Extent(0, len(data), ptrs)
        op.artifacts[key] = ext
        return ext

    def _data_slices(self, ctx: _Ctx, op: _Op, ino: Inode,
                     pieces: Sequence[Tuple[int, bytes]],
                     key: str,
                     defer: Optional[bool] = None) -> Tuple[Extent, ...]:
        """Create (replicated) slices for many ``(region, data)`` pieces as
        ONE scheduled store batch (``wsched``): all stores are planned up
        front, grouped per (server, backing file), small adjacent pieces
        coalesce into covering stores, and distinct servers are written
        concurrently.  Created on first execution only; replays reuse the
        recorded extents verbatim, exactly like ``_data_slice`` (§2.6).
        ``defer`` pins the write-behind decision (see ``_data_slice``).
        """
        cached = op.artifacts.get(key)
        if cached is not None:
            return cached
        if defer is None:
            defer = self._write_behind_active()
        if defer:
            exts = []
            for region, data in pieces:
                pk = region_placement_key(ino.inode_id, region)
                exts.append(self._wb.add(pk, stable_hash(pk), data,
                                         op_tag=id(op)))
            exts = tuple(exts)
            op.artifacts[key] = exts
            return exts
        requests = []
        for i, (region, data) in enumerate(pieces):
            pk = region_placement_key(ino.inode_id, region)
            requests.append(StoreRequest(i, data, pk, stable_hash(pk)))
        ptrs = self.cluster.store_slices(requests, stats=self.stats)
        exts = tuple(Extent(0, len(data), ptrs[i])
                     for i, (_, data) in enumerate(pieces))
        op.artifacts[key] = exts
        return exts

    def _writev_at(self, ctx: _Ctx, op: _Op, inode_id: int, offset: int,
                   chunks: Sequence[bytes], key: str,
                   defer: Optional[bool] = None) -> int:
        """Vectored write engine: plan one store per (chunk, region) piece,
        dispatch the whole plan through the write scheduler, then queue each
        region's extents as one AppendExtents.  Pieces of one region share a
        placement group, so a many-chunk gather-write still lands as a
        single covering slice per region (one store round), while a write
        spanning regions fans out across the ring in parallel."""
        ino = self._inode(ctx, inode_id)
        pieces: list[Tuple[int, int, bytes]] = []   # (region, rel, data)
        cursor = offset
        for chunk in chunks:
            for r, rel, po, ln in split_by_regions(cursor, len(chunk),
                                                   ino.region_size):
                pieces.append((r, rel, chunk[po:po + ln]))
            cursor += len(chunk)
        exts = self._data_slices(ctx, op, ino,
                                 [(r, d) for r, _, d in pieces], key,
                                 defer=defer)
        max_r = ino.max_region
        per_region: dict[int, list[Extent]] = {}
        for (r, rel, _), ext in zip(pieces, exts):
            per_region.setdefault(r, []).append(ext.at(rel))
            max_r = max(max_r, r)
        for r, items in per_region.items():
            self._commute_region_append(ctx, inode_id, r,
                                        AppendExtents(items))
        self._bump(ctx, inode_id, op, max_region=max_r)
        total = cursor - offset
        self.stats.add(logical_bytes_written=total)
        return total

    def _write_at(self, ctx: _Ctx, op: _Op, inode_id: int, offset: int,
                  data: bytes, key: str) -> int:
        ino = self._inode(ctx, inode_id)
        first_region = offset // ino.region_size
        full = self._data_slice(ctx, op, ino, first_region, data, key)
        max_r = ino.max_region
        for r, rel, po, ln in split_by_regions(offset, len(data),
                                               ino.region_size):
            self._commute_region_append(
                ctx, inode_id, r, AppendExtents([full.sub(po, ln).at(rel)]))
            max_r = max(max_r, r)
        self._bump(ctx, inode_id, op, max_region=max_r)
        self.stats.add(logical_bytes_written=len(data))
        return len(data)

    def _paste_at(self, ctx: _Ctx, inode_id: int, offset: int,
                  extents: Sequence[Extent]) -> int:
        """Overlay existing slices at ``offset`` — pure metadata, no I/O.

        Pieces are grouped per region and queued as ONE AppendExtents per
        region: queueing them one-by-one made the commute-coalescing path
        rebuild its extent tuple per piece — O(n²) for a bulk ``pastev``.
        Cursor order is preserved inside each group, so overlay precedence
        is identical."""
        ino = self._inode(ctx, inode_id)
        cursor = offset
        max_r = ino.max_region
        per_region: dict[int, list[Extent]] = {}
        for e in extents:
            consumed = 0
            while consumed < e.length:
                r = cursor // ino.region_size
                rel = cursor - r * ino.region_size
                take = min(e.length - consumed, ino.region_size - rel)
                per_region.setdefault(r, []).append(
                    e.sub(consumed, take).at(rel))
                max_r = max(max_r, r)
                cursor += take
                consumed += take
        for r, pieces in per_region.items():
            self._commute_region_append(ctx, inode_id, r,
                                        AppendExtents(pieces))
        op = _Op("paste_internal", (), {})
        self._bump(ctx, inode_id, op, max_region=max_r)
        return cursor - offset

    # ------------------------------------------------------ async op bodies
    # Worker-thread engines behind the futures surface (``posix_ops``
    # submits them to the cluster's ``IoRuntime``).  They never touch the
    # fd table, the op log, or the write-behind buffer — everything
    # fd-dependent is resolved on the application thread at submission —
    # so they are safe to run concurrently with the application's own ops.

    def _async_readv_body(self, inode_id: int,
                          ranges: Tuple[Tuple[int, int], ...]) -> List[bytes]:
        """Plan + fetch for an async vectored read, on a pool worker.

        Planning happens HERE, at execution time, not at submission: a
        commit that lands while the future is still queued bumps the
        touched region versions, so the plan (cached or fresh) is built
        against — and validated against — the post-commit state.  A stale
        cached plan can never be served; the version check re-plans it.
        The planning transaction commits (validating its read versions)
        before any data round is issued, so the bytes returned are a
        serializable snapshot.
        """
        last: Optional[Exception] = None
        for attempt in range(self.MAX_RETRIES):
            if attempt:
                self.stats.add(txn_retries=1)
            ctx = _Ctx(self._begin_txn(), first=(attempt == 0))
            try:
                ino = self._inode(ctx, inode_id)
                length = self._file_length(ctx, ino)
                clamped = [(off, min(size, max(0, length - off)))
                           for off, size in ranges]
                plans = self._plan_many_cached(ctx, ino, clamped)
                ctx.txn.commit()
            except (KVConflict, PreconditionFailed) as e:
                last = e
                continue
            # Slices are immutable, so fetching after the metadata commit
            # is safe; rounds issued from a worker run inline (iort).
            out = self.cluster.scheduler.fetch_many(
                plans, stats=self.stats, block_cache=self._block_cache,
                inode_id=inode_id)
            self.stats.add(logical_bytes_read=sum(len(b) for b in out),
                           vectored_ops=1)
            return out
        self.stats.add(txn_aborts=1)
        raise TransactionAborted(
            f"async readv failed after {self.MAX_RETRIES} attempts: {last}")

    def _async_pwritev_body(self, inode_id: int,
                            chunks: Tuple[bytes, ...], offset: int) -> int:
        """Store + metadata commit for an async gather-write, on a worker.

        The §2.1 order holds: slices are durable (through the write
        scheduler) before the metadata commit; KV-level aborts retry with
        the op's recorded artifacts, so data is never stored twice.
        ``defer=False`` pins the write-behind decision made at submission —
        a worker must never touch the application thread's buffer.
        """
        op = _Op("pwritev_async", (), {})
        last: Optional[Exception] = None
        try:
            for attempt in range(self.MAX_RETRIES):
                if attempt:
                    self.stats.add(txn_retries=1)
                ctx = _Ctx(self._begin_txn(), first=(attempt == 0))
                try:
                    n = self._writev_at(ctx, op, inode_id, offset, chunks,
                                        key="wv", defer=False)
                    ctx.txn.commit()
                    self.stats.add(vectored_ops=1)
                    return n
                except (KVConflict, PreconditionFailed) as e:
                    last = e
                    continue
        finally:
            # commit or give-up: the GC handoff window for the slices this
            # worker stored is closed (retries reuse them, so only here).
            self._release_handoffs((op,))
        self.stats.add(txn_aborts=1)
        raise TransactionAborted(
            f"async pwritev failed after {self.MAX_RETRIES} attempts: {last}")

    def _truncate_inode(self, ctx: _Ctx, ino: Inode, length: int) -> None:
        """Truncate to zero via commit-time commutes (``ClearRegion`` /
        ``ResetInode``) so queue order decides what survives: writes queued
        earlier in the same transaction are wiped, later ones kept.  The
        caller must pass the *view* inode (``_inode``) so regions grown by
        this transaction's own queued writes are cleared too."""
        if length != 0:
            raise WtfError("only truncate-to-zero is supported")
        for r in range(ino.max_region + 1):
            ctx.txn.commute("regions", region_key(ino.inode_id, r),
                            ClearRegion())
        ctx.txn.commute("inodes", ino.inode_id, ResetInode(self.time_fn()))
