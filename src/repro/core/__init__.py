"""WTF core — the paper's contribution (Escriva & Sirer, 2015).

Architecture (paper Figure 1): metadata storage (`metadata.WarpKV`), storage
servers (`storage.StorageServer`), a replicated coordinator
(`coordinator.ReplicatedCoordinator`), and the client library
(`client.WtfClient`) which combines them into a transactional filesystem with
the file-slicing API (`yank`/`paste`/`punch`/`append`/`concat`/`copy`).
"""
from .client import (SEEK_CUR, SEEK_END, SEEK_SET, Cluster, WtfClient,
                     WtfTransaction, normalize_path)
from .client_runtime import ClientStats
from .coordinator import ReplicatedCoordinator
from .errors import (AlreadyExists, BadFileDescriptor, DeadlineExceeded,
                     DegradedRead, InvalidOffset, IsADirectory, KVConflict,
                     NoQuorum, NotADirectory, NotFound, NotOpenForWriting,
                     PreconditionFailed, ReplicaExhausted, StorageError,
                     TransactionAborted, WtfError)
from .gc import GarbageCollector
from .handle import WtfFile
from .inode import DEFAULT_REGION_SIZE, Inode, RegionData
from .iort import HealthTracker, IoFuture, IoRuntime, IoTask, PlanCache
from .repair import RepairDaemon, RepairQueue, RepairStats, RepairTicket
from .iosched import SliceScheduler
from .wbuf import PendingPtr, WriteBehindBuffer
from .wsched import StoreRequest, WriteScheduler
from .lease import LeaseHub, LeaseStats, LeaseTable
from .mdshard import MdShardStats, PhaseCrash, ShardedKV
from .metadata import CommutingOp, ListAppend, Transaction, WarpKV
from .placement import HashRing, stable_hash
from .slicing import (Extent, SlicePointer, compact, decode_extents,
                      encode_extents, merge_adjacent, overlay, slice_range,
                      split_by_regions)
from .storage import StorageServer
from .wlog import LogConsumer, LogProducer, WtfLog

__all__ = [
    "Cluster", "WtfClient", "WtfTransaction", "WtfFile", "ClientStats",
    "SliceScheduler", "WriteScheduler", "StoreRequest",
    "IoRuntime", "IoFuture", "IoTask", "PlanCache",
    "WriteBehindBuffer", "PendingPtr",
    "WarpKV", "StorageServer",
    "WtfLog", "LogProducer", "LogConsumer",
    "ShardedKV", "MdShardStats", "PhaseCrash",
    "LeaseHub", "LeaseTable", "LeaseStats",
    "ReplicatedCoordinator", "GarbageCollector", "HashRing",
    "Extent", "SlicePointer", "Inode", "RegionData",
    "compact", "overlay", "slice_range", "merge_adjacent",
    "encode_extents", "decode_extents", "split_by_regions",
    "stable_hash", "normalize_path",
    "SEEK_SET", "SEEK_CUR", "SEEK_END", "DEFAULT_REGION_SIZE",
    "WtfError", "TransactionAborted", "KVConflict", "PreconditionFailed",
    "NotFound", "AlreadyExists", "NotADirectory", "IsADirectory",
    "BadFileDescriptor", "NotOpenForWriting", "InvalidOffset",
    "StorageError", "DegradedRead", "ReplicaExhausted", "DeadlineExceeded",
    "NoQuorum",
    "HealthTracker",
    "RepairDaemon", "RepairQueue", "RepairStats", "RepairTicket",
    "CommutingOp", "ListAppend", "Transaction",
]
