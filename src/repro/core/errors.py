"""Exception hierarchy for the WTF reproduction."""
from __future__ import annotations


class WtfError(Exception):
    """Base class for all WTF errors."""


class TransactionAborted(WtfError):
    """Raised to the application when a transaction hit an unresolvable,
    application-visible conflict (paper §2.6)."""


class KVConflict(WtfError):
    """Internal: optimistic validation failed inside the metadata store.

    This is the HyperDex-level abort. It is *not* surfaced to applications;
    the retry layer catches it and replays the op log (§2.6)."""


class PreconditionFailed(WtfError):
    """Internal: a commutative operation's precondition failed at commit time
    (e.g. a bounded append no longer fits in its region, §2.5)."""


class NotFound(WtfError):
    """Pathname or object does not exist."""


class AlreadyExists(WtfError):
    """Pathname already exists."""


class NotADirectory(WtfError):
    """Path component is not a directory."""


class IsADirectory(WtfError):
    """File operation attempted on a directory."""


class DirectoryNotEmpty(WtfError):
    """rmdir on a non-empty directory."""


class BadFileDescriptor(WtfError):
    """Operation on a closed or invalid fd."""


class NotOpenForWriting(BadFileDescriptor):
    """Write-side operation on an fd opened read-only (EBADF-style: POSIX
    write(2) reports EBADF for fds not open for writing)."""


class InvalidOffset(WtfError):
    """A file offset resolved to a negative position (EINVAL-style, matching
    lseek(2)/pread(2) on negative offsets)."""


class StorageError(WtfError):
    """A storage server failed to create or retrieve a slice."""


class NoQuorum(WtfError):
    """The replicated coordinator lost its quorum."""
