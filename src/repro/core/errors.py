"""Exception hierarchy for the WTF reproduction."""
from __future__ import annotations


class WtfError(Exception):
    """Base class for all WTF errors."""


class TransactionAborted(WtfError):
    """Raised to the application when a transaction hit an unresolvable,
    application-visible conflict (paper §2.6)."""


class KVConflict(WtfError):
    """Internal: optimistic validation failed inside the metadata store.

    This is the HyperDex-level abort. It is *not* surfaced to applications;
    the retry layer catches it and replays the op log (§2.6)."""


class PreconditionFailed(WtfError):
    """Internal: a commutative operation's precondition failed at commit time
    (e.g. a bounded append no longer fits in its region, §2.5)."""


class NotFound(WtfError):
    """Pathname or object does not exist."""


class AlreadyExists(WtfError):
    """Pathname already exists."""


class NotADirectory(WtfError):
    """Path component is not a directory."""


class IsADirectory(WtfError):
    """File operation attempted on a directory."""


class DirectoryNotEmpty(WtfError):
    """rmdir on a non-empty directory."""


class BadFileDescriptor(WtfError):
    """Operation on a closed or invalid fd."""


class NotOpenForWriting(BadFileDescriptor):
    """Write-side operation on an fd opened read-only (EBADF-style: POSIX
    write(2) reports EBADF for fds not open for writing)."""


class InvalidOffset(WtfError):
    """A file offset resolved to a negative position (EINVAL-style, matching
    lseek(2)/pread(2) on negative offsets)."""


class StorageError(WtfError):
    """A storage server failed to create or retrieve a slice.

    Failure-domain taxonomy (§2.9 + the repair plane) — all three subtypes
    below are ``StorageError``s, so handlers written against the generic
    data-plane failure keep working while callers that care can match the
    precise condition:

    ``StorageError``
      ├── ``DegradedRead``       read blocked by the ``min_read_replicas``
      │     │                    floor: the extent still has live replicas,
      │     │                    just fewer than the cluster requires
      │     └── ``ReplicaExhausted``
      │                          zero replicas could serve — every candidate
      │                          was dead, circuit-broken, or erroring
      └── ``DeadlineExceeded``   one replica round overran the per-round
                                 ``Cluster(io_deadline_s=...)`` budget and
                                 was abandoned (the hedge/failover walk
                                 decides what happens next)
    """


class DegradedRead(StorageError):
    """A read found fewer live replicas than ``Cluster(min_read_replicas)``
    requires.  The data is (still) readable from the surviving replicas —
    this is a policy refusal, raised so callers that demand full redundancy
    before trusting a read can tell "degraded" apart from "gone"."""


class ReplicaExhausted(DegradedRead):
    """Every replica of an extent failed to serve: the candidate walk ran
    out of live servers (§2.9).  The strongest degraded-read signal — zero
    live copies reachable right now — and what ``run_with_failover`` raises
    on exhaustion instead of a bare ``StorageError``."""


class DeadlineExceeded(StorageError):
    """A single replica round exceeded ``Cluster(io_deadline_s=...)`` and
    was abandoned.  Surfaced to the application only when every candidate
    timed out or failed; otherwise it is recorded against the slow server's
    health and the walk moves on."""


class NoQuorum(WtfError):
    """The replicated coordinator lost its quorum."""
