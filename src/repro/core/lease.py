"""Leased client-side metadata caching (FaaSFS-style, arXiv 2009.09845).

The WTF client's hot metadata reads — path lookups, inode fetches, region
version checks for the plan cache — are exactly the traffic that makes an
"idle-hot" client keep round-tripping to the metadata store.  This module
lets clients hold *leases* on recently-read keys:

  * ``LeaseTable`` — one per client.  A lease caches ``(version, value)``
    for a ``(space, key)`` pair, bounded in time (the cluster's
    ``lease_ttl``) and in version (any committed change revokes it).
    ``Transaction`` serves reads from valid leases with zero KV round
    trips, and a read-only transaction whose whole read set is
    lease-covered *commits* without touching the KV: it revalidates its
    leases atomically against the table and skips ``_commit`` entirely.

  * ``LeaseHub`` — one per cluster.  It wires revocation: a pre-apply
    **invalidation barrier** registered on every shard fires under the
    commit's stripe locks, before the first store, killing leases (and
    in-flight grants) for every key about to change; the per-shard WAL
    subscribe stream additionally piggybacks shared-plan-cache eviction,
    dropping cached I/O plans for any inode whose region metadata moved.

Why the barrier must run *before* the stores: suppose writer W commits
{A=a2, B=b2} and reader R holds leases {A@a1, B@b1}.  If revocation trailed
the stores, R could read B=b2 fresh (store visible) while its lease on A
still looked valid — revalidation would pass and R would commit the
non-serializable snapshot {a1, b2}.  With the barrier, both leases are dead
before *either* store is visible, so a successful revalidation proves R
observed no part of any in-flight commit.  The companion race — a lease
*granted* from a read that predates W but installed after W's barrier — is
closed by the two-step grant protocol: ``begin_grant`` installs a pending
placeholder **before** the KV read, the barrier kills placeholders too, and
``commit_grant`` refuses to activate a killed placeholder.

A revoked or expired lease is never an error: reads fall back to the KV,
and commit revalidation failure falls back to the normal optimistic commit
(which conflicts only if a version truly moved).  Staleness therefore
surfaces as ``KVConflict`` → the §2.6 replay, never as a stale commit.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .iort import AtomicStatsMixin
from .testing import witness_lock

# Lease states.  PENDING: placeholder installed by ``begin_grant``, value
# not yet known.  LIVE: serving reads.  A killed lease is simply removed.
_PENDING, _LIVE = 0, 1


@dataclass(slots=True)
class LeaseStats(AtomicStatsMixin):
    """Cluster-wide lease counters (all client tables report here)."""

    lease_grants: int = 0
    lease_hits: int = 0
    lease_revocations: int = 0       # live/pending leases actually killed
    lease_expirations: int = 0       # lookups that found a dead-by-TTL lease
    lease_commit_skips: int = 0      # read-only commits served sans KV
    plan_invalidations: int = 0      # shared plan-cache entries dropped
    block_invalidations: int = 0     # shared block-cache entries dropped
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False, compare=False)


class _Lease:
    __slots__ = ("state", "version", "value", "expires_at")

    def __init__(self, state: int, version: int = 0, value: Any = None,
                 expires_at: float = 0.0):
        self.state = state
        self.version = version
        self.value = value
        self.expires_at = expires_at


class LeaseTable:
    """Per-client lease cache; thread-safe (async op bodies run on pool
    workers sharing their client's table).  LRU-bounded."""

    MAX_LEASES = 4096

    def __init__(self, hub: "LeaseHub"):
        self._hub = hub
        self._lock = witness_lock(threading.Lock(), "lease.table")
        self._entries: "OrderedDict[Tuple[str, Any], _Lease]" = OrderedDict()
        hub.register(self)

    # -- read path ----------------------------------------------------------
    def lookup(self, sk: Tuple[str, Any]) -> Optional[Tuple[int, Any]]:
        """(version, value) when a live, unexpired lease covers ``sk``."""
        now = self._hub.clock()
        with self._lock:
            ent = self._entries.get(sk)
            if ent is None or ent.state is not _LIVE:
                return None
            if ent.expires_at <= now:
                del self._entries[sk]
                self._hub.stats.add(lease_expirations=1)
                return None
            self._entries.move_to_end(sk)
        self._hub.stats.add(lease_hits=1)
        return ent.version, ent.value

    # -- grant protocol -----------------------------------------------------
    def begin_grant(self, sk: Tuple[str, Any]) -> _Lease:
        """Install a pending placeholder BEFORE the KV read it will cache.
        Any writer's invalidation barrier between now and ``commit_grant``
        kills the placeholder, so a lease can never be born stale."""
        tok = _Lease(_PENDING)
        with self._lock:
            self._entries[sk] = tok
            self._entries.move_to_end(sk)
            while len(self._entries) > self.MAX_LEASES:
                self._entries.popitem(last=False)
        return tok

    def commit_grant(self, sk: Tuple[str, Any], tok: _Lease,
                     version: int, value: Any) -> bool:
        """Activate the placeholder with the value just read; returns False
        if a revocation (or a competing grant) killed it in the meantime."""
        with self._lock:
            if self._entries.get(sk) is not tok:
                return False
            tok.state = _LIVE
            tok.version = version
            tok.value = value
            tok.expires_at = self._hub.clock() + self._hub.ttl
        self._hub.stats.add(lease_grants=1)
        return True

    # -- revocation / validation --------------------------------------------
    def revoke(self, keys) -> int:
        """Kill leases (live or pending) for ``keys``; returns kills."""
        killed = 0
        with self._lock:
            for sk in keys:
                if self._entries.pop(sk, None) is not None:
                    killed += 1
        if killed:
            self._hub.stats.add(lease_revocations=killed)
        return killed

    def revalidate(self, used: Dict[Tuple[str, Any], int]) -> bool:
        """Atomically check that every ``sk → version`` in ``used`` is still
        covered by a live, unexpired lease at that exact version.  Runs
        under the table lock — the same lock revocation takes — so this is
        linearizable against the writers' invalidation barrier."""
        now = self._hub.clock()
        with self._lock:
            for sk, ver in used.items():
                ent = self._entries.get(sk)
                if ent is None or ent.state is not _LIVE \
                        or ent.version != ver or ent.expires_at <= now:
                    return False
        self._hub.stats.add(lease_commit_skips=1)
        return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class LeaseHub:
    """Cluster-side lease authority: fans writer-side invalidations out to
    every registered client table, and piggybacks shared plan-cache
    eviction on the (per-shard, fanned-in) WAL subscribe stream."""

    def __init__(self, kv, ttl: float, plan_cache=None, block_cache=None):
        self.ttl = float(ttl)
        self.clock = time.monotonic      # swappable in tests (expiry)
        self.stats = LeaseStats()
        self._plan_cache = plan_cache
        self._block_cache = block_cache
        self._tables: list[LeaseTable] = []
        self._tables_lock = witness_lock(threading.Lock(), "lease.tables")
        # Pre-apply barrier on every shard: correctness (see module doc).
        kv.add_invalidation_listener(self._invalidate)
        # WAL stream: cache hygiene.  Region mutations evict the shared
        # plan cache's entries for that inode (they could only fail their
        # version validation anyway; eviction keeps the LRU useful), and
        # the shared data-block cache's blocks WITH them — plan and blocks
        # always die together, the blockcache invalidation rule.
        if plan_cache is not None or block_cache is not None:
            kv.subscribe(self._on_wal)

    def register(self, table: LeaseTable) -> None:
        with self._tables_lock:
            self._tables.append(table)

    # Called by WarpKV._apply_staged under the commit's stripe locks,
    # before the first store of the committing transaction.
    def _invalidate(self, keys: list) -> None:
        with self._tables_lock:
            tables = list(self._tables)
        for t in tables:
            t.revoke(keys)

    def _on_wal(self, space: str, key: Any, value: Any,
                version: int) -> None:
        if space == "regions":
            if self._plan_cache is not None:
                dropped = self._plan_cache.drop_inode(key[0])
                if dropped:
                    self.stats.add(plan_invalidations=dropped)
            if self._block_cache is not None:
                dropped = self._block_cache.drop_inode(key[0])
                if dropped:
                    self.stats.add(block_invalidations=dropped)
