"""Deterministic fault injection for tests and benchmarks.

The §2.9 replication and §2.6 transactional-retry guarantees only matter if
failures can strike *mid-operation*: between planning a batch and executing
it, between one replica's store and the next, between an op body and its
commit.  The wrappers here make those windows scriptable:

  * ``FlakyStorageServer`` proxies a real ``StorageServer`` and fails the
    Nth call of a chosen API (``create_slice``/``create_slices``/
    ``retrieve_slice``/``retrieve_slices``) with ``StorageError`` —
    transiently, or crashing
    the server for good (``crash=True``) the way a real node dies.
  * ``FlakyKV`` proxies ``WarpKV`` and fails the Nth *commit* with
    ``KVConflict``, driving the §2.6 replay layer deterministically (unlike
    ``WarpKV.inject_aborts``, which always fails the very next commits).

Both wrappers delegate everything else via ``__getattr__``, so they can be
installed in place (``cluster.servers[sid] = FlakyStorageServer(...)``,
``cluster.kv = FlakyKV(...)``) and the cluster keeps working untouched.
Counters are 1-based: ``fail_on={"create_slices": {1}}`` fails the first
call.  Clients capture ``cluster.kv`` at construction — install ``FlakyKV``
*before* creating the clients that should feel it.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, Optional, Set

from .errors import KVConflict, StorageError

_FAILABLE_SERVER_OPS = ("create_slice", "create_slices", "retrieve_slice",
                        "retrieve_slices")


class FlakyStorageServer:
    """Proxy around a ``StorageServer`` that fails chosen calls by number.

    ``fail_on`` maps an op name to the set of 1-based call numbers that
    raise ``StorageError``; with ``crash=True`` the first injected failure
    also crashes the underlying server (it stays down until
    ``inner.recover()``), modelling a node death rather than a transient
    refusal.  Thread-safe: the write scheduler hits servers from a pool.

    Latency injection (deterministic, for deadline/hedge testing): with
    ``slow_every_n=k``, every k-th intercepted call sleeps ``delay_s``
    before executing — call numbering shared with ``fail_on``, so a test
    can make the SAME call slow once and fail the next time.  The sleep
    happens outside the proxy's lock (other calls proceed while one call
    is slow — and the blocking call would otherwise serialize the pool).
    """

    _LOCAL_ATTRS = frozenset(
        {"_inner", "_fail_on", "_crash", "_lock", "calls", "injected",
         "_slow_every_n", "_delay_s", "delayed"})

    def __init__(self, inner, fail_on: Dict[str, Iterable[int]],
                 crash: bool = False,
                 slow_every_n: Optional[int] = None,
                 delay_s: float = 0.0):
        if slow_every_n is not None and slow_every_n < 1:
            raise ValueError(f"slow_every_n must be >= 1, got {slow_every_n}")
        self._inner = inner
        self._fail_on: Dict[str, Set[int]] = {
            op: set(ns) for op, ns in fail_on.items()}
        for op in self._fail_on:
            if op not in _FAILABLE_SERVER_OPS:
                raise ValueError(f"cannot inject failures into {op!r}")
        self._crash = crash
        self._lock = threading.Lock()
        self._slow_every_n = slow_every_n
        self._delay_s = delay_s
        self.calls: Dict[str, int] = {op: 0 for op in _FAILABLE_SERVER_OPS}
        self.injected: int = 0
        self.delayed: int = 0

    def _maybe_fail(self, op: str) -> None:
        with self._lock:
            self.calls[op] += 1
            n = self.calls[op]
            hit = n in self._fail_on.get(op, ())
            slow = (self._slow_every_n is not None
                    and n % self._slow_every_n == 0)
            if hit:
                self.injected += 1
            if slow:
                self.delayed += 1
        if slow:
            time.sleep(self._delay_s)
        if hit:
            if self._crash:
                self._inner.crash()
            raise StorageError(
                f"injected failure: {op} call #{n} on server "
                f"{self._inner.server_id}")

    # -- intercepted API ---------------------------------------------------
    def create_slice(self, data, locality_hint=None):
        self._maybe_fail("create_slice")
        return self._inner.create_slice(data, locality_hint)

    def create_slices(self, parts, locality_hint=None):
        self._maybe_fail("create_slices")
        return self._inner.create_slices(parts, locality_hint)

    def retrieve_slice(self, ptr):
        self._maybe_fail("retrieve_slice")
        return self._inner.retrieve_slice(ptr)

    def retrieve_slices(self, ptrs):
        self._maybe_fail("retrieve_slices")
        return self._inner.retrieve_slices(ptrs)

    # -- everything else passes through ------------------------------------
    def __getattr__(self, name):
        return getattr(self._inner, name)

    def __setattr__(self, name, value):
        # Writes to server state (e.g. ``reset_io_stats`` assigning a fresh
        # ``stats``) must land on the wrapped server, not shadow it here.
        if name in type(self)._LOCAL_ATTRS:
            object.__setattr__(self, name, value)
        else:
            setattr(self._inner, name, value)


def make_flaky_server(cluster, server_id: int,
                      fail_on: Dict[str, Iterable[int]],
                      crash: bool = False,
                      slow_every_n: Optional[int] = None,
                      delay_s: float = 0.0) -> FlakyStorageServer:
    """Wrap ``cluster.servers[server_id]`` in place; returns the wrapper."""
    flaky = FlakyStorageServer(cluster.servers[server_id], fail_on,
                               crash=crash, slow_every_n=slow_every_n,
                               delay_s=delay_s)
    cluster.servers[server_id] = flaky
    return flaky


def kill_server(cluster, server_id: int) -> None:
    """Silent node death: the server stops serving but NOTHING tells the
    coordinator — unlike ``Cluster.fail_server``, which is an orderly
    administrative removal (coordinator notified, ring refreshed).  Clients
    discover the corpse the way real ones do: failed rounds feed the
    failover walk and the health tracker's circuit breaker."""
    cluster.servers[server_id].crash()


def restart_server(cluster, server_id: int) -> None:
    """Bring a killed server back: storage recovers (slices intact — crash
    loses the process, not the disk), the coordinator re-admits it, and its
    circuit-breaker history is forgotten so it serves immediately."""
    cluster.recover_server(server_id)


class FlakyKV:
    """Proxy around ``WarpKV``/``ShardedKV`` that fails chosen commits —
    and, on a sharded KV, chosen 2PC *phases* — by number.

    ``fail_commits`` holds 1-based commit-attempt numbers (counted across
    the proxy) that raise ``KVConflict`` *before* the real commit runs —
    the filesystem is untouched, exactly the HyperDex-abort contract the
    §2.6 replay layer assumes.

    For cross-shard transactions on a ``mdshard.ShardedKV``:

      * ``fail_prepares`` — 1-based per-shard *prepare* call numbers
        (counted across the proxy) that raise ``KVConflict`` right before
        that shard validates.  Nothing has been applied anywhere yet, so
        the injected abort must leave nothing visible on ANY shard.
      * ``fail_applies`` — 1-based *commit-point* numbers (one per
        cross-shard transaction) that raise ``mdshard.PhaseCrash`` between
        prepare and apply, i.e. a coordinator crash.  ``apply_resolution``
        is what crash recovery reads from the decision record: ``"abort"``
        rolls everything back (retryable ``KVConflict``), ``"commit"``
        rolls forward and the commit completes.

    Transactions begun through the proxy route their commits here; install
    with ``cluster.kv = FlakyKV(cluster.kv)`` before creating clients.
    """

    def __init__(self, inner, fail_commits: Iterable[int] = (),
                 fail_prepares: Iterable[int] = (),
                 fail_applies: Iterable[int] = (),
                 apply_resolution: str = "abort"):
        self._inner = inner
        self._fail_commits = set(fail_commits)
        self._fail_prepares = set(fail_prepares)
        self._fail_applies = set(fail_applies)
        if apply_resolution not in ("abort", "commit"):
            raise ValueError("apply_resolution must be 'abort' or 'commit'")
        self._apply_resolution = apply_resolution
        self._lock = threading.Lock()
        self.commit_calls: int = 0
        self.prepare_calls: int = 0
        self.decide_calls: int = 0
        self.injected: int = 0

    def begin(self):
        txn = self._inner.begin()
        txn._kv = self           # commits route through _commit below
        if self._fail_prepares or self._fail_applies:
            txn._phase_hook = self._on_phase
        return txn

    def _on_phase(self, phase: str, pos: int) -> None:
        """Called by the 2PC coordinator before each shard's prepare and at
        the commit point (``decide``)."""
        if phase == "prepare":
            with self._lock:
                self.prepare_calls += 1
                hit = self.prepare_calls in self._fail_prepares
                if hit:
                    self.injected += 1
                    n = self.prepare_calls
            if hit:
                raise KVConflict(
                    f"injected prepare failure: prepare #{n} "
                    f"(shard position {pos})")
        elif phase == "decide":
            with self._lock:
                self.decide_calls += 1
                hit = self.decide_calls in self._fail_applies
                if hit:
                    self.injected += 1
            if hit:
                from .mdshard import PhaseCrash
                raise PhaseCrash(self._apply_resolution)

    def _commit(self, txn) -> None:
        with self._lock:
            self.commit_calls += 1
            hit = self.commit_calls in self._fail_commits
            if hit:
                self.injected += 1
        if hit:
            self._inner.stats.add(aborts=1)
            raise KVConflict(
                f"injected abort: commit #{self.commit_calls}")
        self._inner._commit(txn)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def make_flaky_kv(cluster, fail_commits: Iterable[int] = (),
                  fail_prepares: Iterable[int] = (),
                  fail_applies: Iterable[int] = (),
                  apply_resolution: str = "abort") -> FlakyKV:
    """Swap ``cluster.kv`` for a ``FlakyKV``; affects clients created
    AFTER this call (clients capture ``cluster.kv`` at construction)."""
    flaky = FlakyKV(cluster.kv, fail_commits, fail_prepares, fail_applies,
                    apply_resolution)
    cluster.kv = flaky
    return flaky


# ---------------------------------------------------------------------------
# Runtime lock-order witness
# ---------------------------------------------------------------------------
#
# The static pass (``python -m repro.analysis``) and this witness share one
# order declaration: ``repro.analysis.lockspec``.  Core modules wrap their
# locks with :func:`witness_lock` at construction time; when the
# ``WTF_LOCK_WITNESS`` env flag is set (``conftest.py`` sets it for the
# whole tier-1 suite), every acquisition is checked against the calling
# thread's held-lock stack and an inversion raises
# :class:`LockOrderViolation` *at acquisition time* — a clean stack trace
# pointing at both locks, instead of a 60-second deadlock timeout.  With
# the flag unset, ``witness_lock`` returns the raw lock: zero overhead in
# production and benchmarks.

import os as _os

from ..analysis import lockspec as _lockspec

_witness_tls = threading.local()


def _witness_stack():
    stack = getattr(_witness_tls, "stack", None)
    if stack is None:
        stack = _witness_tls.stack = []
    return stack


class LockOrderViolation(AssertionError):
    """A lock was acquired against the declared global order."""


class OrderedLock:
    """Wrapper enforcing ``lockspec`` rank/key order on every acquire.

    * Blocking acquires are checked *before* touching the inner lock, so a
      would-be deadlock surfaces as an exception while the thread still
      runs.
    * Re-acquiring a lock this thread already holds is allowed (RLock
      semantics) and skips the order check.
    * Same-level families declared ``multi="sorted"`` require strictly
      ascending ``key`` order — the global (shard, stripe) rule.
    * Works as the lock of a ``threading.Condition``: ``_release_save`` /
      ``_acquire_restore`` are withheld so the Condition falls back to
      plain ``release()``/``acquire()`` (which keep the stack honest), and
      ``_is_owned`` is answered from the per-thread stack.
    """

    __slots__ = ("_inner", "name", "rank", "multi", "key")

    def __init__(self, inner, level: str, key=None):
        spec = _lockspec.LEVEL_BY_NAME.get(level)
        if spec is None:
            raise ValueError(f"unknown lock level {level!r}; declare it in "
                             f"repro.analysis.lockspec.LOCK_LEVELS")
        self._inner = inner
        self.name = level
        self.rank = spec.rank
        self.multi = spec.multi
        self.key = key

    def _describe(self):
        key = f"[{self.key!r}]" if self.key is not None else ""
        return f"{self.name}{key}(rank {self.rank})"

    def _check_order(self):
        stack = _witness_stack()
        for held in stack:
            if held is self:        # identity re-entry: RLock semantics
                return
        for held in stack:
            if held.rank > self.rank:
                raise LockOrderViolation(
                    f"lock-order inversion in thread "
                    f"{threading.current_thread().name!r}: acquiring "
                    f"{self._describe()} while holding {held._describe()}; "
                    f"held stack: "
                    f"{[h._describe() for h in stack]}")
            if held.rank == self.rank:
                if self.multi != "sorted":
                    raise LockOrderViolation(
                        f"two locks of level {self.name!r} (multi=none) "
                        f"held by thread "
                        f"{threading.current_thread().name!r}: "
                        f"{held._describe()} then {self._describe()}")
                if held.key is None or self.key is None \
                        or not held.key < self.key:
                    raise LockOrderViolation(
                        f"unsorted same-level acquisition of "
                        f"{self.name!r} in thread "
                        f"{threading.current_thread().name!r}: "
                        f"{held._describe()} then {self._describe()} — "
                        f"keys must be strictly ascending")

    def acquire(self, blocking=True, timeout=-1):
        if blocking:
            self._check_order()
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            _witness_stack().append(self)
        return ok

    def release(self):
        stack = _witness_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                break
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def _is_owned(self):
        probe = getattr(self._inner, "_is_owned", None)
        if probe is not None:
            return probe()
        return any(entry is self for entry in _witness_stack())

    def __getattr__(self, name):
        if name in ("_release_save", "_acquire_restore"):
            # Withheld on purpose: threading.Condition must go through our
            # acquire()/release() so the held stack stays balanced.
            raise AttributeError(name)
        return getattr(self._inner, name)

    def __repr__(self):
        return f"<OrderedLock {self._describe()} wrapping {self._inner!r}>"


class LockOrderWatchdog:
    """Process-wide switchboard for the runtime witness."""

    ENV_FLAG = "WTF_LOCK_WITNESS"

    @staticmethod
    def enabled() -> bool:
        return _os.environ.get(LockOrderWatchdog.ENV_FLAG, "0") \
            not in ("", "0")

    @staticmethod
    def held():
        """Snapshot of the calling thread's witnessed held-lock stack."""
        return tuple(_witness_stack())

    @staticmethod
    def assert_clean() -> None:
        stack = _witness_stack()
        if stack:
            raise LockOrderViolation(
                f"thread {threading.current_thread().name!r} still holds "
                f"witnessed locks: {[h._describe() for h in stack]}")

    @staticmethod
    def is_witnessed(lock) -> bool:
        return isinstance(lock, OrderedLock)


def witness_lock(lock, level: str, key=None, enabled=None):
    """Wrap ``lock`` as an :class:`OrderedLock` at declared ``level`` when
    the witness is on; return ``lock`` unchanged (zero overhead) when off."""
    if enabled is None:
        enabled = LockOrderWatchdog.enabled()
    if not enabled:
        return lock
    return OrderedLock(lock, level, key=key)
