"""Inodes, regions, and their commit-time commutative operations.

File metadata layout in WarpKV (paper §2.1, §2.3, §2.4):

  space "paths"   : normalized pathname -> inode id      (one-lookup open)
  space "inodes"  : inode id -> Inode                    (standard inode info)
  space "regions" : (inode id, region index) -> RegionData

A file is partitioned into fixed-size regions, each holding its own ordered
extent list plus ``end`` — the highest offset written in the region, which is
what makes the paper's *relative append* possible: an append is a commit-time
commutative operation whose precondition is "still fits in this region", so
concurrent appenders never conflict (§2.5).

All values are immutable dataclasses: WarpKV hands out references, so nothing
may be mutated in place.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from .metadata import CommutingOp
from .slicing import Extent, compact, visible_length

DEFAULT_REGION_SIZE = 64 * 1024 * 1024   # 64 MB, matching the evaluation §4

# Overlay-list length at which writers piggyback a commit-time compaction
# (``CompactRegion``) onto their transaction.  Large enough that explicit
# GC tier-1 passes (and the tests driving them) still see uncompacted
# history below it; small enough to bound hot-region planning cost.
REGION_COMPACT_THRESHOLD = 64


@dataclass(frozen=True, slots=True)
class Inode:
    inode_id: int
    kind: str                   # "file" | "dir"
    links: int = 1
    mtime: int = 0
    mode: int = 0o644
    owner: str = "root"
    group: str = "root"
    region_size: int = DEFAULT_REGION_SIZE
    # Reference to the highest-offset region written (§2.4) — lets clients
    # find end-of-file with a single extra region lookup.  -1 == empty file.
    max_region: int = -1

    def replace(self, **kw) -> "Inode":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True, slots=True)
class RegionData:
    """One region's metadata list.

    ``indirect`` is the tier-2 GC state (§2.8): when a compacted list is
    still too fragmented, it is serialized into a slice and the region keeps
    only a pointer to it; ``entries`` then holds extents appended since.
    """

    entries: Tuple[Extent, ...] = ()
    end: int = 0                      # region-relative high-water mark
    indirect: Optional[Extent] = None


class AppendExtents(CommutingOp):
    """Atomic append of extents to a region list — the HyperDex list-append
    WTF's correctness rests on (§2.1).

    ``relative=True`` implements the paper's relative append: extent offsets
    are ignored and resolved against the region's current ``end`` *at commit
    time*, with the precondition that the result still fits below ``bound``.
    Appends therefore commute: they never carry a read dependency and never
    abort each other.
    """

    __slots__ = ("extents", "relative", "bound", "total")

    def __init__(self, extents, relative: bool = False,
                 bound: Optional[int] = None):
        self.extents = tuple(extents)
        self.relative = relative
        self.bound = bound
        self.total = sum(e.length for e in self.extents)

    def precondition(self, value) -> bool:
        if self.bound is None:
            return True
        end = value.end if value is not None else 0
        return end + self.total <= self.bound

    def apply(self, value):
        rd = value if value is not None else RegionData()
        if self.relative:
            cursor = rd.end
            resolved = []
            for e in self.extents:
                resolved.append(e.at(cursor))
                cursor += e.length
            resolved = tuple(resolved)
        else:
            resolved = self.extents
        new_end = max([rd.end] + [e.end for e in resolved])
        return (RegionData(rd.entries + resolved, new_end, rd.indirect),
                resolved)

    def coalesce(self, nxt: "AppendExtents") -> Optional["AppendExtents"]:
        """Append-of-append composes exactly: [A]+[B] == [A,B] (relative
        cursors chain; a combined bound check is equivalent because a
        failing prefix fails the whole transaction either way).  Bulk
        paste/concat queue thousands of appends on a handful of regions —
        coalescing keeps transaction views and commits O(keys)."""
        if (self.relative != nxt.relative or self.bound != nxt.bound):
            return None
        return AppendExtents(self.extents + nxt.extents,
                             relative=self.relative, bound=self.bound)


class CompactRegion(CommutingOp):
    """Commit-time, threshold-triggered incremental compaction (§2.8 tier 1
    moved onto the commit path).

    Writers piggyback this op when a region's overlay list outgrows the
    cluster threshold, so hot regions never accumulate unbounded history
    between explicit GC passes.  The §2.5 append contract is preserved on
    both sides:

      * no read dependency, no precondition — a compaction can never make
        two transactions conflict;
      * ``version_preserving``: the compacted list reconstructs byte-
        identical content (``compact`` only drops obscured extents and
        merges disk-adjacent ones), so WarpKV keeps the region's version
        unchanged when applying it.  Readers holding a read dependency or
        a cached plan against the pre-compaction value stay valid — their
        plans reference only visible byte ranges, all of which the
        compacted pointers still cover — and are NOT spuriously aborted.

    Slices referenced only by dropped (obscured) extents become garbage
    for the tier-3 collector; the two-consecutive-scans rule in
    ``StorageServer.gc_pass`` already covers the handoff.

    Below the threshold (or on a wiped region) the op is a no-op and —
    per WarpKV's no-op-merge rule — bumps nothing at all.
    """

    version_preserving = True
    __slots__ = ("threshold",)

    def __init__(self, threshold: int):
        self.threshold = threshold

    def apply(self, value):
        rd = value
        if rd is None or len(rd.entries) < self.threshold:
            return value, 0
        compacted = tuple(compact(rd.entries))
        if compacted == rd.entries:
            return value, 0
        return (RegionData(compacted, rd.end, rd.indirect),
                len(rd.entries) - len(compacted))


class ReplaceExtentPtrs(CommutingOp):
    """Repair-plane replica-set swap (§2.9 healing, ``core.repair``).

    ``mapping`` takes an entry's exact pointer tuple to its repaired
    replacement — surviving replicas first (in their original order, so the
    canonical first pointer stays stable whenever replica 0 survived, and
    the PR 9 block-cache key with it), freshly re-replicated pointers
    appended.  Committed as a commuting op so repair NEVER conflicts with
    concurrent appenders: no read dependency, no precondition, and entries
    the mapping misses (compacted or truncated away between the repair scan
    and this commit) are simply left alone for the next scan.

    NOT ``version_preserving``: replica sets are observable to read
    planners, so the version bump is exactly what invalidates
    version-validated cached plans that still point at the dead replica.
    """

    __slots__ = ("mapping",)

    def __init__(self, mapping):
        self.mapping = dict(mapping)

    def apply(self, value):
        rd = value
        if rd is None:
            return value, 0
        swapped = 0
        entries = []
        for e in rd.entries:
            new_ptrs = self.mapping.get(e.ptrs)
            if new_ptrs is None:
                entries.append(e)
            else:
                entries.append(Extent(e.offset, e.length, new_ptrs))
                swapped += 1
        indirect = rd.indirect
        if indirect is not None:
            new_ptrs = self.mapping.get(indirect.ptrs)
            if new_ptrs is not None:
                indirect = Extent(indirect.offset, indirect.length, new_ptrs)
                swapped += 1
        if swapped == 0:
            # Returning the operand untouched engages WarpKV's no-op-merge
            # rule: nothing is written, nothing is bumped.
            return value, 0
        return RegionData(tuple(entries), rd.end, indirect), swapped


class ClearRegion(CommutingOp):
    """Commit-time region wipe (truncate-to-zero).

    Queued as a commutative op — NOT a raw ``delete`` — so it composes with
    appends queued in the same transaction in queue order: extents queued
    *before* the truncate are wiped with the region, extents queued *after*
    survive.  A raw delete was applied before all commutes at commit,
    resurrecting earlier in-txn writes.  The ``None`` result value is the
    same tombstone a delete leaves.
    """

    __slots__ = ()

    def apply(self, value):
        return None, None


class ResetInode(CommutingOp):
    """Truncate-to-zero's inode half: reset ``max_region`` in queue order
    (earlier in-txn bumps are cancelled, later ones re-raise it), merging
    ``mtime`` and leaving the link count untouched."""

    __slots__ = ("mtime",)

    def __init__(self, mtime: int):
        self.mtime = mtime

    def precondition(self, value) -> bool:
        return value is not None        # file must still exist

    def apply(self, value: Inode):
        kw = {"max_region": -1}
        if self.mtime > value.mtime:
            kw["mtime"] = self.mtime
        return value.replace(**kw), None


class BumpInode(CommutingOp):
    """Monotone inode update: ``max_region``/``mtime`` merge by max.

    Because WarpKV skips the version bump when a commutative op leaves the
    value unchanged, appends that stay within the current last region do not
    invalidate concurrent readers of the inode — this is what keeps parallel
    appends conflict-free end to end.

    An *mtime-only* advance additionally keeps the inode's version
    (``preserves_version``): timestamps carry no serializability promise
    in POSIX, so ticking ``mtime`` must not abort concurrent appenders
    holding an inode read dependency, nor invalidate cached read plans.
    Any structural change (``max_region`` growth, link count) still bumps
    the version — that is what serializes appends against truncate and
    namespace ops.
    """

    __slots__ = ("max_region", "mtime", "link_delta")

    def __init__(self, max_region: Optional[int] = None,
                 mtime: Optional[int] = None,
                 link_delta: int = 0):
        self.max_region = max_region
        self.mtime = mtime
        self.link_delta = link_delta

    def precondition(self, value) -> bool:
        return value is not None        # file must still exist

    def apply(self, value: Inode):
        ino = value
        kw = {}
        if self.max_region is not None and self.max_region > ino.max_region:
            kw["max_region"] = self.max_region
        if self.mtime is not None and self.mtime > ino.mtime:
            kw["mtime"] = self.mtime
        if self.link_delta:
            kw["links"] = ino.links + self.link_delta
        return (ino.replace(**kw) if kw else ino), None

    def preserves_version(self, old, new) -> bool:
        return (isinstance(old, Inode) and isinstance(new, Inode)
                and new.replace(mtime=old.mtime) == old)

    def coalesce(self, nxt: "BumpInode") -> "BumpInode":
        def mx(a, b):
            if a is None:
                return b
            if b is None:
                return a
            return max(a, b)
        return BumpInode(max_region=mx(self.max_region, nxt.max_region),
                         mtime=mx(self.mtime, nxt.mtime),
                         link_delta=self.link_delta + nxt.link_delta)


def region_key(inode_id: int, region_idx: int) -> tuple:
    return (inode_id, region_idx)
