"""Inodes, regions, and their commit-time commutative operations.

File metadata layout in WarpKV (paper §2.1, §2.3, §2.4):

  space "paths"   : normalized pathname -> inode id      (one-lookup open)
  space "inodes"  : inode id -> Inode                    (standard inode info)
  space "regions" : (inode id, region index) -> RegionData

A file is partitioned into fixed-size regions, each holding its own ordered
extent list plus ``end`` — the highest offset written in the region, which is
what makes the paper's *relative append* possible: an append is a commit-time
commutative operation whose precondition is "still fits in this region", so
concurrent appenders never conflict (§2.5).

All values are immutable dataclasses: WarpKV hands out references, so nothing
may be mutated in place.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from .metadata import CommutingOp
from .slicing import Extent, visible_length

DEFAULT_REGION_SIZE = 64 * 1024 * 1024   # 64 MB, matching the evaluation §4


@dataclass(frozen=True, slots=True)
class Inode:
    inode_id: int
    kind: str                   # "file" | "dir"
    links: int = 1
    mtime: int = 0
    mode: int = 0o644
    owner: str = "root"
    group: str = "root"
    region_size: int = DEFAULT_REGION_SIZE
    # Reference to the highest-offset region written (§2.4) — lets clients
    # find end-of-file with a single extra region lookup.  -1 == empty file.
    max_region: int = -1

    def replace(self, **kw) -> "Inode":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True, slots=True)
class RegionData:
    """One region's metadata list.

    ``indirect`` is the tier-2 GC state (§2.8): when a compacted list is
    still too fragmented, it is serialized into a slice and the region keeps
    only a pointer to it; ``entries`` then holds extents appended since.
    """

    entries: Tuple[Extent, ...] = ()
    end: int = 0                      # region-relative high-water mark
    indirect: Optional[Extent] = None


class AppendExtents(CommutingOp):
    """Atomic append of extents to a region list — the HyperDex list-append
    WTF's correctness rests on (§2.1).

    ``relative=True`` implements the paper's relative append: extent offsets
    are ignored and resolved against the region's current ``end`` *at commit
    time*, with the precondition that the result still fits below ``bound``.
    Appends therefore commute: they never carry a read dependency and never
    abort each other.
    """

    def __init__(self, extents, relative: bool = False,
                 bound: Optional[int] = None):
        self.extents = tuple(extents)
        self.relative = relative
        self.bound = bound
        self.total = sum(e.length for e in self.extents)

    def precondition(self, value) -> bool:
        if self.bound is None:
            return True
        end = value.end if value is not None else 0
        return end + self.total <= self.bound

    def apply(self, value):
        rd = value if value is not None else RegionData()
        if self.relative:
            cursor = rd.end
            resolved = []
            for e in self.extents:
                resolved.append(e.at(cursor))
                cursor += e.length
            resolved = tuple(resolved)
        else:
            resolved = self.extents
        new_end = max([rd.end] + [e.end for e in resolved])
        return (RegionData(rd.entries + resolved, new_end, rd.indirect),
                resolved)

    def coalesce(self, nxt: "AppendExtents") -> Optional["AppendExtents"]:
        """Append-of-append composes exactly: [A]+[B] == [A,B] (relative
        cursors chain; a combined bound check is equivalent because a
        failing prefix fails the whole transaction either way).  Bulk
        paste/concat queue thousands of appends on a handful of regions —
        coalescing keeps transaction views and commits O(keys)."""
        if (self.relative != nxt.relative or self.bound != nxt.bound):
            return None
        return AppendExtents(self.extents + nxt.extents,
                             relative=self.relative, bound=self.bound)


class ClearRegion(CommutingOp):
    """Commit-time region wipe (truncate-to-zero).

    Queued as a commutative op — NOT a raw ``delete`` — so it composes with
    appends queued in the same transaction in queue order: extents queued
    *before* the truncate are wiped with the region, extents queued *after*
    survive.  A raw delete was applied before all commutes at commit,
    resurrecting earlier in-txn writes.  The ``None`` result value is the
    same tombstone a delete leaves.
    """

    def apply(self, value):
        return None, None


class ResetInode(CommutingOp):
    """Truncate-to-zero's inode half: reset ``max_region`` in queue order
    (earlier in-txn bumps are cancelled, later ones re-raise it), merging
    ``mtime`` and leaving the link count untouched."""

    def __init__(self, mtime: int):
        self.mtime = mtime

    def precondition(self, value) -> bool:
        return value is not None        # file must still exist

    def apply(self, value: Inode):
        kw = {"max_region": -1}
        if self.mtime > value.mtime:
            kw["mtime"] = self.mtime
        return value.replace(**kw), None


class BumpInode(CommutingOp):
    """Monotone inode update: ``max_region``/``mtime`` merge by max.

    Because WarpKV skips the version bump when a commutative op leaves the
    value unchanged, appends that stay within the current last region do not
    invalidate concurrent readers of the inode — this is what keeps parallel
    appends conflict-free end to end.
    """

    def __init__(self, max_region: Optional[int] = None,
                 mtime: Optional[int] = None,
                 link_delta: int = 0):
        self.max_region = max_region
        self.mtime = mtime
        self.link_delta = link_delta

    def precondition(self, value) -> bool:
        return value is not None        # file must still exist

    def apply(self, value: Inode):
        ino = value
        kw = {}
        if self.max_region is not None and self.max_region > ino.max_region:
            kw["max_region"] = self.max_region
        if self.mtime is not None and self.mtime > ino.mtime:
            kw["mtime"] = self.mtime
        if self.link_delta:
            kw["links"] = ino.links + self.link_delta
        return (ino.replace(**kw) if kw else ino), None

    def coalesce(self, nxt: "BumpInode") -> "BumpInode":
        def mx(a, b):
            if a is None:
                return b
            if b is None:
                return a
            return max(a, b)
        return BumpInode(max_region=mx(self.max_region, nxt.max_region),
                         mtime=mx(self.mtime, nxt.mtime),
                         link_delta=self.link_delta + nxt.link_delta)


def region_key(inode_id: int, region_idx: int) -> tuple:
    return (inode_id, region_idx)
