"""Client-side data-block cache: hot re-reads cost zero storage rounds.

A bounded LRU of retrieved extents keyed by the extent's canonical slice
pointer ``(server_id, backing_file, offset, length)`` — the first replica,
which is replica-independent because every replica of an extent stores the
same bytes.  The read scheduler (``iosched.SliceScheduler.fetch_many``)
consults it before building fetch batches and inserts fetched extents
after, so a fully cached read issues *no* storage retrieval round at all —
the data-plane mirror of how metadata leases (PR 6) made hot re-reads cost
zero KV rounds.

Correctness has two independent layers:

* **Pointer immutability** — backing-file byte ranges are append-only and
  never reused: overwrites allocate new extents at new offsets (hence new
  cache keys) and GC preserves live bytes at their offsets, so an entry
  looked up by a *currently valid* pointer is always byte-correct.
* **Version validation** — staleness is therefore a *plan*-level property,
  and the cache shares the exact invalidation rule of the PR 4
  ``PlanCache``: a plan-cache hit is revalidated against the touched
  regions' KV versions, and a failed validation (an invalidating commit
  moved the region version) drops the inode's plans *and* its blocks
  together; on lease-enabled clusters the lease hub's WAL subscription
  does the same eviction on every "regions" write (and hence on lease
  revocation).  A stale block can never satisfy a read: its pointer is no
  longer reachable from any validated plan.

Write-behind pending extents bypass the cache structurally: the overlay
serves them from the client buffer before plans ever reach the scheduler.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Set, Tuple

from .slicing import SlicePointer
from .testing import witness_lock

#: Default ``Cluster(block_cache_bytes=…)``: a client-scale working set,
#: small enough that a cache per client (the no-lease default) stays cheap.
DEFAULT_BLOCK_CACHE_BYTES = 8 << 20

#: Cache key: canonical (server_id, backing_file, offset, length).
BlockKey = Tuple[int, str, int, int]


def block_key(ptr: SlicePointer) -> BlockKey:
    """Canonical replica-independent key for an extent's first replica."""
    return (ptr.server_id, ptr.backing_file, ptr.offset, ptr.length)


class BlockCache:
    """Byte-bounded LRU of retrieved data blocks (see module docstring).

    Thread-safe; like ``PlanCache`` one instance is shared cluster-wide on
    lease-enabled clusters and per-client otherwise.  ``_lock`` is the
    declared ``cache.block`` level, ranked just after ``cache.plan`` so
    the joint plan+block evictions (WAL listener, validation failure)
    nest in the declared order.
    """

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        # A single giant extent must not evict the whole working set.
        self.max_entry = max(1, capacity_bytes // 4)
        self._lock = witness_lock(threading.Lock(), "cache.block")
        self._entries: "OrderedDict[BlockKey, bytes]" = OrderedDict()
        self._nbytes = 0
        # inode id -> live keys, so invalidation is O(the inode's blocks).
        self._by_inode: Dict[int, Set[BlockKey]] = {}
        self._inode_of: Dict[BlockKey, int] = {}

    def get(self, key: BlockKey) -> Optional[bytes]:
        with self._lock:
            data = self._entries.get(key)
            if data is not None:
                self._entries.move_to_end(key)
            return data

    def put(self, key: BlockKey, data, inode_id: int) -> None:
        """Insert ``data`` (any buffer; stored as a compact ``bytes`` copy
        so a small block never pins a large covering blob)."""
        n = len(data)
        if n == 0 or n > self.max_entry:
            return
        blob = data if type(data) is bytes else bytes(data)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._nbytes -= len(old)
                self._drop_index_locked(key)
            self._entries[key] = blob
            self._nbytes += n
            self._by_inode.setdefault(inode_id, set()).add(key)
            self._inode_of[key] = inode_id
            while self._nbytes > self.capacity:
                oldest, buf = self._entries.popitem(last=False)
                self._nbytes -= len(buf)
                self._drop_index_locked(oldest)

    def _drop_index_locked(self, key: BlockKey) -> None:
        ino = self._inode_of.pop(key, None)
        if ino is None:
            return
        keys = self._by_inode.get(ino)
        if keys is not None:
            keys.discard(key)
            if not keys:
                del self._by_inode[ino]

    def drop_inode(self, inode_id: int) -> int:
        """Evict every block for ``inode_id``; returns entries dropped.
        Called from the same sites that drop the inode's plans."""
        with self._lock:
            keys = self._by_inode.pop(inode_id, None)
            if not keys:
                return 0
            for key in keys:
                buf = self._entries.pop(key, None)
                if buf is not None:
                    self._nbytes -= len(buf)
                self._inode_of.pop(key, None)
            return len(keys)

    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_inode.clear()
            self._inode_of.clear()
            self._nbytes = 0
