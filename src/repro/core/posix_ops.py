"""POSIX-style surface — the top layer of the split client (see
``client.py`` for how the layers assemble).

Scalar surface: open/close/read/write/pread/pwrite/seek/tell/truncate,
mkdir/listdir, link/unlink/rmdir/rename/stat — with one-lookup open (§2.4).

Vectored surface (the handle-based I/O redesign):

  * ``readv(fd, ranges)``   — fetch many ``(offset, length)`` ranges in one
    transaction; slice fetches for *all* ranges are planned together and
    handed to the batched scheduler, so adjacent/near-adjacent pointers
    coalesce into single storage rounds and distinct servers are read in
    parallel.  Positional: the fd offset does not move.
  * ``preadv(fd, sizes, offset)`` — POSIX flavor: consecutive chunks
    starting at ``offset``.
  * ``writev(fd, chunks)``  — gather-write at the fd offset; all chunk
    stores are planned first and dispatched through the write scheduler
    (``wsched``): chunks within one region coalesce into a single covering
    store, regions fan out across distinct servers in parallel.
  * ``pwritev(fd, chunks, offset)`` — positional gather-write.

Each vectored call executes as a single logged op inside one transaction, so
a batch is atomic: all of it commits or none of it is visible.  Prefer
``WtfClient.open_file`` / ``WtfFile`` (``handle.py``) over raw fd juggling.

Async surface (the unified I/O runtime's futures flavor):

  * ``readv_async`` / ``preadv_async`` / ``writev_async`` /
    ``pwritev_async`` mirror their synchronous twins but return an
    ``IoFuture``: the op body (metadata planning + data rounds + commit)
    runs on the cluster's ``IoRuntime`` pool, so the caller can plan op
    N+1 while op N's data rounds are in flight.  Everything fd-dependent
    resolves at submission on the calling thread (EBADF/EINVAL fail fast;
    ``writev_async`` advances the fd offset eagerly, like POSIX AIO);
    each op then commits as its own auto-commit transaction on the
    worker.  Async ops are auto-commit only — inside an open
    ``WtfTransaction`` they raise, because the §2.6 op log is ordered by
    the application thread.  With write-behind active, async writes
    complete synchronously into the buffer (there is no storage round to
    overlap) and return an already-resolved future.


Directories are special files (§2.4): their content is a record log of
add/del entries, maintained with the same append machinery as data.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.util import jsonio

from .client_runtime import (SEEK_CUR, SEEK_END, SEEK_SET, _Ctx, _Fd, _Op,
                             basename_of, normalize_path, parent_of)
from .errors import (AlreadyExists, DirectoryNotEmpty, InvalidOffset,
                     IsADirectory, NotADirectory, NotFound, WtfError)
from .inode import AppendExtents, Inode, region_key
from .iort import IoFuture
from .slicing import Extent


class PosixOps:
    """Mixin: POSIX surface + directory machinery for ``WtfClient``."""

    # ===================================================== public API: POSIX
    def mkfs(self) -> None:
        """Create the root directory and GC directory (idempotent)."""
        from .client import GC_DIR
        txn = self._begin_txn()
        if txn.get("paths", "/") is None:
            root = Inode(self._alloc_inode_id_for("/"), "dir",
                         mtime=self.time_fn(),
                         region_size=self.cluster.region_size)
            txn.put("paths", "/", root.inode_id)
            txn.put("inodes", root.inode_id, root)
            txn.commit()
            self.mkdir(GC_DIR)
        else:
            txn.abort()

    def open(self, path: str, mode: str = "r",
             region_size: Optional[int] = None) -> int:
        """One-lookup open (§2.4): pathname → inode in a single KV get."""
        return self._run("open", normalize_path(path), mode, region_size)

    def open_file(self, path: str, mode: str = "r",
                  region_size: Optional[int] = None,
                  buffered: bool = False):
        """Open ``path`` as a first-class ``WtfFile`` handle (context
        manager) — the preferred surface over raw integer fds.
        ``buffered=True`` opts this handle's writes into the write-behind
        buffer even when the client/cluster knob is off (they flush at the
        enclosing commit boundary)."""
        from .handle import WtfFile
        fd = self.open(path, mode, region_size)
        return WtfFile(self, fd, normalize_path(path), mode,
                       buffered=buffered)

    def close(self, fd: int) -> None:
        self._get_fd(fd)
        del self._fds[fd]

    def read(self, fd: int, size: int = -1) -> bytes:
        return self._run("read", fd, size)

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        return self._run("pread", fd, size, offset)

    def write(self, fd: int, data: bytes) -> int:
        return self._run("write", fd, bytes(data))

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        return self._run("pwrite", fd, bytes(data), offset)

    # ------------------------------------------------- vectored POSIX API
    def readv(self, fd: int,
              ranges: Sequence[Tuple[int, int]]) -> List[bytes]:
        """Read many ``(offset, length)`` ranges as one atomic batch.

        Returns one ``bytes`` per range (clamped at end-of-file exactly like
        ``pread``).  All ranges are planned in a single transaction and
        fetched through the batched scheduler — at most one storage round
        per (server, backing-file) run of coalescible pointers."""
        return self._run("readv", fd,
                         tuple((int(o), int(n)) for o, n in ranges))

    def preadv(self, fd: int, sizes: Sequence[int],
               offset: int) -> List[bytes]:
        """POSIX-flavor vectored read: consecutive chunks of the given sizes
        starting at ``offset``.  The fd offset does not move."""
        ranges = []
        pos = offset
        for sz in sizes:
            ranges.append((pos, int(sz)))
            pos += int(sz)
        return self._run("readv", fd, tuple(ranges))

    def writev(self, fd: int, chunks: Sequence[bytes]) -> int:
        """Gather-write ``chunks`` at the fd offset as one atomic batch;
        advances the offset and returns the total byte count.  Stores are
        planned for the whole batch before dispatch: chunks in one region
        coalesce into a single covering store (one round instead of one per
        chunk), chunks in different regions store in parallel."""
        return self._run("writev", fd, tuple(bytes(c) for c in chunks))

    def pwritev(self, fd: int, chunks: Sequence[bytes],
                offset: int) -> int:
        """Positional gather-write at ``offset``; the fd offset is
        untouched."""
        return self._run("pwritev", fd, tuple(bytes(c) for c in chunks),
                         offset)

    # ------------------------------------------------- async POSIX surface
    def _check_async_scope(self) -> None:
        """Async ops are auto-commit only (the §2.6 op log is
        single-threaded).  Checked BEFORE any submission-time state
        mutation — ``writev_async``'s eager offset advance must not happen
        if the call is about to be rejected."""
        if self._txn is not None:
            raise WtfError(
                "async ops are auto-commit only: they cannot join an "
                "open transaction's op log")

    def _submit_async(self, body, *args) -> IoFuture:
        self._check_async_scope()
        self.stats.add(async_ops=1)
        return self.cluster.runtime.submit_op(
            lambda: body(*args), stats=self.stats)

    def readv_async(self, fd: int,
                    ranges: Sequence[Tuple[int, int]]) -> IoFuture:
        """``readv`` returning an ``IoFuture`` of the range list.

        fd resolution and EINVAL checks happen now, on the calling thread;
        planning and fetching run on a runtime worker *at execution time*,
        so a commit landing before the future runs invalidates any cached
        plan (region versions moved) and the read re-plans against the
        committed state — never stale extents.  Positional: the fd offset
        does not move."""
        f = self._get_fd(fd)          # EBADF before EINVAL, like POSIX
        ranges = tuple((int(o), int(n)) for o, n in ranges)
        for off, size in ranges:
            if off < 0 or size < 0:
                raise InvalidOffset(
                    f"negative range ({off}, {size}) in vectored read plan")
        return self._submit_async(self._async_readv_body, f.inode_id, ranges)

    def preadv_async(self, fd: int, sizes: Sequence[int],
                     offset: int) -> IoFuture:
        """POSIX-flavor async vectored read: consecutive chunks starting at
        ``offset``; the fd offset does not move."""
        if offset < 0:
            self._get_fd(fd)          # EBADF first
            raise InvalidOffset(f"preadv at negative offset {offset}")
        ranges = []
        pos = offset
        for sz in sizes:
            ranges.append((pos, int(sz)))
            pos += int(sz)
        return self.readv_async(fd, ranges)

    def writev_async(self, fd: int, chunks: Sequence[bytes]) -> IoFuture:
        """Gather-write returning an ``IoFuture`` of the byte count.

        The fd offset advances eagerly at submission (POSIX-AIO style), so
        the caller can keep issuing ordered writes; stores and the
        metadata commit run on a worker.  A failed future leaves the
        offset advanced — callers that care re-seek, exactly as with
        ``aio_write``."""
        self._check_async_scope()     # before the eager offset mutation
        f = self._get_wfd(fd)
        chunks = tuple(bytes(c) for c in chunks)
        if f.append:
            # O_APPEND fds cannot pin an offset at submission — the EOF
            # is resolved at commit time.  Run the relative append inline
            # and hand back an already-resolved future.
            self.stats.add(async_ops=1)
            return IoFuture.resolved(self._run("writev", fd, chunks))
        offset = f.offset
        f.offset += sum(len(c) for c in chunks)
        return self._async_write(f, chunks, offset)

    def pwritev_async(self, fd: int, chunks: Sequence[bytes],
                      offset: int) -> IoFuture:
        """Positional async gather-write; the fd offset is untouched."""
        f = self._get_wfd(fd)         # EBADF before EINVAL, like POSIX
        if offset < 0:
            raise InvalidOffset(f"pwritev at negative offset {offset}")
        chunks = tuple(bytes(c) for c in chunks)
        return self._async_write(f, chunks, offset)

    def _async_write(self, f, chunks: Tuple[bytes, ...],
                     offset: int) -> IoFuture:
        if self._write_behind_active():
            # Deferred stores never touch a storage server until the
            # commit flush — there is nothing to overlap, and the buffer
            # belongs to the application thread.  Complete synchronously.
            self._check_async_scope()
            self.stats.add(async_ops=1)
            return IoFuture.resolved(
                self._run("pwritev", f.fd, chunks, offset))
        return self._submit_async(self._async_pwritev_body, f.inode_id,
                                  chunks, offset)

    def seek(self, fd: int, offset: int, whence: int = SEEK_SET):
        return self._run("seek", fd, offset, whence)

    def tell(self, fd: int) -> int:
        return self._get_fd(fd).offset

    def truncate(self, fd: int, length: int = 0) -> None:
        return self._run("truncate", fd, length)

    def mkdir(self, path: str) -> None:
        return self._run("mkdir", normalize_path(path))

    def listdir(self, path: str) -> list[str]:
        return self._run("listdir", normalize_path(path))

    def link(self, existing: str, new: str) -> None:
        """Hardlink: atomically add the path→inode mapping, bump the link
        count, and append the dirent — the paper's own example txn (§2.4)."""
        return self._run("link", normalize_path(existing), normalize_path(new))

    def unlink(self, path: str) -> None:
        return self._run("unlink", normalize_path(path))

    def rmdir(self, path: str) -> None:
        return self._run("rmdir", normalize_path(path))

    def rename(self, old: str, new: str) -> None:
        return self._run("rename", normalize_path(old), normalize_path(new))

    def stat(self, path: str) -> dict:
        return self._run("stat", normalize_path(path))

    def exists(self, path: str) -> bool:
        return self.kv.get("paths", normalize_path(path)) is not None

    def file_length(self, path: str) -> int:
        return self.stat(path)["size"]

    # ============================================================ op bodies
    # Each _op_* body executes against a WarpKV transaction and must be
    # replayable: artifacts created on first execution (slices, ids) are
    # recorded on the op and reused verbatim on replay (§2.6: the log keeps
    # slice pointers, never data).

    def _op_open(self, ctx: _Ctx, op: _Op, path: str, mode: str,
                 region_size: Optional[int]) -> int:
        create = "w" in mode or "a" in mode or "x" in mode
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            if not create:
                raise NotFound(path)
            ino_id = self._create_file(ctx, op, path, region_size)
            ino = ctx.txn.get("inodes", ino_id)
        else:
            if "x" in mode:
                raise AlreadyExists(path)
            ino = ctx.txn.get("inodes", ino_id)
            if ino is None:
                raise NotFound(f"dangling path {path}")
            if ino.kind == "dir" and ("w" in mode or "a" in mode):
                raise IsADirectory(path)
            if mode == "w":                       # truncate semantics
                # view inode: regions grown by writes queued earlier in
                # THIS transaction must be truncated too
                self._truncate_inode(ctx, self._inode(ctx, ino_id), 0)
        f = _Fd(op.artifacts.setdefault("fd", next(self._fd_counter)),
                ino_id, path, writable=("r" != mode))
        if "a" in mode:
            # O_APPEND: the offset is advisory (tell/read); writes are
            # routed to the file's current EOF at commit time, never to
            # this snapshot — concurrent appenders from other clients may
            # move the EOF between our writes.
            f.append = True
            f.offset = self._file_length(ctx, ino)
        self._fds[f.fd] = f
        return f.fd

    def _create_file(self, ctx: _Ctx, op: _Op, path: str,
                     region_size: Optional[int]) -> int:
        parent = parent_of(path)
        parent_id = ctx.txn.get("paths", parent)
        if parent_id is None:
            raise NotFound(f"parent directory {parent}")
        pino = ctx.txn.get("inodes", parent_id)
        if pino.kind != "dir":
            raise NotADirectory(parent)
        ino_id = op.artifacts.setdefault(
            "ino", self._alloc_inode_id_for(path))
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ino = Inode(ino_id, "file", mtime=now,
                    region_size=region_size or self.cluster.region_size)
        ctx.txn.put("paths", path, ino_id)
        ctx.txn.put("inodes", ino_id, ino)
        self._dir_append(ctx, op, pino, {"op": "add",
                                         "name": basename_of(path),
                                         "ino": ino_id})
        return ino_id

    def _op_read(self, ctx: _Ctx, op: _Op, fd: int, size: int) -> bytes:
        f = self._get_fd(fd)
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        if size < 0:
            size = max(0, length - f.offset)
        size = min(size, max(0, length - f.offset))
        data = self._read_range(ctx, ino, f.offset, size)
        f.offset += len(data)
        self.stats.add(logical_bytes_read=len(data))
        return data

    def _op_pread(self, ctx: _Ctx, op: _Op, fd: int, size: int,
                  offset: int) -> bytes:
        f = self._get_fd(fd)          # EBADF before EINVAL, like POSIX
        if offset < 0:
            raise InvalidOffset(f"pread at negative offset {offset}")
        ino = self._inode(ctx, f.inode_id)
        length = self._file_length(ctx, ino)
        size = min(size, max(0, length - offset))
        data = self._read_range(ctx, ino, offset, size)
        self.stats.add(logical_bytes_read=len(data))
        return data

    def _op_readv(self, ctx: _Ctx, op: _Op, fd: int,
                  ranges: Tuple[Tuple[int, int], ...]) -> List[bytes]:
        f, plans = self._clamped_plans(ctx, fd, ranges)
        out = self._fetch_many(plans, inode_id=f.inode_id)
        self.stats.add(logical_bytes_read=sum(len(b) for b in out),
                       vectored_ops=1)
        return out

    def _op_write(self, ctx: _Ctx, op: _Op, fd: int, data: bytes) -> int:
        f = self._get_wfd(fd)
        if f.append:
            # O_APPEND: land at the CURRENT end of file, atomically.  A
            # positional write at the fd's cached offset would silently
            # overwrite concurrent appenders that opened at the same EOF;
            # the §2.5 relative append makes them commute instead.
            n = self._append_fd(ctx, op, f, data)
        else:
            n = self._write_at(ctx, op, f.inode_id, f.offset, data,
                               key="w")
        f.offset += n
        return n

    def _op_pwrite(self, ctx: _Ctx, op: _Op, fd: int, data: bytes,
                   offset: int) -> int:
        f = self._get_wfd(fd)         # EBADF before EINVAL, like POSIX
        if offset < 0:
            raise InvalidOffset(f"pwrite at negative offset {offset}")
        return self._write_at(ctx, op, f.inode_id, offset, data, key="w")

    def _op_writev(self, ctx: _Ctx, op: _Op, fd: int,
                   chunks: Tuple[bytes, ...]) -> int:
        f = self._get_wfd(fd)
        if f.append:
            # O_APPEND gather-write: the whole batch is one contiguous
            # relative append (chunks stay adjacent, like writev's
            # single-offset contract).
            n = self._append_fd(ctx, op, f, b"".join(chunks))
        else:
            n = self._writev_at(ctx, op, f.inode_id, f.offset, chunks,
                                key="wv")
        f.offset += n
        self.stats.add(vectored_ops=1)
        return n

    def _op_pwritev(self, ctx: _Ctx, op: _Op, fd: int,
                    chunks: Tuple[bytes, ...], offset: int) -> int:
        f = self._get_wfd(fd)         # EBADF before EINVAL, like POSIX
        if offset < 0:
            raise InvalidOffset(f"pwritev at negative offset {offset}")
        n = self._writev_at(ctx, op, f.inode_id, offset, chunks, key="wv")
        self.stats.add(vectored_ops=1)
        return n

    def _op_seek(self, ctx: _Ctx, op: _Op, fd: int, offset: int,
                 whence: int):
        f = self._get_fd(fd)
        if whence == SEEK_SET:
            if offset < 0:
                raise InvalidOffset(f"seek to negative offset {offset}")
            f.offset = offset
            return f.offset
        if whence == SEEK_CUR:
            if f.offset + offset < 0:
                raise InvalidOffset(
                    f"seek to negative offset {f.offset + offset}")
            f.offset += offset
            return f.offset
        if whence == SEEK_END:
            ino = self._inode(ctx, f.inode_id)
            new = self._file_length(ctx, ino) + offset
            if new < 0:
                raise InvalidOffset(f"seek to negative offset {new}")
            f.offset = new
            # The application never observes the end-of-file offset through
            # seek — that's precisely what makes seek(END)+write retryable
            # without an application-visible conflict (§2.6).
            return None
        raise WtfError(f"bad whence {whence}")

    def _op_truncate(self, ctx: _Ctx, op: _Op, fd: int, length: int) -> None:
        f = self._get_wfd(fd)
        ino = self._inode(ctx, f.inode_id)
        self._truncate_inode(ctx, ino, length)

    def _op_mkdir(self, ctx: _Ctx, op: _Op, path: str) -> None:
        if ctx.txn.get("paths", path) is not None:
            raise AlreadyExists(path)
        parent = parent_of(path)
        parent_id = ctx.txn.get("paths", parent)
        if parent_id is None:
            raise NotFound(f"parent directory {parent}")
        pino = ctx.txn.get("inodes", parent_id)
        if pino.kind != "dir":
            raise NotADirectory(parent)
        ino_id = op.artifacts.setdefault(
            "ino", self._alloc_inode_id_for(path))
        now = op.artifacts.setdefault("mtime", self.time_fn())
        ino = Inode(ino_id, "dir", mtime=now,
                    region_size=self.cluster.region_size)
        ctx.txn.put("paths", path, ino_id)
        ctx.txn.put("inodes", ino_id, ino)
        self._dir_append(ctx, op, pino,
                         {"op": "add", "name": basename_of(path),
                          "ino": ino_id})

    def _op_listdir(self, ctx: _Ctx, op: _Op, path: str) -> list[str]:
        ino = self._inode_at(ctx, path)
        if ino.kind != "dir":
            raise NotADirectory(path)
        return sorted(self._dir_entries(ctx, ino).keys())

    def _op_link(self, ctx: _Ctx, op: _Op, existing: str, new: str) -> None:
        from .inode import BumpInode
        ino_id = ctx.txn.get("paths", existing)
        if ino_id is None:
            raise NotFound(existing)
        if ctx.txn.get("paths", new) is not None:
            raise AlreadyExists(new)
        parent_id = ctx.txn.get("paths", parent_of(new))
        if parent_id is None:
            raise NotFound(parent_of(new))
        pino = ctx.txn.get("inodes", parent_id)
        # Atomically: new mapping + link count + dirent (§2.4).
        ctx.txn.put("paths", new, ino_id)
        ctx.txn.commute("inodes", ino_id, BumpInode(link_delta=1))
        self._dir_append(ctx, op, pino,
                         {"op": "add", "name": basename_of(new),
                          "ino": ino_id})

    def _op_unlink(self, ctx: _Ctx, op: _Op, path: str) -> None:
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        ino = ctx.txn.get("inodes", ino_id)
        if ino.kind == "dir":
            raise IsADirectory(path)
        parent_id = ctx.txn.get("paths", parent_of(path))
        pino = ctx.txn.get("inodes", parent_id)
        ctx.txn.delete("paths", path)
        self._dir_append(ctx, op, pino,
                         {"op": "del", "name": basename_of(path)})
        if ino.links <= 1:
            # Last link: drop the inode and all region metadata; the slices
            # become garbage for the tier-3 collector (§2.8).
            ctx.txn.delete("inodes", ino_id)
            for r in range(ino.max_region + 1):
                ctx.txn.delete("regions", region_key(ino_id, r))
        else:
            ctx.txn.put("inodes", ino_id, ino.replace(links=ino.links - 1))

    def _op_rmdir(self, ctx: _Ctx, op: _Op, path: str) -> None:
        if path == "/":
            raise WtfError("cannot remove the root directory")
        ino_id = ctx.txn.get("paths", path)
        if ino_id is None:
            raise NotFound(path)
        ino = ctx.txn.get("inodes", ino_id)
        if ino.kind != "dir":
            raise NotADirectory(path)
        if self._dir_entries(ctx, ino):
            raise DirectoryNotEmpty(path)
        parent_id = ctx.txn.get("paths", parent_of(path))
        pino = ctx.txn.get("inodes", parent_id)
        ctx.txn.delete("paths", path)
        ctx.txn.delete("inodes", ino_id)
        ctx.txn.delete("regions", region_key(ino_id, 0))
        self._dir_append(ctx, op, pino,
                         {"op": "del", "name": basename_of(path)})

    def _op_rename(self, ctx: _Ctx, op: _Op, old: str, new: str) -> None:
        ino_id = ctx.txn.get("paths", old)
        if ino_id is None:
            raise NotFound(old)
        if ctx.txn.get("paths", new) is not None:
            raise AlreadyExists(new)
        ino = ctx.txn.get("inodes", ino_id)
        if ino.kind == "dir" and (new + "/").startswith(old + "/"):
            # Renaming a directory into its own subtree would orphan the
            # whole subtree behind an unreachable path (a cycle in POSIX
            # terms: rename(2) reports EINVAL for this).
            raise WtfError(
                f"cannot rename directory {old} into its own subtree {new}")
        old_pid = ctx.txn.get("paths", parent_of(old))
        new_pid = ctx.txn.get("paths", parent_of(new))
        if new_pid is None:
            raise NotFound(parent_of(new))
        new_pino = ctx.txn.get("inodes", new_pid)
        if new_pino is None or new_pino.kind != "dir":
            # e.g. rename into "/some/file.txt/x": the dirent must never be
            # appended into a regular file's data (ENOTDIR).
            raise NotADirectory(parent_of(new))
        ctx.txn.delete("paths", old)
        ctx.txn.put("paths", new, ino_id)
        self._dir_append(ctx, op, ctx.txn.get("inodes", old_pid),
                         {"op": "del", "name": basename_of(old)}, key="d1")
        self._dir_append(ctx, op, ctx.txn.get("inodes", new_pid),
                         {"op": "add", "name": basename_of(new),
                          "ino": ino_id}, key="d2")

    def _op_stat(self, ctx: _Ctx, op: _Op, path: str) -> dict:
        ino = self._inode_at(ctx, path)
        return {
            "inode": ino.inode_id,
            "kind": ino.kind,
            "links": ino.links,
            "mtime": ino.mtime,
            "mode": ino.mode,
            "size": self._file_length(ctx, ino),
            "region_size": ino.region_size,
        }

    # ----------------------------------------------------------- dir files
    # Directories are special files (§2.4): their content is a record log of
    # add/del entries, maintained with the same append machinery as data.
    def _dir_append(self, ctx: _Ctx, op: _Op, dir_ino: Inode, record: dict,
                    key: str = "d") -> None:
        data = jsonio.dumps(record) + b"\n"
        full = self._data_slice(ctx, op, dir_ino, 0, data, key=key)
        # routes through the compaction-aware append: a busy directory's
        # record log is exactly the hot-region small-append stream the
        # commit-time compaction threshold exists to bound
        self._commute_region_append(
            ctx, dir_ino.inode_id, 0,
            AppendExtents([Extent(0, len(data), full.ptrs)],
                          relative=True, bound=dir_ino.region_size))
        self._bump(ctx, dir_ino.inode_id, op, max_region=0)

    def _dir_entries(self, ctx: _Ctx, dir_ino: Inode) -> dict[str, int]:
        length = self._file_length(ctx, dir_ino)
        raw = self._read_range(ctx, dir_ino, 0, length)
        entries: dict[str, int] = {}
        for line in raw.split(b"\n"):
            if not line.strip(b"\x00"):
                continue
            rec = jsonio.loads(line)
            if rec["op"] == "add":
                entries[rec["name"]] = rec["ino"]
            else:
                entries.pop(rec["name"], None)
        return entries
