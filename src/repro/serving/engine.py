"""Continuous-batching serving engine over the paged KV cache.

WTF's design, end to end: the PagedKVCache (metadata manager) plays the
HyperDex role — page tables are metadata lists, pages are slices, prefix
forking is `copy` — while the device pools play the storage servers.  The
decode step consumes the page table DIRECTLY via the Pallas
`paged_attention` kernel; gathered K/V is never materialized.

Dense-family models (smollm / qwen2 / command-r / mistral / llava-text).
Layout: pools [L, P, T, Hkv, D] on device; prefill writes a prompt's K/V
into its pages in one fused step, decode appends one token per step for
the whole batch.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.models import layers as L
from .kv_cache import CacheConfig, PagedKVCache


@dataclass
class EngineConfig:
    page_tokens: int = 16
    num_pages: int = 2048
    max_seqs: int = 64
    max_tokens: int = 512
    use_kernel_interpret: bool = True     # CPU: Pallas interpret mode


@dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, cfg: EngineConfig = EngineConfig()):
        if model.cfg.arch_kind not in ("dense", "vlm"):
            raise ValueError("paged engine supports the dense family")
        self.model = model
        self.mcfg = model.cfg
        self.cfg = cfg
        hd = self.mcfg.head_dim_
        self.cache = PagedKVCache(CacheConfig(
            num_layers=self.mcfg.n_layers,
            num_kv_heads=self.mcfg.n_kv_heads, head_dim=hd,
            page_tokens=cfg.page_tokens, num_pages=cfg.num_pages,
            max_seqs=cfg.max_seqs, dtype="float32"), allocate=False)
        dt = jnp.dtype(self.mcfg.compute_dtype)
        shape = (self.mcfg.n_layers, cfg.num_pages, cfg.page_tokens,
                 self.mcfg.n_kv_heads, hd)
        self.k_pool = jnp.zeros(shape, dt)
        self.v_pool = jnp.zeros(shape, dt)
        # reserved scratch page: prefill writes of already-shared prefix
        # positions are redirected here so shared pages stay immutable
        self.scratch_page = self.cache._alloc_page()
        self.params = params
        self._requests: Dict[int, Request] = {}
        self._next_id = 0
        self._prefill = jax.jit(functools.partial(
            _prefill_step, cfg=self.mcfg,
            page_tokens=cfg.page_tokens))
        self._decode = jax.jit(functools.partial(
            _decode_step, cfg=self.mcfg, page_tokens=cfg.page_tokens,
            interpret=cfg.use_kernel_interpret))

    # ------------------------------------------------------------ requests
    def add(self, prompt: np.ndarray, max_new: int = 16,
            fork_from: Optional[int] = None) -> int:
        """Admit a request.  `fork_from` shares the parent's prefix pages
        (WTF `copy`: refcounted, zero data movement) — only the new suffix
        is prefilled."""
        sid = self._next_id
        self._next_id += 1
        shared = 0
        if fork_from is not None:
            self.cache.fork(fork_from, sid)
            shared = self.cache.seq_len[sid]
            # only positions past the shared prefix need prefill
            assert len(prompt) >= shared, "fork prefix longer than prompt"
            if shared % self.cfg.page_tokens:
                # shared prefix ends mid-page: COW the open page so the
                # suffix prefill cannot touch the parent's copy
                self._cow_page(sid, shared // self.cfg.page_tokens)
        else:
            self.cache.create(sid)
        req = Request(sid, prompt, max_new)
        if len(prompt) > shared:
            # the prefill's last-position logits ARE the first output token
            req.out.append(self._run_prefill(sid, prompt, shared))
            req.done = len(req.out) >= max_new
        self._requests[sid] = req
        return sid

    def _cow_page(self, sid: int, page_idx: int) -> None:
        tbl = self.cache.page_table[sid]
        pid = tbl[page_idx]
        if self.cache.refcount[pid] <= 1:
            return
        new = self.cache._alloc_page()
        self.k_pool = self.k_pool.at[:, new].set(self.k_pool[:, pid])
        self.v_pool = self.v_pool.at[:, new].set(self.v_pool[:, pid])
        self.cache._release_page(pid)
        tbl[page_idx] = new
        self.cache.stats["pages_copied"] += 1

    def _ensure_pages(self, sid: int, upto: int) -> None:
        t = self.cfg.page_tokens
        table = self.cache.page_table[sid]
        while len(table) * t < upto:
            table.append(self.cache._alloc_page())

    def _run_prefill(self, sid: int, prompt: np.ndarray,
                     start: int) -> int:
        n = len(prompt)
        self._ensure_pages(sid, n)
        table = np.asarray(self.cache.page_table[sid], np.int32)
        next_tok, kp, vp = self._prefill(
            self.params, self.k_pool, self.v_pool,
            jnp.asarray(prompt[None, :], jnp.int32),
            jnp.asarray(table[None, :]),
            jnp.asarray(start, jnp.int32),
            jnp.asarray(self.scratch_page, jnp.int32))
        self.k_pool, self.v_pool = kp, vp
        self.cache.seq_len[sid] = n
        return int(next_tok[0])

    # --------------------------------------------------------------- step
    def step(self) -> List[int]:
        """One decode step for every active sequence; returns finished ids."""
        active = [r for r in self._requests.values() if not r.done]
        if not active:
            return []
        b = len(active)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i, r in enumerate(active):
            tokens[i, 0] = r.out[-1]       # prefill seeded out[0]
            pos[i] = self.cache.seq_len[r.seq_id]
            # COW before writing into a shared open page
            t = self.cfg.page_tokens
            tbl = self.cache.page_table[r.seq_id]
            self._ensure_pages(r.seq_id, int(pos[i]) + 1)
            pid = tbl[int(pos[i]) // t]
            if self.cache.refcount[pid] > 1:
                new = self.cache._alloc_page()
                self.k_pool = self.k_pool.at[:, new].set(
                    self.k_pool[:, pid])
                self.v_pool = self.v_pool.at[:, new].set(
                    self.v_pool[:, pid])
                self.cache._release_page(pid)
                tbl[int(pos[i]) // t] = new
                self.cache.stats["pages_copied"] += 1

        tbl_arr, _ = self.cache.table_array([r.seq_id for r in active])
        next_tok, self.k_pool, self.v_pool = self._decode(
            self.params, self.k_pool, self.v_pool,
            jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(np.maximum(tbl_arr, 0)))
        next_tok = np.asarray(next_tok)
        finished = []
        for i, r in enumerate(active):
            self.cache.seq_len[r.seq_id] += 1
            r.out.append(int(next_tok[i]))
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(r.seq_id)
        return finished

    def result(self, sid: int) -> List[int]:
        return self._requests[sid].out

    def release(self, sid: int) -> None:
        self.cache.release(sid)
        self._requests.pop(sid, None)


# ---------------------------------------------------------------- compute
def _qkv(p, y, cfg, pos):
    dt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_

    def proj(name, heads):
        out = jnp.einsum("bsd,dhk->bshk", y,
                         L.cast(p[name], dt).reshape(cfg.d_model, heads,
                                                     hd))
        if cfg.qkv_bias and f"{name}_b" in p:
            out = out + L.cast(p[f"{name}_b"], dt).reshape(1, 1, heads, hd)
        return out

    q = L.apply_rope(proj("wq", cfg.n_heads), pos, cfg.rope_theta)
    k = L.apply_rope(proj("wk", cfg.n_kv_heads), pos, cfg.rope_theta)
    v = proj("wv", cfg.n_kv_heads)
    return q, k, v


def _scatter_pages(pool_l, vals, table, positions, page_tokens):
    """Write vals [B,S,Hkv,D] into pool_l [P,T,Hkv,D] at page slots."""
    b, s = vals.shape[:2]
    pages = jnp.take_along_axis(
        table, positions // page_tokens, axis=1)          # [B,S]
    slots = positions % page_tokens
    return pool_l.at[pages.reshape(-1), slots.reshape(-1)].set(
        vals.reshape(b * s, *vals.shape[2:]))


def _prefill_step(params, k_pool, v_pool, tokens, table, start,
                  scratch_page, *, cfg, page_tokens):
    """Full-prompt forward: writes K/V pages, returns updated pools.
    tokens/table: [1, S] / [1, PP].  Positions < `start` belong to a
    shared (immutable) prefix — their writes are redirected to the
    reserved scratch page."""
    x = L.embed(params, tokens, cfg, None)
    s = tokens.shape[1]
    pos = jnp.arange(s)[None, :]

    def body(x, p):
        y = L.rms_norm(x, p["ln"], cfg.norm_eps)
        q, k, v = _qkv(p, y, cfg, pos)
        attn = L.attention(q, k, v, causal=True,
                           sliding_window=cfg.sliding_window)
        dt = jnp.dtype(cfg.compute_dtype)
        o = jnp.einsum("bshk,hkd->bsd", attn,
                       L.cast(p["wo"], dt).reshape(cfg.n_heads,
                                                   cfg.head_dim_,
                                                   cfg.d_model))
        x = x + o
        ln2 = p["ln2"] if "ln2" in p else p["ln"]
        x = x + L.swiglu({**p, "ln": ln2}, x, cfg)
        return x, (k, v)

    def scan_body(x, p):
        x, kv = body(x, p)
        return x, kv

    x, (ks, vs) = jax.lax.scan(scan_body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, x[:, -1:], cfg, None)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    # ks: [L, 1, S, Hkv, D] → scatter each layer; shared-prefix positions
    # go to the scratch page (their real pages are shared + already filled)
    shared_mask = pos < start                              # [1, S]
    eff_table = table

    def write(pool, vals):
        def per_layer(pool_l, vals_l):
            pages = jnp.take_along_axis(eff_table, pos // page_tokens,
                                        axis=1)
            pages = jnp.where(shared_mask, scratch_page, pages)
            slots = pos % page_tokens
            b, s = vals_l.shape[:2]
            return pool_l.at[pages.reshape(-1), slots.reshape(-1)].set(
                vals_l.reshape(b * s, *vals_l.shape[2:]))
        return jax.vmap(per_layer)(pool, vals)

    k_pool = write(k_pool, ks.astype(k_pool.dtype))
    v_pool = write(v_pool, vs.astype(v_pool.dtype))
    return next_tok, k_pool, v_pool


def _decode_step(params, k_pool, v_pool, tokens, pos, table, *,
                 cfg, page_tokens, interpret):
    """One token for B sequences against the paged cache."""
    x = L.embed(params, tokens, cfg, None)
    lengths = pos + 1

    def body(x, inp):
        p, li = inp
        y = L.rms_norm(x, p["ln"], cfg.norm_eps)
        q, k, v = _qkv(p, y, cfg, pos[:, None])
        # write this token's K/V into its page
        kp = _scatter_pages(k_pool[li], k.astype(k_pool.dtype), table,
                            pos[:, None], page_tokens)
        vp = _scatter_pages(v_pool[li], v.astype(v_pool.dtype), table,
                            pos[:, None], page_tokens)
        attn = paged_attention_kernel(
            q[:, 0], jnp.moveaxis(kp, 2, 0), jnp.moveaxis(vp, 2, 0),
            table, lengths, interpret=interpret)[:, None]
        dt = jnp.dtype(cfg.compute_dtype)
        o = jnp.einsum("bshk,hkd->bsd", attn.astype(dt),
                       L.cast(p["wo"], dt).reshape(cfg.n_heads,
                                                   cfg.head_dim_,
                                                   cfg.d_model))
        x = x + o
        ln2 = p["ln2"] if "ln2" in p else p["ln"]
        x = x + L.swiglu({**p, "ln": ln2}, x, cfg)
        return x, (kp, vp)

    n_layers = cfg.n_layers
    li = jnp.arange(n_layers)
    x, (kps, vps) = jax.lax.scan(body, x, (params["layers"], li))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, x, cfg, None)
    return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
            kps, vps)
