"""Serving substrate: slice-paged KV cache with prefix sharing, and the
continuous-batching engine (`engine.py`)."""
from .kv_cache import CacheConfig, OutOfPages, PagedKVCache

__all__ = ["PagedKVCache", "CacheConfig", "OutOfPages"]
