"""Paged KV cache — WTF's slice indirection applied to attention state.

The mapping is exact:

  WTF slice            ≙  an immutable, full KV page
  WTF slice pointer    ≙  a page id in the page table
  metadata list        ≙  a sequence's page table row
  ``copy``/``concat``  ≙  prefix sharing between requests (refcounted, zero
                          data movement)
  tier-3 GC            ≙  refcount reclamation to the free list

Pages are immutable once full; the *open* (last, partially filled) page is
private to its sequence and is copy-on-write when a fork happens mid-page.
The Pallas ``paged_attention`` kernel consumes (pages, page_table, lengths)
directly — the indirection never gets materialized.

The pool is a host-side numpy structure in this reference implementation
(the dry-run models its device layout); all bookkeeping is O(pages touched).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclass
class CacheConfig:
    num_layers: int
    num_kv_heads: int
    head_dim: int
    page_tokens: int = 16          # tokens per page
    num_pages: int = 1024          # pool size (per layer pair K/V)
    max_seqs: int = 64
    dtype: str = "float32"


class PagedKVCache:
    def __init__(self, cfg: CacheConfig, allocate: bool = True):
        self.cfg = cfg
        shape = (cfg.num_layers, cfg.num_pages, cfg.page_tokens,
                 cfg.num_kv_heads, cfg.head_dim)
        # allocate=False → metadata-only mode: an engine owns the pools
        # (device arrays) and uses this object purely as the page-table /
        # refcount manager (the WTF metadata layer)
        self.k_pages = np.zeros(shape if allocate else (0,),
                                dtype=cfg.dtype)
        self.v_pages = np.zeros(shape if allocate else (0,),
                                dtype=cfg.dtype)
        self.refcount = np.zeros(cfg.num_pages, dtype=np.int32)
        self._free: List[int] = list(range(cfg.num_pages - 1, -1, -1))
        # per-sequence state
        self.page_table: Dict[int, List[int]] = {}
        self.seq_len: Dict[int, int] = {}
        self.stats = {"pages_allocated": 0, "pages_shared": 0,
                      "pages_copied": 0, "pages_freed": 0}

    # ------------------------------------------------------------ plumbing
    def _alloc_page(self) -> int:
        if not self._free:
            raise OutOfPages("KV page pool exhausted")
        pid = self._free.pop()
        self.refcount[pid] = 1
        self.stats["pages_allocated"] += 1
        return pid

    def _release_page(self, pid: int) -> None:
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self._free.append(pid)
            self.stats["pages_freed"] += 1

    def free_pages(self) -> int:
        return len(self._free)

    # ------------------------------------------------------------- seq API
    def create(self, seq_id: int) -> None:
        if seq_id in self.page_table:
            raise ValueError(f"sequence {seq_id} already exists")
        self.page_table[seq_id] = []
        self.seq_len[seq_id] = 0

    def release(self, seq_id: int) -> None:
        for pid in self.page_table.pop(seq_id):
            self._release_page(pid)
        del self.seq_len[seq_id]

    def append(self, seq_id: int, k: np.ndarray, v: np.ndarray) -> None:
        """Append ``t`` tokens of K/V: k,v shape
        [num_layers, t, num_kv_heads, head_dim]."""
        cfg = self.cfg
        t = k.shape[1]
        pos = self.seq_len[seq_id]
        table = self.page_table[seq_id]
        done = 0
        while done < t:
            page_slot = pos % cfg.page_tokens
            if page_slot == 0:
                table.append(self._alloc_page())
            pid = table[-1]
            if self.refcount[pid] > 1:
                # shared open page → copy-on-write before mutating
                pid = self._cow(table, len(table) - 1)
            take = min(t - done, cfg.page_tokens - page_slot)
            self.k_pages[:, pid, page_slot:page_slot + take] = \
                k[:, done:done + take]
            self.v_pages[:, pid, page_slot:page_slot + take] = \
                v[:, done:done + take]
            pos += take
            done += take
        self.seq_len[seq_id] = pos

    def _cow(self, table: List[int], idx: int) -> int:
        old = table[idx]
        new = self._alloc_page()
        self.k_pages[:, new] = self.k_pages[:, old]
        self.v_pages[:, new] = self.v_pages[:, old]
        self._release_page(old)
        table[idx] = new
        self.stats["pages_copied"] += 1
        return new

    def fork(self, parent: int, child: int) -> None:
        """Prefix sharing: the child references the parent's pages (WTF
        ``copy`` — metadata only).  Full pages are shared by refcount; the
        open page will be copy-on-written by whichever sequence appends."""
        if child in self.page_table:
            raise ValueError(f"sequence {child} already exists")
        table = list(self.page_table[parent])
        for pid in table:
            self.refcount[pid] += 1
        self.page_table[child] = table
        self.seq_len[child] = self.seq_len[parent]
        self.stats["pages_shared"] += len(table)

    # ------------------------------------------------------------ reads
    def gather(self, seq_id: int, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize a sequence's K/V for one layer (reference path; the
        Pallas kernel reads pages in place instead)."""
        cfg = self.cfg
        n = self.seq_len[seq_id]
        table = self.page_table[seq_id]
        k = np.zeros((n, cfg.num_kv_heads, cfg.head_dim), dtype=cfg.dtype)
        v = np.zeros_like(k)
        for i in range(0, n, cfg.page_tokens):
            pid = table[i // cfg.page_tokens]
            take = min(cfg.page_tokens, n - i)
            k[i:i + take] = self.k_pages[layer, pid, :take]
            v[i:i + take] = self.v_pages[layer, pid, :take]
        return k, v

    def table_array(self, seq_ids: List[int],
                    max_pages: Optional[int] = None) -> Tuple[np.ndarray,
                                                              np.ndarray]:
        """(page_table, lengths) arrays for a decode batch — the kernel's
        input format.  Unused entries are -1."""
        if max_pages is None:
            max_pages = max((len(self.page_table[s]) for s in seq_ids),
                            default=1)
        tbl = np.full((len(seq_ids), max_pages), -1, dtype=np.int32)
        lens = np.zeros(len(seq_ids), dtype=np.int32)
        for i, s in enumerate(seq_ids):
            row = self.page_table[s]
            tbl[i, :len(row)] = row
            lens[i] = self.seq_len[s]
        return tbl, lens
