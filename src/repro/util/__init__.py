"""Small dependency-free utilities shared across the repro packages."""
