"""JSON (de)serialization shim: orjson when available, stdlib otherwise.

The container image does not ship ``orjson``; everything that serializes
metadata (directory records, extent spills, checkpoint manifests) goes
through this module so the hard dependency becomes a fast path instead of
an import-time crash.  ``dumps`` always returns ``bytes`` (orjson's
contract), and ``loads`` accepts ``bytes``/``str`` interchangeably.
"""
from __future__ import annotations

from typing import Any

try:
    import orjson as _orjson

    def dumps(obj: Any) -> bytes:
        return _orjson.dumps(obj)

    def loads(data) -> Any:
        return _orjson.loads(data)

    BACKEND = "orjson"
except ImportError:                                   # pragma: no cover
    import json as _json

    def dumps(obj: Any) -> bytes:
        return _json.dumps(obj, separators=(",", ":")).encode("utf-8")

    def loads(data) -> Any:
        if isinstance(data, (bytes, bytearray, memoryview)):
            data = bytes(data).decode("utf-8")
        return _json.loads(data)

    BACKEND = "json"
