"""Post-optimization HLO text analyzer.

XLA's `compiled.cost_analysis()` visits a while-loop body ONCE — a
scan-over-layers model under-reports FLOPs by ~n_layers× (verified
empirically; see EXPERIMENTS.md §Dry-run).  Every model here scans over
layers, so we parse `compiled.as_text()` ourselves and propagate
`known_trip_count` multipliers through the call graph:

  * dot FLOPs       — 2 · prod(result) · prod(lhs contracting dims),
                      counted inside fusion bodies too;
  * boundary bytes  — operand+result bytes of top-level (non-fused) ops;
                      fusion internals never touch HBM, so a fusion op's
                      boundary is exactly the HBM-traffic model;
  * collectives     — operand bytes + replica-group size per op, from
                      which the roofline computes ring wire bytes.

The module is SPMD-partitioned → all shapes (and all terms) are PER-DEVICE.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")

# call-graph ops and ops excluded from byte/flop accounting
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "add-dependency", "partition-id",
             "replica-id", "iota", "custom-call"}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt",
    "logistic", "cosine", "sine", "and", "or", "xor", "not", "compare",
    "select", "clamp", "convert", "floor", "ceil", "sign",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
}


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def shape_bytes(type_str: str) -> int:
    """Total bytes of all arrays in a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in DTYPE_BYTES:
            total += _elems(dims) * DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    return sum(_elems(dims) for dt, dims in _SHAPE_RE.findall(type_str)
               if dt in DTYPE_BYTES)


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operand_names: List[str]
    attrs: str
    trip_count: int = 1

    def called(self) -> List[str]:
        out = _CALLS_RE.findall(self.attrs)
        b = _BRANCH_RE.search(self.attrs)
        if b:
            out += [c.strip().lstrip("%") for c in b.group(1).split(",")]
        return out


@dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symtab: Dict[str, str]          # instruction/parameter name → type


@dataclass
class Analysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_ops: List[dict] = field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        """Raw operand bytes through collectives (per device)."""
        return float(sum(o["operand_bytes"] * o["count"]
                         for o in self.collective_ops))


def _split_type_opcode(rest: str) -> Tuple[str, str, str]:
    """'f32[8]{1,0} dot(%a, %b), attrs' → (type, opcode, tail)."""
    rest = rest.strip()
    if rest.startswith("("):                       # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[:i + 1]
                    rest2 = rest[i + 1:].lstrip()
                    break
        else:
            return rest, "", ""
    else:
        sp = rest.find(" ")
        if sp < 0:
            return rest, "", ""
        type_str, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    par = rest2.find("(")
    if par < 0:
        return type_str, rest2, ""
    return type_str, rest2[:par], rest2[par + 1:]


def _split_operands_attrs(tail: str) -> Tuple[str, str]:
    depth = 1
    for i, ch in enumerate(tail):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return tail[:i], tail[i + 1:]
    return tail, ""


def parse_instruction(line: str) -> Optional[Instr]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    type_str, opcode, tail = _split_type_opcode(s[eq + 3:])
    if not opcode:
        return None
    operands_str, attrs = _split_operands_attrs(tail)
    operand_names = _NAME_RE.findall(operands_str)
    trip = 1
    t = _TRIP_RE.search(attrs)
    if t:
        trip = int(t.group(1))
    return Instr(name=name, opcode=opcode, result_type=type_str,
                 operand_names=operand_names, attrs=attrs,
                 trip_count=trip)


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HDR_RE.match(line)
            if m and " = " not in line.split("(")[0]:
                current = Computation(m.group(2), [], {})
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
        else:
            if line.strip() == "}":
                current = None
                continue
            ins = parse_instruction(line)
            if ins is not None:
                current.symtab[ins.name] = ins.result_type
                current.instrs.append(ins)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    return sum(shape_bytes(comp.symtab.get(n, "")) for n in
               ins.operand_names)


# ops that touch only a slice of their (first) operand — charging the full
# operand would overcount HBM traffic by the slab size (e.g. a
# dynamic-slice of the stacked [L, ...] scan parameters touches one layer,
# not all L)
_SLICE_READS = {"dynamic-slice", "slice", "gather"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}
_MOVE_OPS = {"copy", "transpose", "concatenate", "pad", "reverse",
             "reshape", "broadcast"}


def _touched_bytes(ins: Instr, comp: Computation) -> float:
    """HBM bytes this op plausibly moves (read + written)."""
    op = ins.opcode
    res = shape_bytes(ins.result_type)
    if op in _SLICE_READS:
        return 2.0 * res                       # read region ≈ result size
    if op in _SLICE_WRITES:
        # read+write the updated region (≈ update operand), not the target
        upd = shape_bytes(comp.symtab.get(ins.operand_names[1], "")) \
            if len(ins.operand_names) > 1 else res
        return 3.0 * upd
    if op in _MOVE_OPS:
        return 2.0 * res
    if op == "iota":
        return float(res)
    return float(_operand_bytes(ins, comp) + res)


_PASSTHROUGH = {"convert", "copy", "bitcast", "reshape", "transpose"}


def _fusion_param_bytes(comp: Computation) -> float:
    """Effective read bytes of a fusion computation's parameters.

    A parameter consumed only as the sliced operand of slice-like ops is
    charged at the sliced size; pass-through ops (convert/copy/bitcast…)
    inherit their consumers' classification — XLA:CPU normalizes bf16
    scatter/DUS by converting whole operands to f32 and back, which would
    otherwise charge a loop-carried KV cache at full size per layer (on
    TPU the bf16 DUS is native and in-place)."""
    params = [i for i in comp.instrs if i.opcode == "parameter"]
    consumers: Dict[str, list] = {}
    by_name = {i.name: i for i in comp.instrs}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            continue
        for j, nm in enumerate(ins.operand_names):
            consumers.setdefault(nm, []).append((ins, j))

    FULL = float("inf")
    memo: Dict[str, float] = {}

    def charge(name: str, depth: int = 0) -> float:
        """Bytes read from `name`'s buffer, or FULL."""
        if name in memo:
            return memo[name]
        if depth > 40:
            return FULL
        memo[name] = FULL                     # cycle guard (conservative)
        total = 0.0
        uses = consumers.get(name, [])
        if not uses:
            memo[name] = 0.0
            return 0.0
        for ins, j in uses:
            if ins.opcode in _SLICE_READS and j == 0:
                total += shape_bytes(ins.result_type)
            elif ins.opcode in _SLICE_WRITES and j == 0:
                upd = shape_bytes(
                    comp.symtab.get(ins.operand_names[1], "")) \
                    if len(ins.operand_names) > 1 else 0.0
                total += 2.0 * upd
            elif ins.opcode in _PASSTHROUGH:
                total += charge(ins.name, depth + 1)
            else:
                total = FULL
                break
        memo[name] = total
        return total

    total = 0.0
    for p in params:
        c = charge(p.name)
        full_b = shape_bytes(p.result_type)
        total += full_b if c == FULL else min(c, full_b)
    return total


def _fusion_result_bytes(comp: Computation, result_bytes: float) -> float:
    """Bytes written by a fusion: if the root is (a pass-through chain
    over) a dynamic-update-slice, only the updated region is written —
    the loop-carried buffer updates in place."""
    root = comp.instrs[-1] if comp.instrs else None
    by_name = {i.name: i for i in comp.instrs}
    seen = 0
    while root is not None and root.opcode in _PASSTHROUGH and seen < 10:
        nxt = by_name.get(root.operand_names[0]) \
            if root.operand_names else None
        if nxt is None:
            break
        root = nxt
        seen += 1
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operand_names) > 1:
        upd = shape_bytes(comp.symtab.get(root.operand_names[1], ""))
        if upd:
            return float(min(upd, result_bytes))
    return float(result_bytes)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res = shape_elems(ins.result_type)
    if not ins.operand_names:
        return 0.0
    lhs_type = comp.symtab.get(ins.operand_names[0], "")
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if cd and cd.group(1):
        for i in cd.group(1).split(","):
            contract *= lhs_dims[int(i)]
    return 2.0 * res * contract


def _group_size(ins: Instr, total_devices: int) -> int:
    gi = _GROUPS_IOTA_RE.search(ins.attrs)
    if gi:
        return int(gi.group(2))
    gl = _GROUPS_LIST_RE.search(ins.attrs)
    if gl and gl.group(1).strip():
        return len(gl.group(1).split(","))
    return total_devices


def analyze(text: str, total_devices: int = 1) -> Analysis:
    comps, entry = parse_computations(text)
    coll: Dict[Tuple[str, int, int], int] = defaultdict(int)
    fusion_cache: Dict[str, float] = {}

    def rec(cname: str, mult: float, in_fusion: bool,
            seen: tuple) -> Tuple[float, float]:
        comp = comps[cname]
        flops = bytes_ = 0.0
        for ins in comp.instrs:
            op = ins.opcode
            if op in _SKIP_OPS:
                continue
            if op == "fusion":
                if not in_fusion:
                    rb = shape_bytes(ins.result_type)
                    pbytes = wbytes = 0.0
                    for c in ins.called():
                        if c in comps:
                            if c not in fusion_cache:
                                fusion_cache[c] = (
                                    _fusion_param_bytes(comps[c]),
                                    _fusion_result_bytes(comps[c], rb))
                            pb, wb = fusion_cache[c]
                            pbytes += pb
                            wbytes += wb
                    bytes_ += (pbytes + (wbytes or rb)) * mult
                for c in ins.called():
                    if c in comps and c not in seen:
                        f, _ = rec(c, mult, True, seen + (c,))
                        flops += f
                continue
            if op == "while":
                m2 = mult * ins.trip_count
                body = [c for c in ins.called() if c in comps]
                for c in body:
                    if c not in seen:
                        f, b = rec(c, m2, in_fusion, seen + (c,))
                        flops += f
                        bytes_ += b
                continue
            if op == "conditional":
                branches = [c for c in ins.called()
                            if c in comps and c not in seen]
                if branches:
                    f, b = max(rec(c, mult, in_fusion, seen + (c,))
                               for c in branches)
                    flops += f
                    bytes_ += b
                continue
            if op == "call":
                for c in ins.called():
                    if c in comps and c not in seen:
                        f, b = rec(c, mult, in_fusion, seen + (c,))
                        flops += f
                        bytes_ += b
                continue
            if op in ("sort", "reduce", "reduce-window", "scatter", "map",
                      "select-and-scatter", "reduce-scatter", "all-reduce"):
                pass        # their to_apply is a scalar lambda — skip walk
            base = None
            for ckind in COLLECTIVES:
                if op == ckind or op == ckind + "-start":
                    base = ckind
                    break
            if base is not None:
                ob = _operand_bytes(ins, comp)
                coll[(base, ob, _group_size(ins, total_devices))] += \
                    max(1, round(mult))
                if not in_fusion:
                    bytes_ += (ob + shape_bytes(ins.result_type)) * mult
                continue
            # ordinary op
            if op == "dot":
                flops += _dot_flops(ins, comp) * mult
            elif op == "convolution":
                # 2 × output elems × kernel elems (upper bound; the models
                # here lower convs to shifted adds, so this op is rare)
                kt = comp.symtab.get(ins.operand_names[1], "") \
                    if len(ins.operand_names) > 1 else ""
                flops += 2.0 * shape_elems(ins.result_type) \
                    * max(1, shape_elems(kt)) * mult
            elif op in ("reduce", "reduce-window"):
                if not in_fusion:
                    flops += (_operand_bytes(ins, comp) / 4.0) * mult
            elif op in _ELEMENTWISE:
                if not in_fusion:
                    flops += shape_elems(ins.result_type) * mult
            if not in_fusion:
                bytes_ += _touched_bytes(ins, comp) * mult
        return flops, bytes_

    flops, bytes_ = rec(entry, 1.0, False, ())
    out = Analysis(flops=flops, bytes_accessed=bytes_)
    for (kind, obytes, gsize), count in sorted(coll.items()):
        out.collective_ops.append({"kind": kind, "operand_bytes": obytes,
                                   "group_size": gsize, "count": count})
    return out
