"""Three-term roofline from the dry-run artifacts.

Hardware model (TPU v5e, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: 50 GB/s (per-chip aggregate used for ring formulas)

Terms (seconds per step, per chip — HLO shapes are already per-device
because the module is SPMD-partitioned):
  compute    = hlo_flops_per_device / 197e12
  memory     = hlo_bytes_per_device / 819e9
  collective = Σ_ops ring_wire_bytes(kind, operand_bytes, group) / 50e9

Ring wire bytes per chip: all-reduce 2·B·(g−1)/g, all-gather/
reduce-scatter/all-to-all B·(g−1)/g, collective-permute B.

MODEL_FLOPS = 6·N_active·D (train), 2·N_active·D (prefill/decode forward
only); the ratio against HLO_FLOPs exposes remat/recompute and quadratic-
attention overhead that 6·N·D does not model.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def wire_bytes(kind: str, operand_bytes: float, group: int) -> float:
    g = max(group, 1)
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * operand_bytes * frac
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return operand_bytes * frac
    if kind == "collective-permute":
        return float(operand_bytes)
    return float(operand_bytes)


def model_flops(rec: dict) -> float:
    n = rec["active_params"]
    tokens = rec["global_batch"] * (rec["seq_len"]
                                    if rec["kind"] != "decode" else 1)
    mult = 6 if rec["kind"] == "train" else 2
    return float(mult * n * tokens)


def terms(rec: dict) -> Dict[str, float]:
    coll = sum(wire_bytes(o["kind"], o["operand_bytes"], o["group_size"])
               * o["count"] for o in rec["collectives"])
    t = {
        "compute_s": rec["hlo_flops_per_device"] / PEAK_FLOPS,
        "memory_s": rec["hlo_bytes_per_device"] / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    t["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: t[k])
    t["bound"] = t["dominant"].split("_")[0]
    mf = model_flops(rec)
    t["model_flops"] = mf
    t["flops_ratio"] = mf / max(1.0, rec["hlo_flops_per_device"]
                                * rec["chips"])
    # roofline fraction: useful model flops per second at the bottleneck
    step_time = t[t["dominant"]]
    t["step_s"] = step_time
    t["mfu"] = mf / (rec["chips"] * PEAK_FLOPS * step_time) \
        if step_time > 0 else 0.0
    return t


def load(mesh: str = "pod") -> List[dict]:
    recs = []
    for p in sorted(ARTIFACT_DIR.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


MOVE_HINTS = {
    ("compute", "train"): "cast more matmuls to bf16 / shard attention "
                          "heads (TP) where divisibility allows",
    ("compute", "prefill"): "flash-attention kernel halves masked-block "
                            "work; shard sequence (SP) across model axis",
    ("compute", "decode"): "batch more sequences per chip; fold GQA "
                           "groups into one matmul pane",
    ("memory", "train"): "raise accum_steps (microbatching) and remat to "
                         "shrink live activations; bf16 cache",
    ("memory", "prefill"): "fuse attention (flash) to avoid S² logits in "
                           "HBM",
    ("memory", "decode"): "decode is KV-bandwidth bound by nature: "
                          "shrink cache via windowing/quantization, or "
                          "raise batch to amortize weight reads",
    ("collective", "train"): "overlap grad all-reduce with backward; "
                             "int8-compress cross-pod gradients",
    ("collective", "prefill"): "reduce TP collectives per layer by "
                               "batching all-gathers",
    ("collective", "decode"): "keep params resident (no per-step "
                              "all-gather); shrink TP degree for decode",
}


def row(rec: dict) -> Optional[dict]:
    if rec["status"] == "skip":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "skip": rec["reason"]}
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "skip": f"ERROR {rec.get('error', '?')[:80]}"}
    t = terms(rec)
    hint = MOVE_HINTS.get((t["bound"], rec["kind"]), "")
    return {"arch": rec["arch"], "shape": rec["shape"],
            "kind": rec["kind"], "chips": rec["chips"], **t,
            "hlo_flops_dev": rec["hlo_flops_per_device"],
            "hlo_bytes_dev": rec["hlo_bytes_per_device"],
            "hint": hint}


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def markdown_table(mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL_FLOPS | MF/HLO | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        r = row(rec)
        if r is None:
            continue
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP | — | — | {r['skip'][:70]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['bound']}** | {r['model_flops']:.2e} | "
            f"{r['flops_ratio']:.3f} | {r['hint']} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()
    print(markdown_table(args.mesh))


if __name__ == "__main__":
    main()
