"""Array/pytree (de)serialization for WTF checkpoints.

Layout: each leaf is one WTF file of raw little-endian bytes; a checkpoint's
``manifest`` records the tree structure, dtypes, shapes, and per-leaf
content digests.  Digests enable incremental checkpoints (unchanged leaves
are ``copy``'d — zero data I/O), and the manifest is the unit of atomicity.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.util import jsonio


def flatten_tree(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten a nested dict/list/tuple pytree into {path: leaf}."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(flatten_tree(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_tree(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def unflatten_tree(flat: Dict[str, Any], template: Any) -> Any:
    """Rebuild ``template``'s structure from {path: leaf}."""

    def build(node: Any, prefix: str) -> Any:
        if isinstance(node, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, tuple):
            items = [build(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            if hasattr(node, "_fields"):          # NamedTuple (OptState)
                return type(node)(*items)
            return tuple(items)
        if isinstance(node, list):
            return [build(v, f"{prefix}{i}/") for i, v in enumerate(node)]
        return flat[prefix.rstrip("/")]

    return build(template, "")


def leaf_to_bytes(leaf: Any) -> Tuple[bytes, dict]:
    arr = np.asarray(leaf)
    data = np.ascontiguousarray(arr).tobytes()
    meta = {
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "digest": hashlib.blake2b(data, digest_size=16).hexdigest(),
        "nbytes": len(data),
    }
    return data, meta


def bytes_to_leaf(data: bytes, meta: dict) -> np.ndarray:
    arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]))
    return arr.reshape(meta["shape"])


def encode_manifest(entries: Dict[str, dict], extra: dict) -> bytes:
    return jsonio.dumps({"leaves": entries, **extra})


def decode_manifest(raw: bytes) -> dict:
    return jsonio.loads(raw)
