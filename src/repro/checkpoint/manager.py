"""Transactional distributed checkpointing on WTF.

Why a transactional filesystem is the right substrate for checkpoints at
scale:

* **Atomic multi-host commit.** Each host writes its shard files; the final
  ``commit`` transaction writes the manifest and flips ``latest`` in one
  atomic action.  A reader (restarting job, evaluator) either sees a
  complete checkpoint or the previous one — never a torn one.  Slices are
  durable *before* the metadata commit (§2.1), so the commit is pure
  metadata regardless of checkpoint size.
* **Incremental checkpoints for free.** Unchanged leaves (content digest
  match vs. the previous step) are ``copy``'d — slice sharing, zero data
  I/O (frozen embeddings, optimizer ints, EMA shadows...).
* **Zero-copy resharding.** Changing the device topology (elastic scaling)
  re-partitions each leaf's flat byte range with ``yank``/``paste``
  arithmetic — no data rewrite of multi-TB checkpoints.
* **Retention = unlink.** Dropped checkpoints become storage-server garbage
  that the paper's tier-3 GC reclaims sparsely.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import NotFound, WtfClient
from .serialize import (bytes_to_leaf, decode_manifest, encode_manifest,
                        flatten_tree, leaf_to_bytes, unflatten_tree)


class CheckpointManager:
    def __init__(self, client: WtfClient, root: str = "/ckpt",
                 keep: Optional[int] = None):
        self.client = client
        self.root = root
        self.keep = keep
        if not client.exists(root):
            client.mkdir(root)

    # ------------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return f"{self.root}/step-{step:010d}"

    def _leaf_path(self, step: int, name: str, shard: int,
                   num_shards: int) -> str:
        safe = name.replace("/", ".")
        return f"{self._step_dir(step)}/{safe}.{shard:04d}-of-{num_shards:04d}"

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, host_id: int = 0,
             num_hosts: int = 1, extra: Optional[dict] = None,
             prev_step: Optional[int] = None) -> dict:
        """Write this host's shards, then (host 0) atomically commit.

        Leaves are sharded across hosts on their leading axis when possible;
        small leaves are written by host 0 alone.  With ``prev_step`` given,
        unchanged leaves are shared with the previous checkpoint via
        ``copy`` instead of rewritten (incremental checkpointing).
        """
        flat = flatten_tree(tree)
        step_dir = self._step_dir(step)
        if not self.client.exists(step_dir):
            try:
                self.client.mkdir(step_dir)
            except Exception:
                pass                       # another host won the race

        prev_manifest = None
        if prev_step is not None:
            try:
                prev_manifest = self.read_manifest(prev_step)
            except NotFound:
                prev_manifest = None

        entries: Dict[str, dict] = {}
        stats = {"bytes_written": 0, "bytes_shared": 0, "leaves_shared": 0}
        # One transaction per host: the host's shard set publishes
        # atomically, and with write-behind every leaf's stores (plus, for
        # a single-host save, the manifest itself) flush through the write
        # scheduler in ONE planning pass at this commit.
        with self.client.transaction():
            for name, leaf in flat.items():
                data, meta = leaf_to_bytes(leaf)
                shards = self._shards_for(meta, num_hosts)
                meta["shards"] = shards
                entries[name] = meta
                prev = (prev_manifest or {}).get("leaves", {}).get(name)
                if (prev is not None and prev["digest"] == meta["digest"]
                        and prev["shards"] == shards):
                    # Incremental: identical content — share the old slices.
                    if host_id == 0:
                        for s in range(shards):
                            src = self._leaf_path(prev_step, name, s, shards)
                            dst = self._leaf_path(step, name, s, shards)
                            self.client.copy(src, dst)
                        stats["bytes_shared"] += meta["nbytes"]
                        stats["leaves_shared"] += 1
                    continue
                for s in range(shards):
                    if s % num_hosts != host_id:
                        continue           # not this host's shard
                    lo, hi = self._shard_range(meta["nbytes"], shards, s)
                    path = self._leaf_path(step, name, s, shards)
                    with self.client.open_file(path, "w") as f:
                        # writev: the shard's stores are planned as one
                        # batch and fanned out per region by the write
                        # scheduler (wsched) instead of a single
                        # synchronous store round.
                        f.writev([data[lo:hi]])
                    stats["bytes_written"] += hi - lo
            if host_id == 0 and num_hosts == 1:
                # Single-host save: shards + manifest + ``latest`` flip
                # commit (and flush) as one transaction.
                self._commit(step, entries, extra or {})

        if host_id == 0:
            if num_hosts > 1:
                self._commit(step, entries, extra or {})
            if self.keep is not None:
                self.retain(self.keep)
        return stats

    def _commit(self, step: int, entries: Dict[str, dict],
                extra: dict) -> None:
        """The atomic rendezvous: manifest + ``latest`` flip in one txn
        (joins the caller's open transaction when there is one)."""
        c = self.client
        if c._txn is not None:
            self._commit_ops(step, entries, extra)
            return
        with c.transaction():
            self._commit_ops(step, entries, extra)

    def _commit_ops(self, step: int, entries: Dict[str, dict],
                    extra: dict) -> None:
        c = self.client
        with c.open_file(f"{self._step_dir(step)}/manifest", "w") as f:
            f.write(encode_manifest(entries, {"step": step, **extra}))
        latest = f"{self.root}/latest"
        if c.exists(latest):
            c.unlink(latest)
        c.link(f"{self._step_dir(step)}/manifest", latest)

    @staticmethod
    def _shards_for(meta: dict, num_hosts: int) -> int:
        # shard big leaves across hosts; keep small ones whole
        if num_hosts > 1 and meta["nbytes"] >= 1 << 16:
            return num_hosts
        return 1

    @staticmethod
    def _shard_range(nbytes: int, shards: int, s: int) -> Tuple[int, int]:
        per = -(-nbytes // shards)
        return s * per, min(nbytes, (s + 1) * per)

    # -------------------------------------------------------------- restore
    def read_manifest(self, step: Optional[int] = None) -> dict:
        c = self.client
        path = (f"{self.root}/latest" if step is None
                else f"{self._step_dir(step)}/manifest")
        with c.open_file(path, "r") as f:
            raw = f.read()
        return decode_manifest(raw)

    def latest_step(self) -> Optional[int]:
        try:
            return self.read_manifest()["step"]
        except NotFound:
            return None

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        """Rebuild the pytree (all leaves, any host).

        Every shard's fetch is issued through the async I/O runtime before
        the first byte is awaited, so shards fan out across servers and a
        restore completes in roughly one shard's latency per server rather
        than the sum of all shard reads.  Inside an open transaction
        (async ops are auto-commit only) the shards read synchronously,
        preserving the old join-the-caller's-transaction behavior."""
        man = self.read_manifest(step)
        step = man["step"]
        c = self.client
        in_txn = c._txn is not None
        handles, futs = [], []
        parts: Dict[str, List[bytes]] = {}
        try:
            for name, meta in man["leaves"].items():
                for s in range(meta["shards"]):
                    path = self._leaf_path(step, name, s, meta["shards"])
                    f = c.open_file(path, "r")
                    if in_txn:
                        parts.setdefault(name, []).append(f.read())
                        f.close()
                        continue
                    # Shard size comes from the manifest (no per-shard
                    # stat round at submission — the fan-out's win would
                    # otherwise be re-serialized by L×S stat calls).
                    lo, hi = self._shard_range(meta["nbytes"],
                                               meta["shards"], s)
                    handles.append(f)
                    futs.append((name, f.preadv_async([hi - lo], 0)))
            for name, fut in futs:
                parts.setdefault(name, []).append(fut.result()[0])
        finally:
            for f in handles:
                f.close()
        # Single-shard leaves keep the vectored read's zero-copy buffer
        # all the way into np.frombuffer; only multi-shard leaves join.
        flat = {name: bytes_to_leaf(ps[0] if len(ps) == 1 else b"".join(ps),
                                    man["leaves"][name])
                for name, ps in parts.items()}
        return unflatten_tree(flat, template)

    # ------------------------------------------------------------ reshard
    def reshard(self, step: int, new_shards: int, dst_step: int) -> None:
        """Re-partition every leaf for a new host count — zero data I/O.

        Each new shard file is a ``concat`` of yanked byte ranges of the old
        shard files; multi-TB checkpoints reshard in metadata time.
        """
        man = self.read_manifest(step)
        c = self.client
        if not c.exists(self._step_dir(dst_step)):
            c.mkdir(self._step_dir(dst_step))
        new_entries: Dict[str, dict] = {}
        for name, meta in man["leaves"].items():
            old_n = meta["shards"]
            n = new_shards if meta["nbytes"] >= 1 << 16 else 1
            with c.transaction():
                # yank each old shard fully (positional vectored yank —
                # no seek/stat dance), building the flat extent list
                flat_extents = []
                for s in range(old_n):
                    lo, hi = self._shard_range(meta["nbytes"], old_n, s)
                    path = self._leaf_path(step, name, s, old_n)
                    with c.open_file(path, "r") as f:
                        flat_extents.extend(f.yankv([(0, hi - lo)])[0])
                # paste computed byte ranges into the new shard files
                for s in range(n):
                    lo, hi = self._shard_range(meta["nbytes"], n, s)
                    path = self._leaf_path(dst_step, name, s, n)
                    with c.open_file(path, "w") as f:
                        f.paste(_carve(flat_extents, lo, hi - lo))
            new_entries[name] = {**meta, "shards": n}
        self._commit(dst_step, new_entries,
                     {"resharded_from": step, "step": dst_step})

    # ------------------------------------------------------------ retention
    def list_steps(self) -> List[int]:
        out = []
        for name in self.client.listdir(self.root):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def retain(self, keep: int) -> List[int]:
        """Unlink all but the newest ``keep`` checkpoints; slices become
        tier-3 garbage reclaimed by the storage GC."""
        steps = self.list_steps()
        victims = steps[:-keep] if keep > 0 else []
        for step in victims:
            d = self._step_dir(step)
            for name in self.client.listdir(d):
                self.client.unlink(f"{d}/{name}")
            self.client.rmdir(d)
        return victims


def _carve(extents: Sequence[Any], start: int, length: int) -> list:
    """Sub-range [start, start+length) of a concatenated extent list."""
    out = []
    cursor = 0
    for e in extents:
        lo = max(start, cursor)
        hi = min(start + length, cursor + e.length)
        if lo < hi:
            out.append(e.sub(lo - cursor, hi - lo))
        cursor += e.length
        if cursor >= start + length:
            break
    return out


class AsyncCheckpointer:
    """Off-critical-path checkpointing on the unified I/O runtime: the
    whole shard save runs as one submitted op on the cluster's pool (no
    ad-hoc thread), and the trainer only blocks if a previous save is
    still in flight (one outstanding save, preserving step order).  A
    failed save re-raises on the next ``wait``/``save``.

    Saves run through a PRIVATE client bound to the same cluster: the
    save's transaction would otherwise set the shared client's ``_txn``
    from a pool worker, making every concurrent async op on that client
    (e.g. the data pipeline's prefetcher) spuriously reject itself —
    clients are one-per-thread by contract, and the worker is a thread.
    """

    def __init__(self, manager: CheckpointManager):
        self.manager = manager
        self._mgr = CheckpointManager(manager.client.cluster.client(),
                                      manager.root, keep=manager.keep)
        self._fut = None

    def save(self, step: int, tree: Any, **kw) -> None:
        self.wait()
        # Snapshot leaves NOW (cheap on host) so the trainer may mutate.
        snap = {k: np.array(v) for k, v in flatten_tree(tree).items()}
        runtime = self._mgr.client.cluster.runtime
        self._fut = runtime.submit_op(
            lambda: self._mgr.save(step, snap, **kw))

    def wait(self) -> None:
        if self._fut is not None:
            fut, self._fut = self._fut, None
            fut.result()                    # re-raises a failed save
