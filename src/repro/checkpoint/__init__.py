"""Transactional distributed checkpointing on WTF: atomic multi-host
commits, incremental (slice-shared) saves, zero-copy resharding."""
from .manager import AsyncCheckpointer, CheckpointManager
from .serialize import flatten_tree, unflatten_tree

__all__ = ["CheckpointManager", "AsyncCheckpointer", "flatten_tree",
           "unflatten_tree"]
