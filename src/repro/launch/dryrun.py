import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")

"""Multi-pod AOT dry-run: lower + compile every (arch × shape × mesh) cell
against the production meshes, record memory/cost/collective artifacts.

This module — and ONLY this module — forces 512 host devices, before any
other import (jax locks the device count on first init).  Smoke tests and
benchmarks see the real single CPU device.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both [--force]
Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are
skipped if present (delete or --force to redo); EXPERIMENTS.md §Dry-run and
§Roofline are generated from them by repro.roofline.report.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCHS, SHAPES, get_config, get_shape
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import get_model
from repro.models.common import decode_window
from repro.parallel.sharding import make_rules, spec_for, tree_shardings, P
from repro.roofline.hlo_analysis import analyze
from repro.train import TrainHyper, abstract_state, make_prefill_step, \
    make_serve_step, make_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def cell_config(arch: str, shape_name: str):
    """Per-cell config adjustments (documented in DESIGN.md):
    - long_500k applies `long_context_window` to attention sites;
    - whisper decode cells size the learned-position table to seq_len;
    - the dry-run always lowers the XLA attention path (Pallas kernels are
      validated separately in interpret mode — they don't lower for the
      host platform)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cfg = cfg.replace(attn_impl="xla")
    if shape_name == "long_500k" and cfg.long_context_window is not None:
        cfg = cfg.replace(sliding_window=cfg.long_context_window)
    if cfg.encdec is not None or cfg.max_seq < shape.seq_len:
        cfg = cfg.replace(max_seq=max(shape.seq_len, cfg.max_seq))
    if shape.kind in ("prefill", "decode"):
        # serving: no fp32 master copy — bf16 params halve both the
        # per-step FSDP all-gather bytes and the weight-read traffic
        cfg = cfg.replace(param_dtype="bfloat16")
    return cfg, shape


def batch_shardings(specs, mesh, rules):
    """First dim of every input is the global batch."""
    def sh(s):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, spec_for(axes, rules))
    return jax.tree.map(sh, specs)


def skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k decode requires sub-quadratic "
                "attention — skipped per DESIGN.md §Arch-applicability")
    return None


def fit_batch_rule(mesh, rules, global_batch: int):
    """Shrink the batch mapping until it divides the global batch
    (long_500k has batch=1 — everything batch-wise is replicated)."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    rule = rules.get("batch")
    if rule is None:
        return rules
    axes = rule if isinstance(rule, tuple) else (rule,)
    while axes:
        n = 1
        for a in axes:
            n *= axis_size[a]
        if global_batch % n == 0:
            break
        axes = axes[1:]
    rules = dict(rules)
    rules["batch"] = axes if axes else None
    return rules


def build_cell(arch: str, shape_name: str, mesh, hyper=None):
    """Returns (fn, example_args, in_shardings, out_shardings, meta)."""
    cfg, shape = cell_config(arch, shape_name)
    model = get_model(cfg)
    rules = make_rules(mesh, **dict(cfg.rules_overrides))
    rules = fit_batch_rule(mesh, rules, shape.global_batch)
    if (shape.kind == "prefill" and rules.get("heads") is None
            and shape.seq_len % mesh.shape["model"] == 0):
        # sequence parallelism: when the head count cannot shard on the
        # model axis, shard the sequence instead — activations and the
        # S² attention logits partition S/16 per device (§Perf).  The
        # activation mlp/vocab dims hand their model-axis mapping to seq
        # (weights keep TP; one mesh axis can't shard two dims of a tensor)
        rules["seq"] = "model"
        rules["act_mlp"] = None
        rules["act_vocab"] = None
    if shape.kind == "decode":
        # KV-parallel decode (split-K): shard the ring-cache window dim on
        # the model axis.  The cache is then fully sharded in storage AND
        # compute (partial softmax + tiny all-reduces), instead of XLA
        # re-gathering a replicated cache to match sharded query heads
        # (measured 212 GB/step of entry all-gather on mistral decode_32k)
        window = decode_window(cfg, shape.seq_len)
        if window % mesh.shape["model"] == 0 \
                and rules.get("kv_heads") is None:
            # (when kv heads themselves shard on model — whisper/zamba2/
            # olmoe — the cache is already fully sharded that way)
            rules["window"] = "model"
    hyper = hyper or TrainHyper(accum_steps=cfg.accum_steps)

    param_sh = tree_shardings(model.schema(), mesh, rules)
    inputs = model.input_specs(shape)
    input_sh = batch_shardings(inputs, mesh, rules)
    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "params": model.param_count(),
        "active_params": model.active_param_count(),
        "accum_steps": hyper.accum_steps,
    }

    if shape.kind == "train":
        state = abstract_state(model)
        rep = NamedSharding(mesh, PartitionSpec())
        state_sh = {
            "params": param_sh,
            "opt": jax.tree.map(
                lambda _: None, state["opt"],
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
            "step": rep,
        }
        # moments mirror the param shardings
        state_sh["opt"] = type(state["opt"])(
            m=param_sh, v=param_sh, count=rep)
        fn = make_train_step(model, hyper, rules)
        args = (state, inputs)
        in_sh = (state_sh, input_sh)
        out_sh = (state_sh, None)
        donate = (0,)
    elif shape.kind == "prefill":
        fn = make_prefill_step(model, rules)
        args = (model.abstract_params(), inputs)
        in_sh = (param_sh, input_sh)
        out_sh = None
        donate = ()
    else:                                       # decode
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        cache_sh = tree_shardings(
            model.cache_schema(shape.global_batch, shape.seq_len),
            mesh, rules)
        fn = make_serve_step(model, rules)
        args = (model.abstract_params(), cache, inputs)
        in_sh = (param_sh, cache_sh, input_sh)
        out_sh = (None, cache_sh)
        donate = (1,)
    return fn, args, in_sh, out_sh, donate, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hyper=None, save: bool = True, verbose: bool = True):
    mesh_name = "multipod" if multi_pod else "pod"
    cfg, shape = cell_config(arch, shape_name)
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    reason = skip_reason(cfg, shape_name)
    if reason:
        out.update({"status": "skip", "reason": reason})
        return _finish(out, save, verbose)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_devices(mesh)
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh, donate, meta = build_cell(
            arch, shape_name, mesh, hyper)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        if save:
            import gzip
            hlo_dir = ARTIFACT_DIR.parent / "hlo"
            hlo_dir.mkdir(parents=True, exist_ok=True)
            with gzip.open(hlo_dir / (f"{arch}__{shape_name}__"
                                      f"{mesh_name}.hlo.gz"), "wt") as f:
                f.write(hlo)
        ana = analyze(hlo, total_devices=chips)
        out.update(meta)
        out.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "hlo_bytes": len(hlo),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            } if mem is not None else None,
            "xla_cost_flops": cost.get("flops"),
            "xla_cost_bytes": cost.get("bytes accessed"),
            # per-device terms from our trip-count-aware HLO walk
            "hlo_flops_per_device": ana.flops,
            "hlo_bytes_per_device": ana.bytes_accessed,
            "collectives": ana.collective_ops,
        })
    except Exception as e:                       # noqa: BLE001
        out.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    return _finish(out, save, verbose)


def _finish(record, save, verbose):
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        path = ARTIFACT_DIR / (f"{record['arch']}__{record['shape']}__"
                               f"{record['mesh']}.json")
        path.write_text(json.dumps(record, indent=1))
    if verbose:
        s = record["status"]
        extra = ""
        if s == "ok":
            extra = (f" flops/dev={record['hlo_flops_per_device']:.3e}"
                     f" compile={record['compile_s']:.0f}s")
        elif s == "error":
            extra = " " + record["error"][:200]
        print(f"[dryrun] {record['arch']} × {record['shape']} × "
              f"{record['mesh']}: {s}{extra}", flush=True)
    return record


def artifact_path(arch, shape_name, mesh_name) -> Path:
    return ARTIFACT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"


def reanalyze_all():
    """Recompute analyzer-derived fields from stored HLO (no recompile)."""
    import gzip
    hlo_dir = ARTIFACT_DIR.parent / "hlo"
    n = 0
    for p in sorted(ARTIFACT_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        hp = hlo_dir / (p.stem + ".hlo.gz")
        if not hp.exists():
            print(f"[reanalyze] no HLO for {p.name}")
            continue
        with gzip.open(hp, "rt") as f:
            hlo = f.read()
        ana = analyze(hlo, total_devices=rec["chips"])
        rec["hlo_flops_per_device"] = ana.flops
        rec["hlo_bytes_per_device"] = ana.bytes_accessed
        rec["collectives"] = ana.collective_ops
        p.write_text(json.dumps(rec, indent=1))
        n += 1
    print(f"[reanalyze] updated {n} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute terms from stored HLO, no compiles")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze_all()
        return

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    failures = 0
    for multi in meshes:
        mname = "multipod" if multi else "pod"
        for arch in archs:
            for shape in shapes:
                p = artifact_path(arch, shape, mname)
                if p.exists() and not args.force:
                    rec = json.loads(p.read_text())
                    if rec.get("status") in ("ok", "skip"):
                        print(f"[dryrun] cached {p.name}: "
                              f"{rec['status']}", flush=True)
                        continue
                rec = run_cell(arch, shape, multi)
                failures += rec["status"] == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
