"""Launchers: production meshes, AOT dry-run, train/serve drivers."""
