"""Production train driver.

  python -m repro.launch.train --arch smollm-360m --steps 200 [--smoke]

On real hardware this process runs per host (jax.distributed); in this
container it drives the reduced config end-to-end on CPU with the full
substrate (WTF data pipeline, transactional checkpoints, restart).
The full-scale configs are exercised via `repro.launch.dryrun`.
"""
from __future__ import annotations

import argparse
import tempfile

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import Cluster
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.data.records import write_token_shard
from repro.models import get_model
from repro.train import AdamWConfig, TrainHyper
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need TPUs; see "
                    "repro.launch.dryrun)")
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(max_seq=args.seq)
    model = get_model(cfg)
    data_dir = args.data_dir or tempfile.mkdtemp(prefix="wtf_launch_")
    cluster = Cluster(n_servers=4, data_dir=data_dir, replication=2)
    fs = cluster.client()
    if not fs.exists("/corpus"):
        fs.mkdir("/corpus")
        rng = np.random.RandomState(0)
        write_token_shard(
            fs, "/corpus/shard0",
            iter(rng.randint(0, cfg.vocab,
                             args.batch * (args.seq + 1) * 64)),
            args.seq + 1)
    pipe = DataPipeline(fs, PipelineConfig(
        src_paths=("/corpus/shard0",), work_dir="/epochs",
        block_tokens=args.seq + 1, global_batch=args.batch, seed=0))
    trainer = Trainer(
        model, pipe, CheckpointManager(fs, "/ckpt", keep=3),
        hyper=TrainHyper(adamw=AdamWConfig(warmup_steps=20,
                                           decay_steps=args.steps),
                         accum_steps=args.accum),
        cfg=TrainerConfig(total_steps=args.steps))
    trainer.run()
    cluster.close()


if __name__ == "__main__":
    main()
