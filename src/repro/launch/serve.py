"""Production serve driver: paged-KV continuous-batching engine.

  python -m repro.launch.serve --arch qwen2-7b --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import get_model
from repro.serving.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.arch_kind not in ("dense", "vlm"):
        raise SystemExit(f"{args.arch}: paged engine serves the dense "
                         "family; use examples/ for SSM decode")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_tokens=args.page_tokens,
        num_pages=max(1024, args.requests * 64)))

    rng = np.random.RandomState(0)
    t0 = time.time()
    sids = [eng.add(rng.randint(0, cfg.vocab,
                                args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    steps = 0
    while any(not eng._requests[s].done for s in sids):
        eng.step()
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(eng.result(s)) for s in sids)
    print(f"[serve] {args.requests} requests, {tokens} tokens, "
          f"{steps} steps, {dt:.2f}s → {tokens / dt:.1f} tok/s")
    print(f"[serve] page stats: {eng.cache.stats}")


if __name__ == "__main__":
    main()
