"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — only `dryrun.py` forces the
512-device host platform, and only before its first jax import.

Topology (TPU v5e target):
  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
The "pod" axis is pure data parallelism — the only cross-pod traffic is
the once-per-step gradient all-reduce (DCN-friendly); "model" carries
TP/EP/SP collectives on intra-pod ICI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def _make_mesh(shape, axes):
    try:                      # AxisType landed after jax 0.4.x; Auto is the
        from jax.sharding import AxisType      # default there anyway
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return _make_mesh((data, model), ("data", "model"))


def mesh_devices(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def validate_mesh(mesh) -> None:
    names = mesh.axis_names
    assert "data" in names and "model" in names, names
