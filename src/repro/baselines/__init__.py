from .hdfs_like import HdfsLikeClient, HdfsLikeCluster

__all__ = ["HdfsLikeClient", "HdfsLikeCluster"]
