"""HDFS-semantics baseline filesystem — the paper's comparison system.

Reproduces the *interface restrictions* that drive Table 2's I/O
accounting, on top of the same StorageServer data nodes as WTF (so
`bytes_read`/`bytes_written` are directly comparable):

  * block-based files (fixed block size, default 64 MB — §4's setting);
  * append-only: no random writes, no punch/yank/paste/concat — any file
    transformation must move data through the client;
  * single writer per file; `hflush` makes data visible to readers
    (the paper's feature-parity configuration);
  * a central "name node" (in-process dict) maps file → block list —
    the centralized-metadata design WTF's HyperDex replaces.

Not reproduced: Java, RPC stacks, rack awareness — irrelevant to the I/O
accounting this baseline exists for.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.errors import NotFound, AlreadyExists, WtfError
from repro.core.placement import HashRing
from repro.core.slicing import SlicePointer
from repro.core.storage import StorageServer

DEFAULT_BLOCK_SIZE = 64 << 20


@dataclass
class _BlockMeta:
    ptrs: List[SlicePointer]        # replicas
    length: int


@dataclass
class _FileMeta:
    blocks: List[_BlockMeta] = field(default_factory=list)
    length: int = 0
    closed: bool = True


class HdfsLikeCluster:
    """Name node + data nodes.  Data nodes are WTF storage servers —
    blocks are stored as slices, which is exactly how HDFS blocks map to
    local files on a data node."""

    def __init__(self, n_servers: int = 4, data_dir: str = "/tmp/hdfs",
                 replication: int = 1,
                 block_size: int = DEFAULT_BLOCK_SIZE):
        import os
        self.block_size = block_size
        self.replication = replication
        self.servers: Dict[int, StorageServer] = {}
        for sid in range(n_servers):
            root = os.path.join(data_dir, f"dn_{sid:03d}")
            self.servers[sid] = StorageServer(sid, root)
        self._ring = HashRing(list(self.servers))
        self._files: Dict[str, _FileMeta] = {}
        self._lock = threading.Lock()

    def client(self) -> "HdfsLikeClient":
        return HdfsLikeClient(self)

    def io_stats(self) -> dict:
        out = {"bytes_read": 0, "bytes_written": 0}
        for s in self.servers.values():
            st = s.stats.snapshot()
            out["bytes_read"] += st["bytes_read"]
            out["bytes_written"] += st["bytes_written"]
        return out

    def close(self) -> None:
        for s in self.servers.values():
            s.close()


class HdfsLikeClient:
    def __init__(self, cluster: HdfsLikeCluster):
        self.c = cluster

    # --------------------------------------------------------------- write
    def create(self, path: str) -> "_Writer":
        with self.c._lock:
            if path in self.c._files:
                raise AlreadyExists(path)
            self.c._files[path] = _FileMeta(closed=False)
        return _Writer(self, path)

    def append_open(self, path: str) -> "_Writer":
        with self.c._lock:
            meta = self.c._files.get(path)
            if meta is None:
                raise NotFound(path)
            if not meta.closed:
                raise WtfError(f"{path}: already open for write "
                               "(single-writer semantics)")
            meta.closed = False
        w = _Writer(self, path)
        # reopen the last partial block by re-reading it (HDFS re-writes
        # the open block on append — the behavior behind the append bug
        # the paper works around)
        meta = self.c._files[path]
        if meta.blocks and meta.blocks[-1].length < self.c.block_size:
            last = meta.blocks.pop()
            meta.length -= last.length
            w._buf = bytearray(self._read_block(last))
        return w

    # ---------------------------------------------------------------- read
    def open(self, path: str) -> "_Reader":
        meta = self.c._files.get(path)
        if meta is None:
            raise NotFound(path)
        return _Reader(self, path)

    def _read_block(self, blk: _BlockMeta) -> bytes:
        for ptr in blk.ptrs:
            srv = self.c.servers.get(ptr.server_id)
            if srv is not None and srv.alive:
                return srv.retrieve_slice(ptr)
        raise WtfError("no live replica")

    def length(self, path: str) -> int:
        meta = self.c._files.get(path)
        if meta is None:
            raise NotFound(path)
        return meta.length

    def exists(self, path: str) -> bool:
        return path in self.c._files

    def listdir(self, prefix: str) -> List[str]:
        prefix = prefix.rstrip("/") + "/"
        return sorted(p for p in self.c._files if p.startswith(prefix))

    def delete(self, path: str) -> None:
        with self.c._lock:
            self.c._files.pop(path, None)

    # ------------------------------------------------------------- helpers
    def read_all(self, path: str) -> bytes:
        r = self.open(path)
        return r.read(self.length(path))

    def write_all(self, path: str, data: bytes) -> None:
        w = self.create(path)
        w.write(data)
        w.close()

    def concat(self, sources: List[str], dest: str) -> None:
        """HDFS-style concat: data moves through the client."""
        w = self.create(dest)
        for s in sources:
            w.write(self.read_all(s))
        w.close()


class _Writer:
    def __init__(self, client: HdfsLikeClient, path: str):
        self.client = client
        self.path = path
        self._buf = bytearray()

    def write(self, data: bytes) -> int:
        self._buf.extend(data)
        while len(self._buf) >= self.client.c.block_size:
            self._flush_block(self.client.c.block_size)
        return len(data)

    def hflush(self) -> None:
        """Make buffered data visible (paper's parity setting): seals the
        current partial block."""
        if self._buf:
            self._flush_block(len(self._buf))

    def _flush_block(self, n: int) -> None:
        c = self.client.c
        data = bytes(self._buf[:n])
        del self._buf[:n]
        blk_idx = len(c._files[self.path].blocks)
        ptrs = []
        servers = c._ring.owners(f"{self.path}#{blk_idx}", c.replication)
        for sid in servers:
            ptrs.append(c.servers[sid].create_slice(data))
        with c._lock:
            meta = c._files[self.path]
            meta.blocks.append(_BlockMeta(ptrs=ptrs, length=len(data)))
            meta.length += len(data)

    def close(self) -> None:
        self.hflush()
        self.client.c._files[self.path].closed = True


class _Reader:
    def __init__(self, client: HdfsLikeClient, path: str):
        self.client = client
        self.path = path
        self.pos = 0

    def seek(self, pos: int) -> None:
        self.pos = pos

    def read(self, size: int) -> bytes:
        c = self.client.c
        meta = c._files[self.path]
        out = bytearray()
        while size > 0 and self.pos < meta.length:
            # locate block
            off = 0
            for blk in meta.blocks:
                if self.pos < off + blk.length:
                    data = self.client._read_block(blk)
                    take = min(size, off + blk.length - self.pos)
                    out.extend(data[self.pos - off:self.pos - off + take])
                    self.pos += take
                    size -= take
                    break
                off += blk.length
            else:
                break
        return bytes(out)
