"""Record-oriented files on WTF.

Training shards are files of *fixed-size records* (a record = ``block_size``
int32 tokens, or an arbitrary payload for the sort benchmark).  Fixed framing
is what makes the slicing API shine: any record's byte range is computable,
so datasets can be shuffled, mixed, and re-sharded with ``yank``/``paste`` —
pure metadata operations that move zero data bytes (the paper's sort
pipeline, §4.1, applied to training data).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.core import Extent, WtfClient


@dataclass(frozen=True)
class RecordSpec:
    record_bytes: int          # fixed record size in bytes
    count: int                 # number of records in the file


class RecordWriter:
    """Sequentially append fixed-size records to a WTF file."""

    def __init__(self, client: WtfClient, path: str, record_bytes: int):
        self.client = client
        self.path = path
        self.record_bytes = record_bytes
        self._fd = client.open(path, "w")
        self._count = 0

    def append(self, payload: bytes) -> int:
        if len(payload) != self.record_bytes:
            raise ValueError(
                f"record must be exactly {self.record_bytes} bytes, "
                f"got {len(payload)}")
        self.client.append(self._fd, payload)
        self._count += 1
        return self._count - 1

    def append_array(self, tokens: np.ndarray) -> int:
        return self.append(np.ascontiguousarray(tokens).tobytes())

    def close(self) -> RecordSpec:
        self.client.close(self._fd)
        return RecordSpec(self.record_bytes, self._count)


class RecordFile:
    """Random and sliced access to a fixed-record WTF file."""

    def __init__(self, client: WtfClient, path: str, record_bytes: int):
        self.client = client
        self.path = path
        self.record_bytes = record_bytes
        self._fd = client.open(path, "r")
        size = client.stat(path)["size"]
        if size % record_bytes:
            raise ValueError(
                f"{path}: size {size} is not a multiple of record size "
                f"{record_bytes}")
        self.count = size // record_bytes

    # -- data-plane reads ---------------------------------------------------
    def read_record(self, idx: int) -> bytes:
        self._check(idx)
        return self.client.pread(self._fd, self.record_bytes,
                                 idx * self.record_bytes)

    def read_records(self, start: int, n: int) -> bytes:
        self._check(start)
        n = min(n, self.count - start)
        return self.client.pread(self._fd, n * self.record_bytes,
                                 start * self.record_bytes)

    def read_tokens(self, idx: int, dtype=np.int32) -> np.ndarray:
        return np.frombuffer(self.read_record(idx), dtype=dtype)

    # -- metadata-plane (zero-copy) ------------------------------------------
    def yank_records(self, start: int, n: int) -> List[Extent]:
        """Slice pointers for records [start, start+n) — no data I/O."""
        self._check(start)
        n = min(n, self.count - start)
        self.client.seek(self._fd, start * self.record_bytes)
        return list(self.client.yank(self._fd, n * self.record_bytes))

    def _check(self, idx: int) -> None:
        if not (0 <= idx < self.count):
            raise IndexError(f"record {idx} out of range [0,{self.count})")

    def close(self) -> None:
        self.client.close(self._fd)


def write_token_shard(client: WtfClient, path: str,
                      token_stream: Iterable[int], block_tokens: int,
                      dtype=np.int32) -> RecordSpec:
    """Pack a token stream into fixed ``block_tokens`` records; the tail
    partial block is dropped (standard LM-shard convention)."""
    itemsize = np.dtype(dtype).itemsize
    w = RecordWriter(client, path, block_tokens * itemsize)
    buf: list[int] = []
    for tok in token_stream:
        buf.append(tok)
        if len(buf) == block_tokens:
            w.append_array(np.asarray(buf, dtype=dtype))
            buf.clear()
    return w.close()
