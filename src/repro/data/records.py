"""Record-oriented files on WTF.

Training shards are files of *fixed-size records* (a record = ``block_size``
int32 tokens, or an arbitrary payload for the sort benchmark).  Fixed framing
is what makes the slicing API shine: any record's byte range is computable,
so datasets can be shuffled, mixed, and re-sharded with ``yank``/``paste`` —
pure metadata operations that move zero data bytes (the paper's sort
pipeline, §4.1, applied to training data).

Built on the handle-based vectored client API: a ``RecordFile`` owns a
``WtfFile`` and exposes batched record access (``read_records_batch``,
``yank_record_runs``) that turns N scattered record touches into one
transaction whose slice fetches are coalesced by the I/O scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import Extent, WtfClient


@dataclass(frozen=True)
class RecordSpec:
    record_bytes: int          # fixed record size in bytes
    count: int                 # number of records in the file


class RecordWriter:
    """Sequentially append fixed-size records to a WTF file."""

    def __init__(self, client: WtfClient, path: str, record_bytes: int):
        self.client = client
        self.path = path
        self.record_bytes = record_bytes
        self._f = client.open_file(path, "w")
        self._count = 0

    def append(self, payload: bytes) -> int:
        if len(payload) != self.record_bytes:
            raise ValueError(
                f"record must be exactly {self.record_bytes} bytes, "
                f"got {len(payload)}")
        self._f.append(payload)
        self._count += 1
        return self._count - 1

    def append_many(self, payloads: Sequence[bytes]) -> int:
        """Gather-append a batch of records as ONE atomic append op.

        The joined batch goes through the client's §2.5 relative-append
        path, so it is a single transaction (all-or-nothing) and remains
        safe under concurrent appenders — unlike a seek(END)+write pair.
        Returns the index of the last appended record (-1 if the writer
        is still empty)."""
        for p in payloads:
            if len(p) != self.record_bytes:
                raise ValueError(
                    f"record must be exactly {self.record_bytes} bytes, "
                    f"got {len(p)}")
        if payloads:
            self._f.append(b"".join(payloads))
            self._count += len(payloads)
        return self._count - 1

    def append_array(self, tokens: np.ndarray) -> int:
        return self.append(np.ascontiguousarray(tokens).tobytes())

    def close(self) -> RecordSpec:
        self._f.close()
        return RecordSpec(self.record_bytes, self._count)


class RecordFile:
    """Random and sliced access to a fixed-record WTF file."""

    def __init__(self, client: WtfClient, path: str, record_bytes: int):
        self.client = client
        self.path = path
        self.record_bytes = record_bytes
        self._f = client.open_file(path, "r")
        size = client.stat(path)["size"]
        if size % record_bytes:
            raise ValueError(
                f"{path}: size {size} is not a multiple of record size "
                f"{record_bytes}")
        self.count = size // record_bytes

    @property
    def handle(self):
        """The underlying ``WtfFile`` for direct vectored access."""
        return self._f

    # -- data-plane reads ---------------------------------------------------
    def read_record(self, idx: int) -> bytes:
        self._check(idx)
        return self._f.pread(self.record_bytes, idx * self.record_bytes)

    def read_records(self, start: int, n: int) -> bytes:
        self._check(start)
        n = min(n, self.count - start)
        return self._f.pread(n * self.record_bytes,
                             start * self.record_bytes)

    def read_records_batch(self, indices: Sequence[int]) -> List[bytes]:
        """Fetch many (possibly scattered) records in one vectored read —
        one transaction, coalesced slice fetches."""
        rb = self.record_bytes
        for i in indices:
            self._check(i)
        return self._f.readv([(i * rb, rb) for i in indices])

    def read_record_runs(self, runs: Sequence[Tuple[int, int]]
                         ) -> List[bytes]:
        """Vectored read of ``(start, n)`` record runs; one buffer per run."""
        return self._f.readv(self._ranges_of(runs))

    def read_record_runs_async(self, runs: Sequence[Tuple[int, int]]):
        """``read_record_runs`` through the async runtime: returns an
        ``IoFuture`` of the buffer list.  The caller can issue the next
        window's fetch before consuming this one — the overlap the data
        pipeline's prefetcher is built on."""
        return self._f.readv_async(self._ranges_of(runs))

    def _ranges_of(self, runs: Sequence[Tuple[int, int]]
                   ) -> List[Tuple[int, int]]:
        """Bounds-checked, EOF-clamped byte ranges for record runs — the
        one conversion shared by the vectored read and yank paths."""
        rb = self.record_bytes
        ranges = []
        for start, n in runs:
            self._check(start)
            n = min(n, self.count - start)
            ranges.append((start * rb, n * rb))
        return ranges

    def read_tokens(self, idx: int, dtype=np.int32) -> np.ndarray:
        return np.frombuffer(self.read_record(idx), dtype=dtype)

    # -- metadata-plane (zero-copy) ------------------------------------------
    def yank_records(self, start: int, n: int) -> List[Extent]:
        """Slice pointers for records [start, start+n) — no data I/O."""
        self._check(start)
        n = min(n, self.count - start)
        rb = self.record_bytes
        return list(self._f.yankv([(start * rb, n * rb)])[0])

    def yank_record_runs(self, runs: Sequence[Tuple[int, int]]
                         ) -> List[Tuple[Extent, ...]]:
        """Slice-pointer plans for many ``(start, n)`` record runs, computed
        in ONE transaction (one consistent snapshot of the file) — the
        batched flavor of ``yank_records`` used by shuffle/sort."""
        return self._f.yankv(self._ranges_of(runs))

    def _check(self, idx: int) -> None:
        if not (0 <= idx < self.count):
            raise IndexError(f"record {idx} out of range [0,{self.count})")

    def close(self) -> None:
        self._f.close()


def write_token_shard(client: WtfClient, path: str,
                      token_stream: Iterable[int], block_tokens: int,
                      dtype=np.int32) -> RecordSpec:
    """Pack a token stream into fixed ``block_tokens`` records; the tail
    partial block is dropped (standard LM-shard convention)."""
    itemsize = np.dtype(dtype).itemsize
    w = RecordWriter(client, path, block_tokens * itemsize)
    buf: list[int] = []
    for tok in token_stream:
        buf.append(tok)
        if len(buf) == block_tokens:
            w.append_array(np.asarray(buf, dtype=dtype))
            buf.clear()
    return w.close()
