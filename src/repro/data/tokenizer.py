"""A small, dependency-free byte-level tokenizer for the examples/tests.

Deterministic and reversible: token = byte value (0..255); specials above.
Real deployments plug in their own vocab — the pipeline only needs ids.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True, eos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8",
                                                       errors="replace")

    def stream(self, texts: Iterable[str]) -> Iterator[int]:
        for t in texts:
            yield from self.encode(t)
