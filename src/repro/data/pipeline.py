"""Deterministic, resumable, multi-host training-data pipeline on WTF.

Per epoch, the pipeline materializes a *shuffled epoch file* with the
zero-copy shuffle (metadata only), then serves per-host batches by reading
contiguous record ranges.  Because every epoch file is a pure function of
(sources, seed, epoch), and the cursor is a single integer, the iterator
state is tiny and is checkpointed transactionally together with the model —
after a restart, data position and weights can never disagree.

Multi-host / elastic: hosts slice the batch by ``host_id``/``num_hosts``;
``with_hosts`` re-derives a pipeline for a new topology at the same global
step (elastic re-scale), which is valid precisely because epoch files are
deterministic and host assignment is stateless.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core import WtfClient
from .records import RecordFile
from .shuffle import shuffle_epoch


@dataclass
class PipelineConfig:
    src_paths: Tuple[str, ...]
    work_dir: str                  # where epoch files live, e.g. /data/epochs
    block_tokens: int              # tokens per record (seq_len + 1)
    global_batch: int              # sequences per step across all hosts
    seed: int = 0
    dtype: str = "int32"
    run_length: int = 1            # shuffle granularity (records)
    host_id: int = 0
    num_hosts: int = 1
    prefetch: int = 2              # prefetched batches (0 = synchronous)
    # Issue each prefetch window's storage fetch through the async I/O
    # runtime (readv_async) so window W+1 is in flight while window W is
    # being consumed.  False reverts to one synchronous vectored read per
    # window (plan+fetch serialized with consumption) — the comparison the
    # pipeline_bench overlap scenario measures.
    async_prefetch: bool = True

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")


@dataclass
class PipelineState:
    """The checkpointable cursor — deliberately tiny."""
    epoch: int = 0
    step_in_epoch: int = 0

    def to_dict(self) -> dict:
        return {"epoch": self.epoch, "step_in_epoch": self.step_in_epoch}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(d["epoch"], d["step_in_epoch"])


class DataPipeline:
    def __init__(self, client: WtfClient, config: PipelineConfig,
                 state: Optional[PipelineState] = None):
        self.client = client
        self.cfg = config
        self.state = state or PipelineState()
        self._itemsize = np.dtype(config.dtype).itemsize
        self.record_bytes = config.block_tokens * self._itemsize
        self._epoch_file: Optional[RecordFile] = None
        self._epoch_loaded = -1
        if not client.exists(config.work_dir):
            client.mkdir(config.work_dir)

    # ----------------------------------------------------------- epoch mgmt
    def _epoch_path(self, epoch: int) -> str:
        return f"{self.cfg.work_dir}/epoch-{epoch:05d}"

    def _ensure_epoch(self, epoch: int) -> RecordFile:
        if self._epoch_loaded == epoch and self._epoch_file is not None:
            return self._epoch_file
        path = self._epoch_path(epoch)
        if not self.client.exists(path):
            # Zero-copy shuffle: pure metadata, deterministic in (seed, epoch)
            shuffle_epoch(self.client, self.cfg.src_paths, path,
                          self.record_bytes,
                          seed=self.cfg.seed + epoch,
                          run_length=self.cfg.run_length)
        if self._epoch_file is not None:
            self._epoch_file.close()
        self._epoch_file = RecordFile(self.client, path, self.record_bytes)
        self._epoch_loaded = epoch
        return self._epoch_file

    @property
    def steps_per_epoch(self) -> int:
        f = self._ensure_epoch(self.state.epoch)
        return f.count // self.cfg.global_batch

    # ------------------------------------------------------------- batching
    def _host_batch(self, epoch: int, step: int) -> np.ndarray:
        """This host's rows of global step ``step`` in ``epoch``."""
        return self._host_batches(epoch, [step])[0]

    def _host_batches(self, epoch: int,
                      steps: Sequence[int]) -> list[np.ndarray]:
        """This host's rows for several global steps, fetched as ONE
        vectored read — the record runs of all steps are planned in a
        single transaction and their slice fetches batched per server."""
        f = self._ensure_epoch(epoch)
        raws = f.read_record_runs(self._window_runs(steps))
        return [self._blocks_of(raw) for raw in raws]

    def __iter__(self) -> Iterator[dict]:
        if self.cfg.prefetch > 0:
            return self._prefetching_iter()
        return self._sync_iter()

    def _sync_iter(self) -> Iterator[dict]:
        while True:
            epoch, step = self.state.epoch, self.state.step_in_epoch
            f = self._ensure_epoch(epoch)
            if (step + 1) * self.cfg.global_batch > f.count:
                self.state = PipelineState(epoch + 1, 0)
                continue
            blocks = self._host_batch(epoch, step)
            self.state = PipelineState(epoch, step + 1)
            yield {
                "tokens": blocks[:, :-1],
                "labels": blocks[:, 1:],
                "epoch": epoch,
                "step_in_epoch": step,
            }

    def _window_runs(self, steps: Sequence[int]) -> list[Tuple[int, int]]:
        per_host = self.cfg.global_batch // self.cfg.num_hosts
        return [(s * self.cfg.global_batch + self.cfg.host_id * per_host,
                 per_host) for s in steps]

    def _blocks_of(self, raw: bytes) -> np.ndarray:
        per_host = self.cfg.global_batch // self.cfg.num_hosts
        return np.frombuffer(raw, dtype=self.cfg.dtype).reshape(
            per_host, self.cfg.block_tokens)

    def _prefetching_iter(self) -> Iterator[dict]:
        """Background-thread prefetch on the async I/O runtime.

        The producer pulls up to ``prefetch`` steps per vectored read, so
        a prefetch window costs one storage round per server instead of
        one per step — and with ``async_prefetch`` it issues window W+1's
        fetch (``readv_async``) *before* awaiting window W's, so the
        metadata planning and data rounds of the next window overlap the
        consumption of the current one.  The trainer's step time then
        hides the pipeline's I/O twice over: behind the queue, and behind
        the in-flight future."""
        q: "queue.Queue" = queue.Queue(maxsize=max(1, self.cfg.prefetch))
        stop = threading.Event()
        window = max(1, self.cfg.prefetch)

        def next_window(epoch: int, step: int):
            """Advance to the next non-empty window, crossing (and, on
            first touch, materializing) epoch boundaries.  Re-checks
            ``stop`` on every epoch bump — a shard smaller than one
            global batch has zero steps per epoch, and this loop must
            stay interruptible rather than materialize epoch files
            forever.  Returns ``None`` when stopping."""
            while not stop.is_set():
                f = self._ensure_epoch(epoch)
                spe = f.count // self.cfg.global_batch
                if step >= spe:
                    epoch, step = epoch + 1, 0
                    continue
                return epoch, list(range(step, min(step + window, spe))), f
            return None

        def emit(epoch: int, steps, raws) -> bool:
            for s, raw in zip(steps, raws):
                if stop.is_set():
                    return False
                blocks = self._blocks_of(raw)
                self.state = PipelineState(epoch, s + 1)
                q.put({
                    "tokens": blocks[:, :-1],
                    "labels": blocks[:, 1:],
                    "epoch": epoch,
                    "step_in_epoch": s,
                })
            return True

        def producer():
            pending = []                     # every issued, un-awaited future

            def issue(win):
                fu = win[2].read_record_runs_async(self._window_runs(win[1]))
                pending.append(fu)
                return fu

            def collect(fu):
                try:
                    return fu.result()
                finally:
                    pending.remove(fu)

            try:
                cur = next_window(self.state.epoch,
                                  self.state.step_in_epoch)
                if cur is None:
                    return
                fut = issue(cur) if self.cfg.async_prefetch else None
                while not stop.is_set():
                    epoch, steps, f = cur
                    if self.cfg.async_prefetch:
                        # Issue-ahead: the next window's fetch enters the
                        # runtime before this window's future is awaited.
                        # (The async read captured its inode at submission,
                        # so crossing an epoch — which closes the current
                        # RecordFile handle — cannot invalidate it.)
                        nxt = next_window(epoch, steps[-1] + 1)
                        if nxt is None:
                            return
                        nfut = issue(nxt)
                        raws, fut = collect(fut), nfut
                    else:
                        raws = f.read_record_runs(self._window_runs(steps))
                        nxt = None
                    if not emit(epoch, steps, raws):
                        return
                    cur = nxt if nxt is not None \
                        else next_window(epoch, steps[-1] + 1)
                    if cur is None:
                        return
            except Exception as e:           # surface errors to the consumer
                q.put(e)
            finally:
                for fu in list(pending):     # quiesce: no orphaned rounds,
                    try:                     # even when the awaited window
                        fu.result()          # failed with nfut in flight
                    except Exception:        # noqa: BLE001 - already stopping
                        pass

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            # Deterministic shutdown for abandoned iterators: wake a
            # producer blocked on a full queue, then join it so no stray
            # fetch lands after the consumer is gone.
            stop.set()
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join(timeout=10)
            if t.is_alive():
                # A wedged producer (e.g. a fetch stuck on a dead server)
                # can still mutate state/stats after this point — make
                # that visible instead of silently pretending quiescence.
                import warnings
                warnings.warn(
                    "DataPipeline producer did not stop within 10s of "
                    "iterator shutdown; counters may keep moving",
                    RuntimeWarning, stacklevel=2)

    # ---------------------------------------------------------- elasticity
    def with_hosts(self, host_id: int, num_hosts: int) -> "DataPipeline":
        """Same logical stream, new topology (elastic re-scale)."""
        import dataclasses

        cfg = dataclasses.replace(self.cfg, host_id=host_id,
                                  num_hosts=num_hosts)
        return DataPipeline(self.client, cfg, state=self.state)
