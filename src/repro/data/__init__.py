"""WTF-backed training-data pipeline: record shards, zero-copy global
shuffle/mixing, deterministic resumable multi-host iteration."""
from .pipeline import DataPipeline, PipelineConfig, PipelineState
from .records import RecordFile, RecordSpec, RecordWriter, write_token_shard
from .shuffle import mix_datasets, shuffle_epoch
from .tokenizer import ByteTokenizer

__all__ = [
    "DataPipeline", "PipelineConfig", "PipelineState",
    "RecordFile", "RecordSpec", "RecordWriter", "write_token_shard",
    "shuffle_epoch", "mix_datasets", "ByteTokenizer",
]
