"""Zero-copy global shuffle and dataset mixing (the paper's technique as a
training-data primitive).

A global shuffle of N fixed-size records is a permutation of their slice
pointers: yank every record, permute, paste into the epoch file.  Data bytes
moved: **zero** — the same property that gives the paper's sort benchmark its
4× win (§4.1, Table 2).  The shuffled file then reads *sequentially* for the
trainer, and locality-aware placement keeps those reads contiguous per
source region.

Mixing datasets with weights is the same trick: interleave yanked record
runs from each source proportionally to the weights.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import WtfClient
from .records import RecordFile, RecordSpec


def shuffle_epoch(client: WtfClient, src_paths: Sequence[str],
                  dst_path: str, record_bytes: int, seed: int,
                  run_length: int = 1) -> int:
    """Create ``dst_path`` = a seeded permutation of all records across the
    source shards.  Returns the number of records.

    ``run_length`` shuffles *runs* of consecutive records instead of single
    records — coarser shuffling that preserves more disk locality (longer
    mergeable extents), the classic shuffle-quality/IO-locality dial.
    """
    files = [RecordFile(client, p, record_bytes) for p in src_paths]
    runs: List[Tuple[int, int, int]] = []      # (file idx, start, n)
    for fi, f in enumerate(files):
        for start in range(0, f.count, run_length):
            runs.append((fi, start, min(run_length, f.count - start)))

    rng = np.random.Generator(np.random.Philox(seed))
    order = rng.permutation(len(runs))

    total = 0
    with client.transaction():
        dst = client.open(dst_path, "w")
        for ri in order:
            fi, start, n = runs[ri]
            extents = files[fi].yank_records(start, n)
            client.paste(dst, extents)
            total += n
        client.close(dst)
    for f in files:
        f.close()
    return total


def mix_datasets(client: WtfClient, specs: Sequence[Tuple[str, float]],
                 dst_path: str, record_bytes: int, seed: int,
                 total_records: Optional[int] = None) -> int:
    """Weighted mixture: dst is an interleaving of source records where
    source i contributes proportionally to its weight.  Zero data I/O.

    Sampling is without replacement per source; a source that runs dry stops
    contributing (the remaining weights renormalize implicitly).
    """
    files = [RecordFile(client, p, record_bytes) for p, _ in specs]
    weights = np.asarray([w for _, w in specs], dtype=np.float64)
    weights = weights / weights.sum()
    rng = np.random.Generator(np.random.Philox(seed))
    cursors = [0] * len(files)
    budget = (sum(f.count for f in files)
              if total_records is None else total_records)

    written = 0
    with client.transaction():
        dst = client.open(dst_path, "w")
        while written < budget:
            avail = [i for i, f in enumerate(files)
                     if cursors[i] < f.count]
            if not avail:
                break
            w = weights[avail]
            src = int(rng.choice(avail, p=w / w.sum()))
            extents = files[src].yank_records(cursors[src], 1)
            client.paste(dst, extents)
            cursors[src] += 1
            written += 1
        client.close(dst)
    for f in files:
        f.close()
    return written
