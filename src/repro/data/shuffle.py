"""Zero-copy global shuffle and dataset mixing (the paper's technique as a
training-data primitive).

A global shuffle of N fixed-size records is a permutation of their slice
pointers: yank every record, permute, paste into the epoch file.  Data bytes
moved: **zero** — the same property that gives the paper's sort benchmark its
4× win (§4.1, Table 2).  The shuffled file then reads *sequentially* for the
trainer, and locality-aware placement keeps those reads contiguous per
source region.

Vectored execution: all runs of a source shard are yanked with ONE
``yankv`` per shard and the permuted pointer order is pasted with ONE
``pastev`` — the op log holds a handful of vectored ops instead of one op
per record, so both the commit and any §2.6 replay stay O(shards), not
O(records).

Mixing datasets with weights is the same trick: interleave yanked record
runs from each source proportionally to the weights.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import WtfClient
from .records import RecordFile, RecordSpec


def shuffle_epoch(client: WtfClient, src_paths: Sequence[str],
                  dst_path: str, record_bytes: int, seed: int,
                  run_length: int = 1) -> int:
    """Create ``dst_path`` = a seeded permutation of all records across the
    source shards.  Returns the number of records.

    ``run_length`` shuffles *runs* of consecutive records instead of single
    records — coarser shuffling that preserves more disk locality (longer
    mergeable extents), the classic shuffle-quality/IO-locality dial.
    """
    files = [RecordFile(client, p, record_bytes) for p in src_paths]
    runs: List[Tuple[int, int, int]] = []      # (file idx, start, n)
    per_file_runs: List[List[Tuple[int, int]]] = [[] for _ in files]
    run_slot: List[Tuple[int, int]] = []       # run idx -> (file, slot)
    for fi, f in enumerate(files):
        for start in range(0, f.count, run_length):
            n = min(run_length, f.count - start)
            runs.append((fi, start, n))
            run_slot.append((fi, len(per_file_runs[fi])))
            per_file_runs[fi].append((start, n))

    rng = np.random.Generator(np.random.Philox(seed))
    order = rng.permutation(len(runs))

    total = sum(n for _, _, n in runs)
    with client.transaction():
        # One yankv per shard: every run's slice pointers in one op.
        yanked = [f.yank_record_runs(per_file_runs[fi])
                  for fi, f in enumerate(files)]
        # One pastev: the entire permuted epoch in a single atomic op.
        batches = []
        for ri in order:
            fi, slot = run_slot[ri]
            batches.append(yanked[fi][slot])
        with client.open_file(dst_path, "w") as dst:
            dst.pastev(batches)
    for f in files:
        f.close()
    return total


def mix_datasets(client: WtfClient, specs: Sequence[Tuple[str, float]],
                 dst_path: str, record_bytes: int, seed: int,
                 total_records: Optional[int] = None) -> int:
    """Weighted mixture: dst is an interleaving of source records where
    source i contributes proportionally to its weight.  Zero data I/O.

    Sampling is without replacement per source; a source that runs dry stops
    contributing (the remaining weights renormalize implicitly).  Record
    pointers are pre-yanked per source with one vectored op and the chosen
    interleaving is pasted with one ``pastev``.
    """
    files = [RecordFile(client, p, record_bytes) for p, _ in specs]
    weights = np.asarray([w for _, w in specs], dtype=np.float64)
    weights = weights / weights.sum()
    rng = np.random.Generator(np.random.Philox(seed))
    cursors = [0] * len(files)
    budget = (sum(f.count for f in files)
              if total_records is None else total_records)

    # Decide the interleaving first (pure RNG, no I/O), then yank exactly
    # the chosen records — O(budget), never O(total records in sources).
    picks: List[Tuple[int, int]] = []          # (source idx, record idx)
    written = 0
    while written < budget:
        avail = [i for i, f in enumerate(files) if cursors[i] < f.count]
        if not avail:
            break
        w = weights[avail]
        src = int(rng.choice(avail, p=w / w.sum()))
        picks.append((src, cursors[src]))
        cursors[src] += 1
        written += 1

    with client.transaction():
        per_src: dict[int, List[int]] = {}
        for src, idx in picks:
            per_src.setdefault(src, []).append(idx)
        yanked = {
            src: dict(zip(idxs, files[src].yank_record_runs(
                [(i, 1) for i in idxs])))
            for src, idxs in per_src.items()
        }
        with client.open_file(dst_path, "w") as dst:
            dst.pastev([yanked[src][idx] for src, idx in picks])
    for f in files:
        f.close()
    return written
