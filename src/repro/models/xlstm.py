"""xLSTM (xlstm-1.3b): mLSTM blocks with one sLSTM block per
`xlstm.slstm_every` layers.

mLSTM (matrix memory, exponential gating) trains with a *chunkwise
parallel* form — quadratic only within a chunk, a `lax.scan` carries the
stabilized (C, n, m) state across chunks.  sLSTM (scalar memory, true
recurrence through the hidden state) is a `lax.scan` over time — that
sequential dependency is the architecture, not an implementation choice.

Layer layout: n_layers = G groups × (slstm_every-1 mLSTM + 1 sLSTM);
mLSTM params are stacked [G, K, ...] (outer scan over groups, inner scan
over the K mLSTM layers), sLSTM params are stacked [G, ...].
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import P, logical_constraint as lc
from . import layers as L
from .common import (decode_specs, padded_vocab, scan_layers, stacked,
                     token_specs)


def _dims(cfg):
    x = cfg.xlstm
    d = cfg.d_model
    dk = int(d * x.qk_dim_factor)
    dv = int(d * x.v_dim_factor)
    h = cfg.n_heads
    return d, dk, dv, h, dk // h, dv // h


def _groups(cfg) -> Tuple[int, int]:
    every = cfg.xlstm.slstm_every
    assert cfg.n_layers % every == 0, \
        f"n_layers {cfg.n_layers} % slstm_every {every} != 0"
    return cfg.n_layers // every, every - 1     # (G groups, K mLSTM each)


def _slstm_ff(d: int) -> int:
    return max(128, (8 * d // 9) // 128 * 128)  # xLSTM pf=4/3 SwiGLU


# ------------------------------------------------------------------ schema
def mlstm_schema(cfg) -> Dict[str, P]:
    d, dk, dv, h, _, _ = _dims(cfg)
    return {
        "ln": P((d,), ("act_embed",), init="ones"),
        "wq": P((d, dk), ("embed", "heads"), init="scaled"),
        "wk": P((d, dk), ("embed", "heads"), init="scaled"),
        "wv": P((d, dv), ("embed", "mlp"), init="scaled"),
        "wif": P((d, 2 * h), ("embed", None), init="scaled"),
        "b_if": P((2 * h,), (None,), init="zeros"),
        "wg": P((d, dv), ("embed", "mlp"), init="scaled"),
        "wo": P((dv, d), ("mlp", "embed"), init="scaled"),
    }


def slstm_schema(cfg) -> Dict[str, P]:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ff = _slstm_ff(d)
    return {
        "ln": P((d,), ("act_embed",), init="ones"),
        "w_zifo": P((d, 4 * d), ("embed", "mlp"), init="scaled"),
        "r_zifo": P((h, dh, 4 * dh), ("heads", None, None), init="scaled",
                    scale=0.5),
        "b_zifo": P((4 * d,), ("mlp",), init="zeros"),
        "wo": P((d, d), ("embed", "embed2"), init="scaled"),
        "ln2": P((d,), ("act_embed",), init="ones"),
        "w_gate": P((d, ff), ("embed", "mlp"), init="scaled"),
        "w_up": P((d, ff), ("embed", "mlp"), init="scaled"),
        "w_down": P((ff, d), ("mlp", "embed"), init="scaled"),
    }


def schema(cfg) -> Dict[str, Any]:
    g, k = _groups(cfg)
    v = padded_vocab(cfg)
    return {
        "embedding": P((v, cfg.d_model), ("vocab", "embed")),
        "unembedding": P((v, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "mlstm": stacked(g, stacked(k, mlstm_schema(cfg))),
        "slstm": stacked(g, slstm_schema(cfg)),
    }


# ------------------------------------------------------- mLSTM chunked fwd
def mlstm_chunked(q, k, v, ig, fg, chunk: int,
                  state: Optional[Tuple] = None):
    """Stabilized chunkwise mLSTM.

    q,k: [B,S,H,dk]; v: [B,S,H,dv]; ig,fg: [B,S,H] raw gate pre-activations.
    state: (C [B,H,dv,dk], n [B,H,dk], m [B,H]) or None.
    Returns (h [B,S,H,dv], state').  fp32 throughout.
    """
    f32 = jnp.float32
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    qc = min(chunk, s)
    assert s % qc == 0
    nc = s // qc
    scale = 1.0 / np.sqrt(dk)

    q, k, v = (t.astype(f32) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(fg.astype(f32))             # [B,S,H]
    logi = ig.astype(f32)

    def r(t, tail):
        return t.reshape((b, nc, qc) + tail)

    qs, ks, vs = r(q, (h, dk)), r(k, (h, dk)), r(v, (h, dv))
    lf, li = r(logf, (h,)), r(logi, (h,))

    if state is None:
        c0 = jnp.zeros((b, h, dv, dk), f32)
        n0 = jnp.zeros((b, h, dk), f32)
        m0 = jnp.full((b, h), -jnp.inf, f32)
    else:
        c0, n0, m0 = (t.astype(f32) for t in state)

    tri = jnp.tril(jnp.ones((qc, qc), bool))

    def body(carry, xs):
        c_st, n_st, m_st = carry
        qq, kk, vv, lff, lii = xs                         # [B,Q,...]
        fcum = jnp.cumsum(lff, axis=1)                    # [B,Q,H]
        # intra log-weights D[i,j] = Fcum_i − Fcum_j + logi_j  (j ≤ i)
        dlog = fcum[:, :, None, :] - fcum[:, None, :, :] \
            + lii[:, None, :, :]                          # [B,Q,Q,H]
        dlog = jnp.where(tri[None, :, :, None], dlog, -jnp.inf)
        w_inter = fcum + m_st[:, None, :]                 # [B,Q,H]
        m_i = jnp.maximum(jnp.max(dlog, axis=2), w_inter)
        m_i = jnp.maximum(m_i, -1e30)                     # avoid -inf − -inf
        sc = jnp.einsum("bihk,bjhk->bijh", qq, kk) * scale
        sc = sc * jnp.exp(dlog - m_i[:, :, None, :])
        inter_w = jnp.exp(w_inter - m_i)                  # [B,Q,H]
        num = jnp.einsum("bijh,bjhv->bihv", sc, vv) \
            + inter_w[..., None] \
            * jnp.einsum("bihk,bhvk->bihv", qq, c_st) * scale
        den = jnp.sum(sc, axis=2) \
            + inter_w * jnp.einsum("bihk,bhk->bih", qq, n_st) * scale
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        hh = num / den[..., None]                         # [B,Q,H,dv]

        # end-of-chunk state
        f_tot = fcum[:, -1]                               # [B,H]
        dlog_end = f_tot[:, None, :] - fcum + lii         # [B,Q,H]
        m_new = jnp.maximum(f_tot + m_st, jnp.max(dlog_end, axis=1))
        w_old = jnp.exp(f_tot + m_st - m_new)             # [B,H]
        w_j = jnp.exp(dlog_end - m_new[:, None, :])       # [B,Q,H]
        c_new = c_st * w_old[:, :, None, None] \
            + jnp.einsum("bjh,bjhv,bjhk->bhvk", w_j, vv, kk)
        n_new = n_st * w_old[:, :, None] \
            + jnp.einsum("bjh,bjhk->bhk", w_j, kk)
        return (c_new, n_new, m_new), hh

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qs, ks, vs, lf, li))
    (c_f, n_f, m_f), hs = jax.lax.scan(body, (c0, n0, m0), xs)
    h_out = jnp.moveaxis(hs, 0, 1).reshape(b, s, h, dv)
    return h_out, (c_f, n_f, m_f)


def mlstm_step(state, q, k, v, ig, fg):
    """Single-token recurrent mLSTM.  q,k: [B,H,dk]; v: [B,H,dv];
    ig,fg: [B,H]."""
    f32 = jnp.float32
    c, n, m = (t.astype(f32) for t in state)
    q, k, v = (t.astype(f32) for t in (q, k, v))
    scale = 1.0 / np.sqrt(q.shape[-1])
    logf = jax.nn.log_sigmoid(fg.astype(f32))
    logi = ig.astype(f32)
    m_new = jnp.maximum(logf + m, logi)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(logi - m_new)
    c = c * fw[:, :, None, None] + iw[:, :, None, None] \
        * jnp.einsum("bhv,bhk->bhvk", v, k)
    n = n * fw[..., None] + iw[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", c, q) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)) * scale,
                      jnp.exp(-m_new))
    return num / den[..., None], (c, n, m_new)


def mlstm_block(params, x, cfg, rules=None, state=None):
    d, dk, dv, h, dkh, dvh = _dims(cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    y = L.rms_norm(x, params["ln"], cfg.norm_eps)
    b = y.shape[0]

    q = jnp.einsum("bsd,dk->bsk", y, L.cast(params["wq"], dt))
    k = jnp.einsum("bsd,dk->bsk", y, L.cast(params["wk"], dt))
    v = jnp.einsum("bsd,dk->bsk", y, L.cast(params["wv"], dt))
    gates = jnp.einsum("bsd,dg->bsg", y.astype(jnp.float32),
                       params["wif"].astype(jnp.float32)) \
        + params["b_if"].astype(jnp.float32)
    ig, fg = gates[..., :h], gates[..., h:]

    if state is None:
        qh = q.reshape(*q.shape[:2], h, dkh)
        kh = k.reshape(*k.shape[:2], h, dkh)
        vh = v.reshape(*v.shape[:2], h, dvh)
        qh = lc(qh, ("batch", "seq", "heads", None), rules)
        hh, _ = mlstm_chunked(qh, kh, vh, ig, fg, cfg.xlstm.chunk)
        new_state = None
    else:
        hh, new_state = mlstm_step(state, q[:, 0].reshape(b, h, dkh),
                                   k[:, 0].reshape(b, h, dkh),
                                   v[:, 0].reshape(b, h, dvh),
                                   ig[:, 0], fg[:, 0])
        hh = hh[:, None]
    hv = hh.reshape(*hh.shape[:2], dv).astype(dt)
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", y, L.cast(params["wg"], dt)))
    out = jnp.einsum("bsk,kd->bsd", hv * g, L.cast(params["wo"], dt))
    return lc(out, ("batch", "seq", "act_embed"), rules), new_state


# ------------------------------------------------------------------- sLSTM
def slstm_scan(params, y, cfg, state=None):
    """y: [B,S,d] (already normed, fp32).  Returns (h [B,S,d], state')."""
    b, s, d = y.shape
    h = cfg.n_heads
    dh = d // h
    f32 = jnp.float32
    wx = jnp.einsum("bsd,dg->bsg", y.astype(f32),
                    params["w_zifo"].astype(f32)) \
        + params["b_zifo"].astype(f32)                    # [B,S,4d]
    wx = wx.reshape(b, s, h, 4 * dh)
    r = params["r_zifo"].astype(f32)                      # [H, dh, 4dh]

    if state is None:
        zeros = jnp.zeros((b, h, dh), f32)
        state = (zeros, zeros + 1e-6, jnp.full((b, h, dh), -1e30, f32),
                 zeros)                                   # c, n, m, h_prev

    def step(carry, wx_t):
        c, n, m, h_prev = carry
        g = wx_t + jnp.einsum("bhd,hdg->bhg", h_prev, r)
        zr, ir, fr, orr = jnp.split(g, 4, axis=-1)        # [B,H,dh] each
        logf = jax.nn.log_sigmoid(fr)
        m_new = jnp.maximum(logf + m, ir)
        fw = jnp.exp(logf + m - m_new)
        iw = jnp.exp(ir - m_new)
        c = fw * c + iw * jnp.tanh(zr)
        n = fw * n + iw
        h_t = jax.nn.sigmoid(orr) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h_t), h_t

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1).reshape(b, s, d), state


def slstm_block(params, x, cfg, rules=None, state=None):
    dt = jnp.dtype(cfg.compute_dtype)
    y = L.rms_norm(x, params["ln"], cfg.norm_eps)
    hs, new_state = slstm_scan(params, y, cfg, state=state)
    out = jnp.einsum("bsd,de->bse", hs.astype(dt), L.cast(params["wo"], dt))
    out = lc(out, ("batch", "seq", "act_embed"), rules)
    x = x + out
    x = x + L.swiglu({**params, "ln": params["ln2"]}, x, cfg, rules=rules)
    return x, new_state


# ----------------------------------------------------------------- forward
def forward(params, batch, cfg, rules=None):
    x = L.embed(params, batch["tokens"], cfg, rules)

    def mbody(x, p, _):
        out, _ = mlstm_block(p, x, cfg, rules=rules)
        return x + out, None

    def gbody(x, gp, _):
        x, _ = scan_layers(mbody, x, gp["mlstm"], cfg)
        x, _ = slstm_block(gp["slstm"], x, cfg, rules=rules)
        return x, None

    x, _ = scan_layers(gbody, x,
                       {"mlstm": params["mlstm"], "slstm": params["slstm"]},
                       cfg)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules)


# ------------------------------------------------------------------ decode
def cache_spec(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    g, k = _groups(cfg)
    d, dk, dv, h, dkh, dvh = _dims(cfg)
    dh = d // h
    return {
        "m_c": P((g, k, batch, h, dvh, dkh),
                 (None, "layers", "batch", "heads", "mlp", None),
                 init="zeros", dtype="float32"),
        "m_n": P((g, k, batch, h, dkh),
                 (None, "layers", "batch", "heads", None),
                 init="zeros", dtype="float32"),
        "m_m": P((g, k, batch, h),
                 (None, "layers", "batch", "heads"),
                 init="neg_large", dtype="float32"),
        "s_c": P((g, batch, h, dh), (None, "batch", "heads", None),
                 init="zeros", dtype="float32"),
        "s_n": P((g, batch, h, dh), (None, "batch", "heads", None),
                 init="eps", dtype="float32"),
        "s_m": P((g, batch, h, dh), (None, "batch", "heads", None),
                 init="neg_large", dtype="float32"),
        "s_h": P((g, batch, h, dh), (None, "batch", "heads", None),
                 init="zeros", dtype="float32"),
    }


def decode_step(params, cache, batch, cfg, rules=None):
    x = L.embed(params, batch["tokens"], cfg, rules)

    def mbody(x, p, st):
        out, new_st = mlstm_block(p, x, cfg, rules=rules,
                                  state=(st["c"], st["n"], st["m"]))
        c, n, m = new_st
        return x + out, {"c": c, "n": n, "m": m}

    def gbody(x, gp, gc):
        mst = {"c": gc["m_c"], "n": gc["m_n"], "m": gc["m_m"]}
        x, mst_out = scan_layers(mbody, x, gp["mlstm"], cfg, extra_xs=mst)
        x, sst = slstm_block(gp["slstm"], x, cfg, rules=rules,
                             state=(gc["s_c"], gc["s_n"], gc["s_m"],
                                    gc["s_h"]))
        return x, {"m_c": mst_out["c"], "m_n": mst_out["n"],
                   "m_m": mst_out["m"], "s_c": sst[0], "s_n": sst[1],
                   "s_m": sst[2], "s_h": sst[3]}

    x, new_cache = scan_layers(
        gbody, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]},
        cfg, extra_xs=cache)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules), new_cache


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_specs(shape.global_batch)
    return token_specs(shape.global_batch, shape.seq_len)
