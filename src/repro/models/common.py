"""Shared scaffolding for the architecture zoo.

Every model module exposes the same five functions:

  schema(cfg)                         -> pytree of P (declarative params)
  forward(params, batch, cfg, rules)  -> logits  [B, S, vocab]
  cache_spec(cfg, batch, max_len)     -> pytree of P for the decode cache
  decode_step(params, cache, batch, cfg, rules) -> (logits, new_cache)
  prefill(params, cache, batch, cfg, rules)     -> (logits, new_cache)

`batch` is a dict of arrays (tokens/labels/positions/frames/patch_embeds);
the launcher builds ShapeDtypeStructs of exactly the same structure for the
AOT dry-run.  Layer parameters are *stacked* along a leading "layers" axis
so the forward pass is a `lax.scan` — constant-size HLO regardless of depth,
which is what keeps 88-layer × 512-device AOT compiles tractable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import P

VOCAB_PAD = 128


def padded_vocab(cfg) -> int:
    """Embedding tables are padded to a multiple of 128 so the vocab axis
    shards evenly on any mesh axis up to 128-way (whisper's 51865 and
    granite's 49155 are not divisible by 16)."""
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


def stacked(n_layers: int, sub: Dict[str, P]) -> Dict[str, P]:
    """Add a leading scan ("layers") axis to every P in a per-layer schema."""
    out = {}
    for k, p in sub.items():
        out[k] = P((n_layers,) + p.shape, ("layers",) + p.axes,
                   init=p.init, scale=p.scale, dtype=p.dtype)
    return out


def scan_layers(body, x, layer_params, cfg, *, extra_xs=None, length=None):
    """`lax.scan` over stacked layer params with the config remat policy.

    body(x, per_layer_params, per_layer_xs) -> (x, per_layer_ys)
    """
    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots,
            prevent_cse=False)

    def step(carry, xs):
        params_i, extra_i = xs
        return body(carry, params_i, extra_i)

    xs = (layer_params, extra_xs)
    x, ys = jax.lax.scan(step, x, xs, length=length)
    return x, ys


def attn_cache_spec(cfg, batch: int, window: int,
                    n_layers: Optional[int] = None,
                    prefix: str = "") -> Dict[str, P]:
    """Ring-buffer KV cache schema, stacked on layers.

    key_pos is int32 (-1 = empty); caches live in compute dtype.
    """
    n_layers = cfg.n_layers if n_layers is None else n_layers
    hd = cfg.head_dim_
    kv = (n_layers, batch, window, cfg.n_kv_heads, hd)
    kv_axes = ("layers", "batch", "window", "kv_heads", None)
    return {
        prefix + "k": P(kv, kv_axes, init="zeros", dtype=cfg.compute_dtype),
        prefix + "v": P(kv, kv_axes, init="zeros", dtype=cfg.compute_dtype),
        prefix + "key_pos": P((n_layers, batch, window),
                              ("layers", "batch", "window"),
                              init="neg_ones", dtype="int32"),
    }


def decode_window(cfg, max_len: int) -> int:
    """Cache width: full history, or the sliding window if the config
    declares one (sub-quadratic long-context cells)."""
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def positions_for(tokens: jax.Array) -> jax.Array:
    return jnp.arange(tokens.shape[1])[None, :]


def token_specs(batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


def decode_specs(batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
