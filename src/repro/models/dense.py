"""Dense GQA transformer (mistral-large / command-r / qwen2 / smollm) and
the LLaVA VLM backbone (dense + projected patch embeddings).

Sequential pre-norm blocks by default; `parallel_block=True` (command-r)
computes attention and FFN from one shared norm and sums the branches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import P, logical_constraint as lc
from . import layers as L
from .common import (attn_cache_spec, decode_specs, decode_window,
                     padded_vocab, scan_layers, stacked, token_specs)


def layer_schema(cfg) -> Dict[str, P]:
    d, hd = cfg.d_model, cfg.head_dim_
    s: Dict[str, P] = {
        "ln": P((d,), ("act_embed",), init="ones"),
        "wq": P((d, cfg.n_heads * hd), ("embed", "heads"), init="scaled"),
        "wk": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wv": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wo": P((cfg.n_heads * hd, d), ("heads", "embed"), init="scaled"),
        "ln2": P((d,), ("act_embed",), init="ones"),
        "w_gate": P((d, cfg.d_ff), ("embed", "mlp"), init="scaled"),
        "w_up": P((d, cfg.d_ff), ("embed", "mlp"), init="scaled"),
        "w_down": P((cfg.d_ff, d), ("mlp", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        s["wq_b"] = P((cfg.n_heads * hd,), ("heads",), init="zeros")
        s["wk_b"] = P((cfg.n_kv_heads * hd,), ("kv_heads",), init="zeros")
        s["wv_b"] = P((cfg.n_kv_heads * hd,), ("kv_heads",), init="zeros")
    if getattr(cfg, "parallel_block", False):
        del s["ln2"]                      # one shared norm per block
    return s


def schema(cfg) -> Dict[str, Any]:
    v = padded_vocab(cfg)
    s: Dict[str, Any] = {
        "embedding": P((v, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "layers": stacked(cfg.n_layers, layer_schema(cfg)),
    }
    if not cfg.tie_embeddings:
        s["unembedding"] = P((v, cfg.d_model), ("vocab", "embed"))
    if cfg.vlm is not None:
        s["vision_proj"] = P((cfg.vlm.vision_dim, cfg.d_model),
                             (None, "embed"), init="scaled")
    return s


def _block(params, x, cfg, *, positions, rules, cache=None,
           sliding_window=None):
    """One transformer block; returns (x, new_cache)."""
    if getattr(cfg, "parallel_block", False):
        y = L.rms_norm(x, params["ln"], cfg.norm_eps)
        attn, new_cache = L.gqa_block(params, y, cfg, positions=positions,
                                      rules=rules, cache=cache, norm=False,
                                      sliding_window=sliding_window)
        mlp = L.swiglu({**params, "ln": None}, y, cfg, rules=rules,
                       pre_normed=True)
        return x + attn + mlp, new_cache
    attn, new_cache = L.gqa_block(params, x, cfg, positions=positions,
                                  rules=rules, cache=cache,
                                  sliding_window=sliding_window)
    x = x + attn
    x = x + L.swiglu({**params, "ln": params["ln2"]}, x, cfg, rules=rules)
    return x, new_cache


def _embed_inputs(params, batch, cfg, rules):
    """Token embeddings, with projected patch embeddings prepended for the
    VLM backbone (the vision tower itself is a stub per the assignment)."""
    x = L.embed(params, batch["tokens"], cfg, rules)
    positions = jnp.arange(batch["tokens"].shape[1])[None, :]
    if cfg.vlm is not None and "patch_embeds" in batch:
        dt = jnp.dtype(cfg.compute_dtype)
        patches = jnp.einsum("bpv,vd->bpd",
                             batch["patch_embeds"].astype(dt),
                             params["vision_proj"].astype(dt))
        patches = lc(patches, ("batch", "seq", "act_embed"), rules)
        x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
    return x, positions


def forward(params, batch, cfg, rules=None):
    x, positions = _embed_inputs(params, batch, cfg, rules)

    def body(x, p, _):
        x, _ = _block(p, x, cfg, positions=positions, rules=rules,
                      sliding_window=cfg.sliding_window)
        return x, None

    x, _ = scan_layers(body, x, params["layers"], cfg)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, x, cfg, rules)
    if cfg.vlm is not None and "patch_embeds" in batch:
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    return logits


# ------------------------------------------------------------------ decode
def cache_spec(cfg, batch: int, max_len: int) -> Dict[str, P]:
    return attn_cache_spec(cfg, batch, decode_window(cfg, max_len))


def decode_step(params, cache, batch, cfg, rules=None):
    """One token: batch = {"tokens": [B,1], "pos": [B]}."""
    x = L.embed(params, batch["tokens"], cfg, rules)
    pos = batch["pos"]

    def body(x, p, cache_l):
        x, new_cache = _block(p, x, cfg, positions=pos, rules=rules,
                              cache=(cache_l["k"], cache_l["v"],
                                     cache_l["key_pos"]),
                              sliding_window=cfg.sliding_window)
        k, v, kp = new_cache
        return x, {"k": k, "v": v, "key_pos": kp}

    x, new_cache = scan_layers(body, x, params["layers"], cfg,
                               extra_xs=cache)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules), new_cache


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_specs(shape.global_batch)
    specs = token_specs(shape.global_batch, shape.seq_len)
    if cfg.vlm is not None:
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vlm.num_patches, cfg.vlm.vision_dim),
            jnp.dtype(cfg.compute_dtype))
    return specs
