"""Shared building blocks for the architecture zoo (pure JAX).

Everything is functional: params are pytrees produced by the declarative
schemas in each model file; these functions only compute.  ``rules`` is an
optional logical→mesh table that drops activation sharding constraints into
the graph (no-op when None, e.g. CPU smoke tests).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import logical_constraint as lc


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x, scale, bias, eps):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


# ----------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2,
                                      dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,D/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              sliding_window: Optional[int] = None,
              q_offset: Optional[jax.Array] = None,
              kv_len: Optional[jax.Array] = None,
              impl: str = "xla") -> jax.Array:
    """Scaled-dot-product GQA attention.

    q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D].  ``q_offset`` positions the
    query block inside the kv timeline (decode: q_offset = kv_len - 1).
    ``kv_len`` masks out unwritten cache slots.  ``impl`` selects the XLA
    einsum path or the Pallas flash kernel (train/prefill shapes).
    """
    if impl.startswith("pallas") and q.shape[1] > 1 and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal,
                               interpret=(impl == "pallas_interpret"))

    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    # grouped GQA: fold query heads over their kv head — no repeat_kv
    # materialization of the K/V tensors (§Perf)
    qg = q.reshape(b, sq, hkv, g, d)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale

    # positions: qpos [Bm, Sq] (Bm = 1 or B), kpos [Skv]
    qpos = jnp.arange(sq)[None, :]
    if q_offset is not None:
        qpos = qpos + jnp.reshape(q_offset, (-1, 1))
    kpos = jnp.arange(skv)
    mask = jnp.ones((qpos.shape[0], sq, skv), dtype=bool)
    if causal:
        mask = mask & (kpos[None, None, :] <= qpos[:, :, None])
    if sliding_window is not None:
        mask = mask & (kpos[None, None, :]
                       > qpos[:, :, None] - sliding_window)
    if kv_len is not None:
        mask = mask & (kpos[None, None, :] < jnp.reshape(kv_len, (-1, 1, 1)))
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     key_pos: jax.Array, qpos: jax.Array, *,
                     sliding_window: Optional[int] = None,
                     rules=None) -> jax.Array:
    """Single-token attention against a ring-buffer cache.

    q: [B, 1, H, D]; k_cache/v_cache: [B, W, Hkv, D]; key_pos: [B, W]
    (absolute position written to each slot, -1 = empty); qpos: [B].
    The ring layout makes full caches (W = max_len) and sliding-window
    caches (W = window) the same code path — key validity is positional,
    not slot-order based.

    GQA is computed GROUPED (q reshaped to [B, Hkv, G, D]) — never via
    `repeat_kv`, which would materialize H/Hkv copies of the cache in HBM
    per layer per step (§Perf iteration: 12× cache-read blowup on
    mistral-large decode_32k).
    """
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    mask = (key_pos >= 0) & (key_pos <= qpos[:, None])
    if sliding_window is not None:
        mask = mask & (key_pos > qpos[:, None] - sliding_window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    # probs back in cache dtype: the AV einsum must read the cache at its
    # storage precision — an explicit f32 astype of the (sliced) cache gets
    # hoisted by XLA into a full-cache convert INSIDE the layer loop
    # (measured: 2.27 TB/step on mistral-large decode_32k; §Perf)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h, d).astype(q.dtype)
    return lc(out, ("batch", None, "heads", None), rules)


def cache_write(k_cache: jax.Array, v_cache: jax.Array, key_pos: jax.Array,
                k: jax.Array, v: jax.Array, pos: jax.Array):
    """Write one token's K/V into the ring cache at slot = pos % W.

    k_cache/v_cache: [B, W, Hkv, D]; key_pos: [B, W]; k/v: [B, 1, Hkv, D];
    pos: [B].  One batched scatter (unique indices) instead of a
    vmap(dynamic_update_slice): the SPMD partitioner keeps the batch
    dimension aligned for the former but falls back to replicate-and-
    repartition for the latter (§Perf)."""
    b, w = k_cache.shape[:2]
    slot = pos % w
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(
        k[:, 0].astype(k_cache.dtype), unique_indices=True,
        indices_are_sorted=True)
    v_cache = v_cache.at[bidx, slot].set(
        v[:, 0].astype(v_cache.dtype), unique_indices=True,
        indices_are_sorted=True)
    key_pos = key_pos.at[bidx, slot].set(
        pos.astype(key_pos.dtype), unique_indices=True,
        indices_are_sorted=True)
    return k_cache, v_cache, key_pos


def gqa_block(params: Dict[str, Any], x: jax.Array, cfg, *,
              positions: jax.Array, rules=None,
              cache: Optional[Tuple] = None,
              sliding_window: Optional[int] = None,
              norm: bool = True):
    """Pre-norm GQA attention block.  Returns (out, new_cache).

    Training/prefill: cache=None, full sequence.
    Decode: x is [B, 1, d]; cache=(k_cache, v_cache, key_pos) — ring-buffer
    layout [B, W, Hkv, D] (see `decode_attention`); positions [B] are the
    absolute token positions being written.
    `norm=False` skips the input norm (parallel-block archs norm once).
    """
    dt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    y = rms_norm(x, params["ln"], cfg.norm_eps) if norm else x
    b, s, _ = y.shape

    def proj(name, heads):
        w = cast(params[name], dt)
        out = jnp.einsum("bsd,dhk->bshk", y, w.reshape(cfg.d_model, heads, hd))
        if cfg.qkv_bias and f"{name}_b" in params:
            out = out + cast(params[f"{name}_b"], dt).reshape(1, 1, heads, hd)
        return out

    q = proj("wq", cfg.n_heads)
    k = proj("wk", cfg.n_kv_heads)
    v = proj("wv", cfg.n_kv_heads)
    if cache is not None and positions.ndim == 1:
        rope_pos = positions[:, None]                   # [B] -> [B, 1]
    else:
        rope_pos = positions
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    k = apply_rope(k, rope_pos, cfg.rope_theta)
    q = lc(q, ("batch", "seq", "heads", None), rules)
    k = lc(k, ("batch", "seq", "kv_heads", None), rules)

    new_cache = None
    if cache is not None:
        k_cache, v_cache, key_pos = cache
        k_cache, v_cache, key_pos = cache_write(
            k_cache, v_cache, key_pos, cast(k, k_cache.dtype),
            cast(v, v_cache.dtype), positions)
        new_cache = (k_cache, v_cache, key_pos)
        attn = decode_attention(q, cast(k_cache, dt), cast(v_cache, dt),
                                key_pos, positions,
                                sliding_window=sliding_window, rules=rules)
    else:
        attn = attention(q, k, v, causal=True,
                         sliding_window=sliding_window, impl=cfg.attn_impl)

    wo = cast(params["wo"], dt)
    out = jnp.einsum("bshk,hkd->bsd",
                     attn, wo.reshape(cfg.n_heads, hd, cfg.d_model))
    return lc(out, ("batch", "seq", "act_embed"), rules), new_cache


# ------------------------------------------------------------------- MLPs
def swiglu(params, x, cfg, rules=None, pre_normed=False):
    dt = jnp.dtype(cfg.compute_dtype)
    y = x if pre_normed else rms_norm(x, params["ln"], cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", y, cast(params["w_gate"], dt))
    up = jnp.einsum("bsd,df->bsf", y, cast(params["w_up"], dt))
    h = jax.nn.silu(gate) * up
    h = lc(h, ("batch", "seq", "act_mlp"), rules)
    out = jnp.einsum("bsf,fd->bsd", h, cast(params["w_down"], dt))
    return lc(out, ("batch", "seq", "act_embed"), rules)


def gelu_mlp(params, x, cfg, rules=None):
    dt = jnp.dtype(cfg.compute_dtype)
    y = layer_norm(x, params["ln"], params["ln_b"], cfg.norm_eps)
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, cast(params["w_up"], dt))
                    + cast(params["b_up"], dt))
    h = lc(h, ("batch", "seq", "act_mlp"), rules)
    return jnp.einsum("bsf,fd->bsd", h, cast(params["w_down"], dt)) \
        + cast(params["b_down"], dt)


# -------------------------------------------------------------------- MoE
def moe_block(params, x, cfg, rules=None, rng=None):
    """Top-k expert routing with fixed capacity (gather/scatter dispatch).

    Returns (out, aux_loss).  Compute scales with capacity (≈ active
    experts), not num_experts — matching the MoE roofline.  EP: the expert
    dim of the weights is sharded on "model"; XLA inserts the all-to-alls.
    """
    m = cfg.moe
    e_pad = m.e_pad
    dt = jnp.dtype(cfg.compute_dtype)
    y = rms_norm(x, params["ln"], cfg.norm_eps)
    b, s, d = y.shape
    n_tok = b * s
    flat = y.reshape(n_tok, d)

    router_logits = jnp.einsum("td,de->te", flat.astype(jnp.float32),
                               params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)         # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], m.num_experts, dtype=jnp.float32),
        axis=0)
    aux = m.num_experts * jnp.sum(me * ce) * m.router_aux_weight

    capacity = int(np.ceil(n_tok * m.top_k / m.num_experts
                           * m.capacity_factor))
    capacity = max(capacity, 1)

    # slot assignment: position of each (token, choice) within its expert
    flat_e = top_e.reshape(-1)                           # [T*k]
    onehot = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot       # 1-based slot
    slot = jnp.sum(pos_in_e, axis=-1) - 1                # [T*k]
    keep = slot < capacity                               # dropped beyond C

    # dispatch: expert_inputs[e, c] = token routed to (e, c); the router
    # only ever selects real experts, so padded rows stay empty
    tok_idx = jnp.arange(n_tok * m.top_k) // m.top_k
    e_idx = jnp.where(keep, flat_e, e_pad)               # overflow bucket
    s_idx = jnp.where(keep, slot, 0)
    expert_in = jnp.zeros((e_pad + 1, capacity, d), dt)
    expert_in = expert_in.at[e_idx, s_idx].set(flat[tok_idx].astype(dt))
    expert_in = expert_in[:-1]                           # drop overflow
    expert_in = lc(expert_in, ("experts", None, "act_embed"), rules)

    # per-expert SwiGLU at fixed capacity
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in,
                               cast(params["w_gate"], dt))) \
        * jnp.einsum("ecd,edf->ecf", expert_in, cast(params["w_up"], dt))
    expert_out = jnp.einsum("ecf,efd->ecd", h, cast(params["w_down"], dt))
    expert_out = lc(expert_out, ("experts", None, "act_embed"), rules)

    # combine: weighted scatter back to token positions
    gathered = expert_out[jnp.where(keep, flat_e, 0), s_idx]   # [T*k, d]
    weight = jnp.where(keep, top_p.reshape(-1), 0.0).astype(dt)
    out = jnp.zeros((n_tok, d), dt).at[tok_idx].add(gathered * weight[:, None])
    return out.reshape(b, s, d), aux


# -------------------------------------------------------------- embeddings
def embed(params, tokens, cfg, rules=None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(cast(params["embedding"], dt), tokens, axis=0)
    return lc(x, ("batch", "seq", "act_embed"), rules)


def unembed(params, x, cfg, rules=None):
    dt = jnp.dtype(cfg.compute_dtype)
    w = params.get("unembedding", params["embedding"])
    logits = jnp.einsum("bsd,vd->bsv", x, cast(w, dt))
    return lc(logits, ("batch", "seq", "act_vocab"), rules)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)
