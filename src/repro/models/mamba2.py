"""Mamba2 (SSD) blocks + Zamba2-style shared attention (zamba2-1.2b).

Training/prefill uses the chunked SSD algorithm (quadratic only within a
chunk, linear across chunks); decode is the O(1) recurrent update.  The
layer stack is grouped: after every `hybrid.attn_period` Mamba2 layers the
*weight-shared* attention+MLP block is applied (separate KV caches per
application site — weights are shared, history is not).  Groups are
unrolled in Python with an inner `lax.scan` per group so HLO cost reflects
the true number of attention applications.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import P, logical_constraint as lc
from . import layers as L
from .common import (attn_cache_spec, decode_specs, decode_window,
                     padded_vocab, scan_layers, stacked, token_specs)


# --------------------------------------------------------------- structure
def group_sizes(cfg) -> List[int]:
    """Mamba-layer run lengths; shared attention fires after each full
    `attn_period`-sized group (not after a trailing remainder)."""
    period = cfg.hybrid.attn_period if cfg.hybrid else cfg.n_layers
    sizes, left = [], cfg.n_layers
    while left > 0:
        sizes.append(min(period, left))
        left -= period
    return sizes


def num_attn_sites(cfg) -> int:
    period = cfg.hybrid.attn_period if cfg.hybrid else cfg.n_layers
    return sum(1 for s in group_sizes(cfg) if s == period) \
        if cfg.hybrid and cfg.hybrid.shared_attention else 0


def _dims(cfg):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    nheads = inner // s.head_dim
    conv_dim = inner + 2 * s.state_dim
    return inner, nheads, conv_dim


# ------------------------------------------------------------------ schema
def layer_schema(cfg) -> Dict[str, P]:
    d, s = cfg.d_model, cfg.ssm
    inner, nheads, conv_dim = _dims(cfg)
    return {
        "ln": P((d,), ("act_embed",), init="ones"),
        # in_proj → [z(inner), x(inner), B(N), C(N), dt(H)]
        "in_proj": P((d, 2 * inner + 2 * s.state_dim + nheads),
                     ("embed", "heads"), init="scaled"),
        "conv_w": P((s.conv_width, conv_dim), ("conv", "heads"),
                    init="scaled", scale=0.5),
        "conv_b": P((conv_dim,), ("heads",), init="zeros"),
        "a_log": P((nheads,), ("heads",), init="ones"),
        "d_skip": P((nheads,), ("heads",), init="ones"),
        "dt_bias": P((nheads,), ("heads",), init="zeros"),
        "norm": P((inner,), ("heads",), init="ones"),
        "out_proj": P((inner, d), ("heads", "embed"), init="scaled"),
    }


def attn_block_schema(cfg) -> Dict[str, P]:
    """The single weight-shared attention + MLP block."""
    d, hd = cfg.d_model, cfg.head_dim_
    return {
        "ln": P((d,), ("act_embed",), init="ones"),
        "wq": P((d, cfg.n_heads * hd), ("embed", "heads"), init="scaled"),
        "wk": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wv": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wo": P((cfg.n_heads * hd, d), ("heads", "embed"), init="scaled"),
        "ln2": P((d,), ("act_embed",), init="ones"),
        "w_gate": P((d, cfg.d_ff), ("embed", "mlp"), init="scaled"),
        "w_up": P((d, cfg.d_ff), ("embed", "mlp"), init="scaled"),
        "w_down": P((cfg.d_ff, d), ("mlp", "embed"), init="scaled"),
    }


def schema(cfg) -> Dict[str, Any]:
    v = padded_vocab(cfg)
    s: Dict[str, Any] = {
        "embedding": P((v, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "layers": stacked(cfg.n_layers, layer_schema(cfg)),
    }
    if cfg.hybrid and cfg.hybrid.shared_attention:
        s["shared_attn"] = attn_block_schema(cfg)
    return s


# ----------------------------------------------------------- SSD (chunked)
def ssd_chunked(xh, dt, a_log, b, c, d_skip, chunk: int,
                s0: Optional[jax.Array] = None, rules=None):
    """Chunked SSD scan (Mamba-2 §6, adapted for TPU-friendly einsums).

    xh: [B,S,H,Pd]  dt: [B,S,H] (post-softplus)  a_log: [H] (A = -exp(a_log))
    b, c: [B,S,N]   d_skip: [H]   s0: [B,H,Pd,N] initial state or None.
    Returns (y [B,S,H,Pd], s_final [B,H,Pd,N]).  All state math in fp32.
    """
    bsz, seq, h, pd = xh.shape
    n = b.shape[-1]
    q = min(chunk, seq)
    assert seq % q == 0, f"seq {seq} % chunk {q} != 0"
    nc = seq // q
    f32 = jnp.float32

    dt = dt.astype(f32)
    la = -jnp.exp(a_log.astype(f32))                      # A (negative)
    dta = dt * la                                         # [B,S,H] log-decay
    xw = xh.astype(f32) * dt[..., None]                   # dt-weighted input

    def r(t, tail):                                       # chunkify
        return t.reshape((bsz, nc, q) + tail)

    dta, xw = r(dta, (h,)), r(xw, (h, pd))
    bc, cc = r(b.astype(f32), (n,)), r(c.astype(f32), (n,))
    lcum = jnp.cumsum(dta, axis=2)                        # [B,C,Q,H]

    # intra-chunk: M[i,j] = (c_i·b_j)·exp(l_i − l_j), j ≤ i  (l_i−l_j ≤ 0)
    g = jnp.einsum("bcin,bcjn->bcij", cc, bc)             # [B,C,Q,Q]
    ldiff = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]   # [B,C,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(tri[None, None, :, :, None],
                  jnp.exp(ldiff), 0.0) * g[..., None]     # [B,C,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xw)

    # per-chunk state contribution: Σ_j exp(l_Q − l_j)·xw_j ⊗ b_j
    decay_to_end = jnp.exp(lcum[:, :, -1:, :] - lcum)     # [B,C,Q,H]
    chunk_state = jnp.einsum("bcjh,bcjhp,bcjn->bchpn",
                             decay_to_end, xw, bc)
    chunk_decay = jnp.exp(lcum[:, :, -1, :])              # [B,C,H]

    # inter-chunk scan over the carried state
    def step(s, inp):
        cs, cd = inp                                      # [B,H,Pd,N], [B,H]
        s_in = s
        s = s * cd[:, :, None, None] + cs
        return s, s_in

    s_init = (jnp.zeros((bsz, h, pd, n), f32) if s0 is None
              else s0.astype(f32))
    cs_t = jnp.moveaxis(chunk_state, 1, 0)                # [C,B,H,Pd,N]
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)                # [C,B,H]
    s_final, s_starts = jax.lax.scan(step, s_init, (cs_t, cd_t))
    s_starts = jnp.moveaxis(s_starts, 0, 1)               # [B,C,H,Pd,N]

    # inter-chunk output: c_i · (exp(l_i)·S_start)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         cc, jnp.exp(lcum), s_starts)

    y = (y_intra + y_inter).reshape(bsz, seq, h, pd)
    y = y + d_skip.astype(f32)[None, None, :, None] * xh.astype(f32)
    return y, s_final


def ssd_step(s, xh, dt, a_log, b, c, d_skip):
    """Recurrent single-token SSD update.

    s: [B,H,Pd,N]; xh: [B,H,Pd]; dt: [B,H]; b,c: [B,N].
    Returns (y [B,H,Pd], s')."""
    f32 = jnp.float32
    dt = dt.astype(f32)
    la = -jnp.exp(a_log.astype(f32))
    decay = jnp.exp(dt * la)                              # [B,H]
    xw = xh.astype(f32) * dt[..., None]                   # [B,H,Pd]
    s = s * decay[:, :, None, None] \
        + jnp.einsum("bhp,bn->bhpn", xw, b.astype(f32))
    y = jnp.einsum("bhpn,bn->bhp", s, c.astype(f32))
    y = y + d_skip.astype(f32)[None, :, None] * xh.astype(f32)
    return y, s


# ---------------------------------------------------------- Mamba2 block
def _split_proj(cfg, zxbcdt):
    inner, nheads, _ = _dims(cfg)
    n = cfg.ssm.state_dim
    z, x, b, c, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + n, 2 * inner + 2 * n],
        axis=-1)
    return z, x, b, c, dt


def mamba2_block(params, x_in, cfg, rules=None,
                 state: Optional[Tuple] = None):
    """Pre-norm Mamba2 block.  Returns (out, new_state).

    Training/prefill: state=None (zero-initialized, discarded).
    Decode: x_in is [B,1,d]; state = (conv_buf [B,K-1,convdim], s [B,H,Pd,N]).
    """
    dt_c = jnp.dtype(cfg.compute_dtype)
    s_cfg = cfg.ssm
    inner, nheads, conv_dim = _dims(cfg)
    y = L.rms_norm(x_in, params["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", y, L.cast(params["in_proj"], dt_c))
    z, xs, b, c, dt_raw = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    conv_in = jnp.concatenate([xs, b, c], axis=-1)        # [B,S,convdim]
    new_state = None
    if state is None:
        # causal depthwise conv via shifted adds (width is tiny, K=4)
        k = s_cfg.conv_width
        w = params["conv_w"].astype(jnp.float32)          # [K, convdim]
        acc = jnp.zeros_like(conv_in, dtype=jnp.float32)
        for i in range(k):
            shift = k - 1 - i
            seg = conv_in.astype(jnp.float32)
            if shift > 0:
                seg = jnp.pad(seg[:, :-shift], ((0, 0), (shift, 0), (0, 0)))
            acc = acc + seg * w[i]
        conv_out = jax.nn.silu(acc + params["conv_b"].astype(jnp.float32))
        xs, b, c = jnp.split(conv_out, [inner, inner + s_cfg.state_dim],
                             axis=-1)
        xh = xs.reshape(*xs.shape[:2], nheads, s_cfg.head_dim)
        xh = lc(xh, ("batch", "seq", "heads", None), rules)
        yh, s_fin = ssd_chunked(xh, dt, params["a_log"], b, c,
                                params["d_skip"], s_cfg.chunk, rules=rules)
    else:
        conv_buf, s0 = state
        k = s_cfg.conv_width
        w = params["conv_w"].astype(jnp.float32)
        hist = jnp.concatenate(
            [conv_buf, conv_in.astype(conv_buf.dtype)], axis=1)  # [B,K,cd]
        conv_out = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
            + params["conv_b"].astype(jnp.float32))[:, None]     # [B,1,cd]
        xs1, b1, c1 = jnp.split(conv_out, [inner, inner + s_cfg.state_dim],
                                axis=-1)
        xh = xs1[:, 0].reshape(-1, nheads, s_cfg.head_dim)
        yh, s_fin = ssd_step(s0, xh, dt[:, 0], params["a_log"],
                             b1[:, 0], c1[:, 0], params["d_skip"])
        yh = yh[:, None]                                  # [B,1,H,Pd]
        new_state = (hist[:, 1:], s_fin)

    yv = yh.reshape(*yh.shape[:2], inner)
    # gated RMSNorm (Mamba2: norm(y) ⊙ silu(z)), then out-projection
    yv = L.rms_norm(yv.astype(dt_c), params["norm"], cfg.norm_eps) \
        * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", yv, L.cast(params["out_proj"], dt_c))
    return lc(out, ("batch", "seq", "act_embed"), rules), new_state


def _shared_attn(params, x, cfg, *, positions, rules, cache=None):
    attn, new_cache = L.gqa_block(params, x, cfg, positions=positions,
                                  rules=rules, cache=cache,
                                  sliding_window=cfg.sliding_window)
    x = x + attn
    x = x + L.swiglu({**params, "ln": params["ln2"]}, x, cfg, rules=rules)
    return x, new_cache


# ----------------------------------------------------------------- forward
def _slice_layers(layers, start, size):
    return jax.tree.map(lambda a: a[start:start + size], layers)


def forward(params, batch, cfg, rules=None):
    x = L.embed(params, batch["tokens"], cfg, rules)
    positions = jnp.arange(batch["tokens"].shape[1])[None, :]
    period = cfg.hybrid.attn_period if cfg.hybrid else cfg.n_layers

    def body(x, p, _):
        out, _ = mamba2_block(p, x, cfg, rules=rules)
        return x + out, None

    start = 0
    for size in group_sizes(cfg):
        x, _ = scan_layers(body, x, _slice_layers(params["layers"],
                                                  start, size), cfg)
        start += size
        if size == period and "shared_attn" in params:
            x, _ = _shared_attn(params["shared_attn"], x, cfg,
                                positions=positions, rules=rules)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules)


# ------------------------------------------------------------------ decode
def cache_spec(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    s = cfg.ssm
    inner, nheads, conv_dim = _dims(cfg)
    spec: Dict[str, Any] = {
        "conv": P((cfg.n_layers, batch, s.conv_width - 1, conv_dim),
                  ("layers", "batch", None, "heads"), init="zeros",
                  dtype=cfg.compute_dtype),
        "ssm": P((cfg.n_layers, batch, nheads, s.head_dim, s.state_dim),
                 ("layers", "batch", "heads", None, "state"),
                 init="zeros", dtype="float32"),
    }
    sites = num_attn_sites(cfg)
    if sites:
        spec["attn"] = attn_cache_spec(
            cfg, batch, decode_window(cfg, max_len), n_layers=sites)
    return spec


def decode_step(params, cache, batch, cfg, rules=None):
    x = L.embed(params, batch["tokens"], cfg, rules)
    pos = batch["pos"]
    period = cfg.hybrid.attn_period if cfg.hybrid else cfg.n_layers

    def body(x, p, st):
        out, new_st = mamba2_block(p, x, cfg, rules=rules,
                                   state=(st["conv"], st["ssm"]))
        return x + out, {"conv": new_st[0], "ssm": new_st[1]}

    start, site = 0, 0
    new_cache: Dict[str, Any] = {"conv": [], "ssm": []}
    new_attn = {"k": [], "v": [], "key_pos": []}
    for size in group_sizes(cfg):
        st = {"conv": cache["conv"][start:start + size],
              "ssm": cache["ssm"][start:start + size]}
        x, st_out = scan_layers(body, x, _slice_layers(params["layers"],
                                                       start, size), cfg,
                                extra_xs=st)
        new_cache["conv"].append(st_out["conv"])
        new_cache["ssm"].append(st_out["ssm"])
        start += size
        if size == period and "shared_attn" in params:
            ac = cache["attn"]
            x, (k, v, kp) = _shared_attn(
                params["shared_attn"], x, cfg, positions=pos, rules=rules,
                cache=(ac["k"][site], ac["v"][site], ac["key_pos"][site]))
            new_attn["k"].append(k)
            new_attn["v"].append(v)
            new_attn["key_pos"].append(kp)
            site += 1

    out: Dict[str, Any] = {
        "conv": jnp.concatenate(new_cache["conv"], axis=0),
        "ssm": jnp.concatenate(new_cache["ssm"], axis=0),
    }
    if site:
        out["attn"] = {k: jnp.stack(v_, axis=0)
                       for k, v_ in new_attn.items()}
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules), out


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_specs(shape.global_batch)
    return token_specs(shape.global_batch, shape.seq_len)
