"""Whisper-style encoder-decoder (whisper-medium).

The conv frontend is a stub per the assignment: `input_specs()` provides
precomputed frame embeddings [B, encoder_seq, d_model].  LayerNorm blocks
with biases, GELU MLPs, learned decoder positions, sinusoidal encoder
positions, tied decoder embedding/unembedding — whisper conventions.

Decode caches the decoder self-attention ring buffer AND the cross-attention
K/V (computed once from the encoder output at prefill; the decode cell feeds
them in as part of the cache).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import P, logical_constraint as lc
from . import layers as L
from .common import (attn_cache_spec, decode_specs, decode_window,
                     padded_vocab, scan_layers, stacked)


# ------------------------------------------------------------------ schema
def _attn_schema(cfg, prefix: str) -> Dict[str, P]:
    d, hd = cfg.d_model, cfg.head_dim_
    return {
        f"{prefix}ln": P((d,), ("act_embed",), init="ones"),
        f"{prefix}ln_b": P((d,), ("act_embed",), init="zeros"),
        f"{prefix}wq": P((d, cfg.n_heads * hd), ("embed", "heads"),
                         init="scaled"),
        f"{prefix}wq_b": P((cfg.n_heads * hd,), ("heads",), init="zeros"),
        f"{prefix}wk": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                         init="scaled"),
        f"{prefix}wv": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                         init="scaled"),
        f"{prefix}wv_b": P((cfg.n_kv_heads * hd,), ("kv_heads",),
                           init="zeros"),
        f"{prefix}wo": P((cfg.n_heads * hd, d), ("heads", "embed"),
                         init="scaled"),
        f"{prefix}wo_b": P((d,), ("act_embed",), init="zeros"),
    }


def _mlp_schema(cfg) -> Dict[str, P]:
    d = cfg.d_model
    return {
        "mlp_ln": P((d,), ("act_embed",), init="ones"),
        "mlp_ln_b": P((d,), ("act_embed",), init="zeros"),
        "w_up": P((d, cfg.d_ff), ("embed", "mlp"), init="scaled"),
        "b_up": P((cfg.d_ff,), ("mlp",), init="zeros"),
        "w_down": P((cfg.d_ff, d), ("mlp", "embed"), init="scaled"),
        "b_down": P((d,), ("act_embed",), init="zeros"),
    }


def enc_layer_schema(cfg) -> Dict[str, P]:
    return {**_attn_schema(cfg, "self_"), **_mlp_schema(cfg)}


def dec_layer_schema(cfg) -> Dict[str, P]:
    return {**_attn_schema(cfg, "self_"), **_attn_schema(cfg, "cross_"),
            **_mlp_schema(cfg)}


def schema(cfg) -> Dict[str, Any]:
    v = padded_vocab(cfg)
    e = cfg.encdec
    return {
        "embedding": P((v, cfg.d_model), ("vocab", "embed")),
        "pos_emb": P((cfg.max_seq, cfg.d_model), (None, "embed")),
        "enc_ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "enc_ln_f_b": P((cfg.d_model,), ("act_embed",), init="zeros"),
        "dec_ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "dec_ln_f_b": P((cfg.d_model,), ("act_embed",), init="zeros"),
        "encoder": stacked(e.encoder_layers, enc_layer_schema(cfg)),
        "decoder": stacked(cfg.n_layers, dec_layer_schema(cfg)),
    }


# --------------------------------------------------------------- attention
def _proj(params, prefix, name, y, heads, hd, dt, bias=True):
    w = L.cast(params[f"{prefix}{name}"], dt)
    out = jnp.einsum("bsd,dhk->bshk", y,
                     w.reshape(y.shape[-1], heads, hd))
    bkey = f"{prefix}{name}_b"
    if bias and bkey in params:
        out = out + L.cast(params[bkey], dt).reshape(1, 1, heads, hd)
    return out


def _attn(params, prefix, x, kv_src, cfg, *, causal, rules,
          cache: Optional[Tuple] = None, positions=None,
          static_kv: Optional[Tuple] = None):
    """LN attention block with biases, no RoPE.  Returns (out, cache').

    kv_src: sequence K/V come from (encoder output for cross-attention).
    static_kv: precomputed (k, v) — decode-time cross-attention.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    y = L.layer_norm(x, params[f"{prefix}ln"], params[f"{prefix}ln_b"],
                     cfg.norm_eps)
    q = _proj(params, prefix, "wq", y, cfg.n_heads, hd, dt)
    q = lc(q, ("batch", "seq", "heads", None), rules)

    new_cache = None
    if static_kv is not None:                    # decode cross-attn
        k, v = static_kv
        attn = L.attention(q, L.cast(k, dt), L.cast(v, dt), causal=False)
    elif cache is not None:                      # decode self-attn
        yk = L.layer_norm(kv_src, params[f"{prefix}ln"],
                          params[f"{prefix}ln_b"], cfg.norm_eps)
        k = _proj(params, prefix, "wk", yk, cfg.n_kv_heads, hd, dt)
        v = _proj(params, prefix, "wv", yk, cfg.n_kv_heads, hd, dt)
        k_c, v_c, key_pos = cache
        k_c, v_c, key_pos = L.cache_write(
            k_c, v_c, key_pos, L.cast(k, k_c.dtype), L.cast(v, v_c.dtype),
            positions)
        new_cache = (k_c, v_c, key_pos)
        attn = L.decode_attention(q, L.cast(k_c, dt), L.cast(v_c, dt),
                                  key_pos, positions, rules=rules)
    else:                                        # full-sequence
        # self-attn keys come from the normed input; cross-attn keys come
        # from the (already-final-normed) encoder output
        yk = y if kv_src is x else kv_src
        k = _proj(params, prefix, "wk", yk, cfg.n_kv_heads, hd, dt)
        v = _proj(params, prefix, "wv", yk, cfg.n_kv_heads, hd, dt)
        k = lc(k, ("batch", "seq", "kv_heads", None), rules)
        attn = L.attention(q, k, v, causal=causal, impl=cfg.attn_impl)

    wo = L.cast(params[f"{prefix}wo"], dt)
    out = jnp.einsum("bshk,hkd->bsd", attn,
                     wo.reshape(cfg.n_heads, hd, cfg.d_model)) \
        + L.cast(params[f"{prefix}wo_b"], dt)
    return lc(out, ("batch", "seq", "act_embed"), rules), new_cache


def _mlp(params, x, cfg, rules):
    return L.gelu_mlp(
        {"ln": params["mlp_ln"], "ln_b": params["mlp_ln_b"],
         "w_up": params["w_up"], "b_up": params["b_up"],
         "w_down": params["w_down"], "b_down": params["b_down"]},
        x, cfg, rules)


def sinusoids(length: int, channels: int) -> np.ndarray:
    t = np.log(10000) / (channels // 2 - 1)
    inv = np.exp(-t * np.arange(channels // 2))
    ang = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1)


# --------------------------------------------------------------- enc / dec
def encode(params, frames, cfg, rules=None):
    dt = jnp.dtype(cfg.compute_dtype)
    e = cfg.encdec
    x = frames.astype(dt) + jnp.asarray(
        sinusoids(e.encoder_seq, cfg.d_model), dt)[None]
    x = lc(x, ("batch", "seq", "act_embed"), rules)

    def body(x, p, _):
        attn, _ = _attn(p, "self_", x, x, cfg, causal=False, rules=rules)
        x = x + attn
        return x + _mlp(p, x, cfg, rules), None

    x, _ = scan_layers(body, x, params["encoder"], cfg)
    return L.layer_norm(x, params["enc_ln_f"], params["enc_ln_f_b"],
                        cfg.norm_eps)


def _decoder_stack(params, x, enc_out, cfg, rules, positions=None,
                   caches=None, cross_kv=None):
    def body(x, p, extra):
        cache_l, cross_l = extra
        self_cache = None if cache_l is None else \
            (cache_l["k"], cache_l["v"], cache_l["key_pos"])
        attn, new_self = _attn(p, "self_", x, x, cfg, causal=True,
                               rules=rules, cache=self_cache,
                               positions=positions)
        x = x + attn
        static_kv = None if cross_l is None else (cross_l["k"],
                                                  cross_l["v"])
        cross, _ = _attn(p, "cross_", x, enc_out, cfg, causal=False,
                         rules=rules, static_kv=static_kv)
        x = x + cross
        x = x + _mlp(p, x, cfg, rules)
        ys = None if new_self is None else \
            {"k": new_self[0], "v": new_self[1], "key_pos": new_self[2]}
        return x, ys

    x, new_caches = scan_layers(body, x, params["decoder"], cfg,
                                extra_xs=(caches, cross_kv))
    x = L.layer_norm(x, params["dec_ln_f"], params["dec_ln_f_b"],
                     cfg.norm_eps)
    return x, new_caches


def forward(params, batch, cfg, rules=None):
    enc_out = encode(params, batch["frames"], cfg, rules)
    tokens = batch["tokens"]
    dt = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params, tokens, cfg, rules) \
        + L.cast(params["pos_emb"][:tokens.shape[1]], dt)[None]
    x, _ = _decoder_stack(params, x, enc_out, cfg, rules)
    return L.unembed(params, x, cfg, rules)


# ------------------------------------------------------------------ decode
def cache_spec(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    e = cfg.encdec
    hd = cfg.head_dim_
    cross = (cfg.n_layers, batch, e.encoder_seq, cfg.n_kv_heads, hd)
    return {
        "self": attn_cache_spec(cfg, batch, decode_window(cfg, max_len)),
        "cross": {
            "k": P(cross, ("layers", "batch", "seq", "kv_heads", None),
                   init="zeros", dtype=cfg.compute_dtype),
            "v": P(cross, ("layers", "batch", "seq", "kv_heads", None),
                   init="zeros", dtype=cfg.compute_dtype),
        },
    }


def make_cross_kv(params, enc_out, cfg, rules=None):
    """Precompute decoder cross-attention K/V from the encoder output
    (prefill step of serving)."""
    dt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_

    def body(_, p, __):
        k = _proj(p, "cross_", "wk", enc_out, cfg.n_kv_heads, hd, dt)
        v = _proj(p, "cross_", "wv", enc_out, cfg.n_kv_heads, hd, dt)
        return _, (k, v)

    _, (ks, vs) = scan_layers(body, 0, params["decoder"], cfg)
    return {"k": ks, "v": vs}


def decode_step(params, cache, batch, cfg, rules=None):
    tokens, pos = batch["tokens"], batch["pos"]
    dt = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params, tokens, cfg, rules)
    x = x + jnp.take(L.cast(params["pos_emb"], dt), pos, axis=0)[:, None]
    x, new_self = _decoder_stack(params, x, None, cfg, rules,
                                 positions=pos, caches=cache["self"],
                                 cross_kv=cache["cross"])
    logits = L.unembed(params, x, cfg, rules)
    return logits, {"self": new_self, "cross": cache["cross"]}


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_specs(shape.global_batch)
    e = cfg.encdec
    return {
        "frames": jax.ShapeDtypeStruct(
            (shape.global_batch, e.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)),
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
    }
