"""Mixture-of-Experts transformer (olmoe-1b-7b, granite-moe-3b-a800m).

Every layer: GQA attention + top-k routed expert SwiGLU FFN with fixed
capacity (dense dispatch — compile-friendly and EP-shardable: the expert
axis of the weights shards on "model", XLA inserts the all-to-alls).
The router auxiliary loss is accumulated through the scan and returned to
the trainer via the `aux` output.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import P
from . import layers as L
from .common import (attn_cache_spec, decode_specs, decode_window,
                     padded_vocab, scan_layers, stacked, token_specs)


def layer_schema(cfg) -> Dict[str, P]:
    d, hd, m = cfg.d_model, cfg.head_dim_, cfg.moe
    return {
        "ln": P((d,), ("act_embed",), init="ones"),
        "wq": P((d, cfg.n_heads * hd), ("embed", "heads"), init="scaled"),
        "wk": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wv": P((d, cfg.n_kv_heads * hd), ("embed", "kv_heads"),
                init="scaled"),
        "wo": P((cfg.n_heads * hd, d), ("heads", "embed"), init="scaled"),
        "ln2": P((d,), ("act_embed",), init="ones"),
        "router": P((d, m.num_experts), ("embed", None), init="scaled"),
        "w_gate": P((m.e_pad, d, m.d_expert),
                    ("experts", "embed", "mlp"), init="scaled"),
        "w_up": P((m.e_pad, d, m.d_expert),
                  ("experts", "embed", "mlp"), init="scaled"),
        "w_down": P((m.e_pad, m.d_expert, d),
                    ("experts", "mlp", "embed"), init="scaled"),
    }


def schema(cfg) -> Dict[str, Any]:
    v = padded_vocab(cfg)
    s: Dict[str, Any] = {
        "embedding": P((v, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("act_embed",), init="ones"),
        "layers": stacked(cfg.n_layers, layer_schema(cfg)),
    }
    if not cfg.tie_embeddings:
        s["unembedding"] = P((v, cfg.d_model), ("vocab", "embed"))
    return s


def _block(params, x, cfg, *, positions, rules, cache=None):
    attn, new_cache = L.gqa_block(params, x, cfg, positions=positions,
                                  rules=rules, cache=cache,
                                  sliding_window=cfg.sliding_window)
    x = x + attn
    moe_out, aux = L.moe_block({**params, "ln": params["ln2"]}, x, cfg,
                               rules=rules)
    return x + moe_out, new_cache, aux


def forward(params, batch, cfg, rules=None, return_aux=False):
    x = L.embed(params, batch["tokens"], cfg, rules)
    positions = jnp.arange(batch["tokens"].shape[1])[None, :]

    def body(carry, p, _):
        x, aux = carry
        x, _, aux_l = _block(p, x, cfg, positions=positions, rules=rules)
        return (x, aux + aux_l), None

    (x, aux), _ = scan_layers(body, (x, jnp.zeros((), jnp.float32)),
                              params["layers"], cfg)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, x, cfg, rules)
    if return_aux:
        return logits, aux
    return logits


def cache_spec(cfg, batch: int, max_len: int) -> Dict[str, P]:
    return attn_cache_spec(cfg, batch, decode_window(cfg, max_len))


def decode_step(params, cache, batch, cfg, rules=None):
    x = L.embed(params, batch["tokens"], cfg, rules)
    pos = batch["pos"]

    def body(x, p, cache_l):
        x, new_cache, _ = _block(p, x, cfg, positions=pos, rules=rules,
                                 cache=(cache_l["k"], cache_l["v"],
                                        cache_l["key_pos"]))
        k, v, kp = new_cache
        return x, {"k": k, "v": v, "key_pos": kp}

    x, new_cache = scan_layers(body, x, params["layers"], cfg,
                               extra_xs=cache)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(params, x, cfg, rules), new_cache


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_specs(shape.global_batch)
    return token_specs(shape.global_batch, shape.seq_len)
