"""Architecture registry: ModelConfig.arch_kind → model module.

`Model` is a thin façade bundling the per-family functions with exact
(schema-derived) parameter counts for the roofline's MODEL_FLOPS term.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.parallel.sharding import P, abstract_params, init_params
from . import dense, mamba2, moe, whisper, xlstm

_MODULES = {
    "dense": dense,
    "vlm": dense,                   # LLaVA backbone = dense + patch proj
    "moe": moe,
    "mamba2_hybrid": mamba2,
    "xlstm": xlstm,
    "whisper": whisper,
}


@dataclass(frozen=True)
class Model:
    cfg: Any
    module: Any

    # ------------------------------------------------------------- params
    def schema(self) -> Any:
        return self.module.schema(self.cfg)

    def abstract_params(self) -> Any:
        return abstract_params(self.schema(), self.cfg.param_dtype)

    def init(self, rng: jax.Array) -> Any:
        return init_params(self.schema(), rng, self.cfg.param_dtype)

    def param_count(self) -> int:
        """Exact parameter count, derived from the schema."""
        leaves = jax.tree.leaves(self.schema(),
                                 is_leaf=lambda x: isinstance(x, P))
        return int(sum(int(np.prod(p.shape)) for p in leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of the expert FFNs)."""
        m = self.cfg.moe
        if m is None:
            return self.param_count()
        expert = 3 * self.cfg.d_model * m.d_expert * self.cfg.n_layers
        inactive = expert * (m.e_pad - m.top_k)
        return self.param_count() - inactive

    # ------------------------------------------------------------ compute
    def forward(self, params, batch, rules=None):
        return self.module.forward(params, batch, self.cfg, rules=rules)

    def decode_step(self, params, cache, batch, rules=None):
        return self.module.decode_step(params, cache, batch, self.cfg,
                                       rules=rules)

    # ------------------------------------------------------------- decode
    def cache_schema(self, batch: int, max_len: int) -> Any:
        return self.module.cache_spec(self.cfg, batch, max_len)

    def abstract_cache(self, batch: int, max_len: int) -> Any:
        return abstract_params(self.cache_schema(batch, max_len),
                               self.cfg.compute_dtype)

    def init_cache(self, batch: int, max_len: int) -> Any:
        return init_params(self.cache_schema(batch, max_len),
                           jax.random.PRNGKey(0), self.cfg.compute_dtype)

    # -------------------------------------------------------------- shapes
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        return self.module.input_specs(self.cfg, shape)


def get_model(cfg) -> Model:
    if cfg.arch_kind not in _MODULES:
        raise KeyError(f"unknown arch_kind {cfg.arch_kind!r}; "
                       f"known: {sorted(_MODULES)}")
    return Model(cfg, _MODULES[cfg.arch_kind])
