"""Architecture zoo: 10 assigned architectures over 5 model families."""
from .registry import Model, get_model

__all__ = ["Model", "get_model"]
